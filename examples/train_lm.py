"""End-to-end driver: train a ~100M-param LM for a few hundred steps on the
synthetic pipeline with checkpointing, fault tolerance, and straggler
monitoring — then evaluate it through the analog serving path.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.config import ModelConfig
from repro.data.synthetic import SyntheticLM
from repro.optim.adamw import cosine_schedule
from repro.runtime.fault import StragglerMonitor, resilient_step
from repro.train.step import make_train_state, train_step_fn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    # ~100M params: 8 layers x d=768 x ff=3072, 32k vocab
    cfg = ModelConfig(name="lm-100m", family="dense", n_layers=8,
                      d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                      vocab=32000, dtype="float32", remat=False)
    print(f"params ~{cfg.param_count()/1e6:.0f}M")
    ds = SyntheticLM(cfg=cfg, seq_len=128, global_batch=16, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0))
    sched = cosine_schedule(3e-4, warmup=20, total=args.steps)
    step = jax.jit(train_step_fn(cfg, microbatches=2, lr_schedule=sched))
    mgr = CheckpointManager(args.ckpt_dir, keep_last=2)
    mon = StragglerMonitor()

    start = mgr.latest_step() or 0
    if start:
        state, start, _ = mgr.restore(state)
        print(f"resumed from step {start}")
    for i in range(start, args.steps):
        t0 = time.perf_counter()
        state, m = resilient_step(step, state, ds.batch(i))
        mon.record(time.perf_counter() - t0)
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.2f}")
        if i % 100 == 99:
            mgr.save_async(i + 1, state)
    mgr.wait()
    print(f"done; stragglers flagged: {len(mon.flagged)}")
if __name__ == "__main__":
    main()
