"""Serve a stream of mixed-length requests through the continuous-batching
analog runtime: train a tiny LM, program + calibrate it onto the analog
substrate (Design A + SONOS-style errors), then drain a request trace
with temperature sampling — watching completions stream out as slots
free up and refill.

Run: PYTHONPATH=src python examples/serve_loop.py
"""

import numpy as np
import jax

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.data.synthetic import SyntheticLM
from repro.serve import (
    SamplerConfig, ServeRuntime, calibrate_lm, program_lm)
from repro.train.step import make_train_state, train_step_fn


def main():
    cfg = get_smoke_config("qwen1.5-4b")
    ds = SyntheticLM(cfg=cfg, seq_len=32, global_batch=8, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), lr=3e-3)
    step = jax.jit(train_step_fn(cfg, lr=3e-3))
    for i in range(120):
        state, m = step(state, ds.batch(i))
    print(f"trained tiny qwen-style LM to loss {float(m['loss']):.3f}")

    # program + calibrate one analog design point; the running server is
    # then a valid sweep point (alpha / r_hat ride in the pack's spec)
    spec = A.design_a(error=E.state_proportional(0.05))
    pack = program_lm(cfg, state.params, spec, jax.random.PRNGKey(7))
    pack = calibrate_lm(cfg, state.params, pack, ds.batch(499)["tokens"])

    rt = ServeRuntime(
        cfg, state.params, pack=pack, max_slots=4, max_len=48,
        buckets=(8, 16),
        sampler=SamplerConfig(kind="top_k", top_k=8, temperature=0.9),
        seed=0,
    )

    # a mixed trace: variable prompt lengths AND generation budgets
    rng = np.random.default_rng(0)
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab, size=int(rng.integers(3, 15)))
        rt.submit(prompt, max_new_tokens=int(rng.integers(4, 17)), uid=i)

    print(f"\nserving 10 requests on {rt.max_slots} slots "
          f"(continuous batching, top-k sampling):")
    while not rt.idle:
        for c in rt.step():
            print(f"  request {c.uid}: prompt[{c.prompt_len}] -> "
                  f"{c.tokens.tolist()}  (ttft {1e3 * c.ttft_s:.0f} ms)")

    s = rt.stats
    print(f"\n{s['tokens_out']} tokens in {s['decode_steps']} decode steps "
          f"+ {s['prefill_calls']} prefill calls; "
          f"slot occupancy {s['occupancy']:.0%}, "
          f"mean ttft {1e3 * np.mean(s['ttft_s']):.0f} ms")


if __name__ == "__main__":
    main()
