"""Design-space exploration: sweep the analog core design axes and print
the accuracy / energy / area frontier (the paper's Sec. 9 case study).

Demonstrates the ``repro.sweep`` engine end to end: the five named
designs are an explicit-point :class:`~repro.sweep.SweepSpec`, accuracy
comes from the vectorized :class:`~repro.sweep.ClassifierEvaluator`
(trials vmapped, results cached+resumable on disk), and the energy/area
columns reuse the same design points through ``repro.core.energy``.

Run: PYTHONPATH=src:. python examples/design_space.py
"""

from benchmarks.common import digital_accuracy, run_bench_sweep, train_mlp
from repro.core import energy as en
from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import SONOS_ON_OFF, sonos
from repro.core.mapping import MappingConfig
from repro.sweep import SweepSpec

DESIGNS = [
    ("differential", None, 1152, "analog", 0.02),
    ("differential", 1, 1152, "analog", 0.08),
    ("differential", None, 144, "analog", 0.02),
    ("differential", None, 1152, "digital", 0.02),
    ("offset", 2, 72, "digital", 0.5),
]


def main():
    params = train_mlp()
    base = digital_accuracy(params)
    print(f"digital 8-bit baseline: {base:.4f}\n")
    print(f"{'design':<44}{'acc':>8}{'fJ/op':>10}{'mm^2':>8}")

    def name_of(scheme, bpc, rows, accum):
        return f"{scheme}/bpc={bpc}/rows={rows}/{accum}"

    sweep = SweepSpec.from_points(
        "example_design_space",
        [
            (name_of(scheme, bpc, rows, accum), AnalogSpec(
                mapping=MappingConfig(scheme=scheme, bits_per_cell=bpc,
                                      on_off_ratio=SONOS_ON_OFF),
                adc=ADCConfig(style="calibrated", bits=8),
                error=sonos(), input_accum=accum, max_rows=rows))
            for scheme, bpc, rows, accum, _ in DESIGNS
        ],
        trials=3,
    )
    res = run_bench_sweep(sweep)
    for (scheme, bpc, rows, accum, g_avg), r in zip(DESIGNS, res):
        spec = sweep.explicit[r.index][1]
        c = en.core_costs(spec, g_avg=g_avg)
        print(f"{r.tag:<44}{r.mean:>8.4f}{c.energy_fj_per_op:>10.1f}"
              f"{c.area_mm2:>8.2f}")


if __name__ == "__main__":
    main()
