"""Serve a trained LM through the analog pipeline: program -> calibrate ->
generate, comparing digital and analog generations and perplexity across
hardware design points (the paper's Table 4 on an LM).

Run: PYTHONPATH=src python examples/analog_serve.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.data.synthetic import SyntheticLM
from repro.serve.analog_engine import (
    analog_eval_loss, calibrate_lm, decode_lm, program_lm)
from repro.train.step import make_train_state, train_step_fn


def main():
    cfg = get_smoke_config("gemma-2b")
    ds = SyntheticLM(cfg=cfg, seq_len=64, global_batch=8, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), lr=3e-3)
    step = jax.jit(train_step_fn(cfg, lr=3e-3))
    for i in range(120):
        state, m = step(state, ds.batch(i))
    print(f"trained tiny gemma-style LM to loss {float(m['loss']):.3f}")

    batch = ds.batch(500)
    designs = {
        "A  diff/unsliced/analog-accum + SONOS": A.design_a(error=E.sonos()),
        "A' diff/unsliced, no errors": A.design_a(),
        "E  offset/2b/digital-accum + SONOS": A.design_e(error=E.sonos()),
    }
    from repro.train.step import loss_fn
    dig = float(loss_fn(cfg, state.params, batch)[0])
    print(f"digital eval loss: {dig:.4f}")
    for name, spec in designs.items():
        pack = program_lm(cfg, state.params, spec, jax.random.PRNGKey(7))
        pack = calibrate_lm(cfg, state.params, pack, ds.batch(499)["tokens"])
        al = float(analog_eval_loss(cfg, state.params, pack,
                                    batch["tokens"], batch["targets"]))
        print(f"{name:42s} analog loss {al:.4f} (delta {al-dig:+.4f})")

    # batched greedy serving through the analog path: one prefill + a
    # scanned decode loop per request batch (repro.serve.decode_lm)
    pack = program_lm(cfg, state.params, A.design_a(error=E.sonos()),
                      jax.random.PRNGKey(7))
    pack = calibrate_lm(cfg, state.params, pack, ds.batch(499)["tokens"])
    prompts = batch["tokens"][:4, :8]
    analog_toks = decode_lm(cfg, state.params, prompts, 8, pack=pack)
    digital_toks = decode_lm(cfg, state.params, prompts, 8, pack=None)
    match = float(jnp.mean((analog_toks == digital_toks).astype(jnp.float32)))
    print("analog greedy continuations:", np.asarray(analog_toks).tolist())
    print(f"agreement with digital serving: {match:.0%}")


if __name__ == "__main__":
    main()
