"""Heterogeneous per-site hardware: serve one LM with 8-bit-ADC attention
arrays, 6-bit-ADC MLP arrays, and a digital lm_head.

``repro.hw.Profile`` resolves every analog matmul site (hook name) to its
own AnalogSpec via pattern rules — the paper's "match the precision of
the hardware to the needs of the algorithm", made concrete.  The same
``program_lm -> calibrate_lm -> decode_lm`` pipeline serves the mixed
pack unchanged, and ``core.energy`` prices each site class on its own
spec and array shape.

Run: PYTHONPATH=src python examples/hetero_profile.py
"""

import dataclasses

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import energy as en
from repro.core import errors as E
from repro.data.synthetic import SyntheticLM
from repro.hw import DIGITAL, Profile, site_class
from repro.serve.analog_engine import (
    analog_eval_loss, calibrate_lm, decode_lm, program_lm)
from repro.train.step import loss_fn, make_train_state, train_step_fn


def main():
    cfg = get_smoke_config("qwen1.5-4b")
    ds = SyntheticLM(cfg=cfg, seq_len=32, global_batch=8, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), lr=3e-3)
    step = jax.jit(train_step_fn(cfg, microbatches=1, lr=3e-3))
    for i in range(60):
        state, m = step(state, ds.batch(i))
    print(f"trained smoke LM to loss {float(m['loss']):.3f}")

    attn_spec = A.design_a(error=E.state_proportional(0.05))      # 8-bit ADC
    mlp_spec = dataclasses.replace(
        attn_spec, adc=dataclasses.replace(attn_spec.adc, bits=6))
    profile = Profile.by_class(attn=attn_spec, mlp=mlp_spec, head=DIGITAL)

    pack = program_lm(cfg, state.params, profile, jax.random.PRNGKey(7))
    pack = calibrate_lm(cfg, state.params, pack, ds.batch(998)["tokens"])
    assert pack.head is None, "head stays off-array (digital fallback)"

    batch = ds.batch(999)
    dig = float(loss_fn(cfg, state.params, batch)[0])
    al = float(analog_eval_loss(cfg, state.params, pack,
                                batch["tokens"], batch["targets"]))
    print(f"digital loss {dig:.4f} | 8b-attn/6b-mlp/digital-head analog "
          f"loss {al:.4f} (delta {al - dig:+.4f})")

    toks = decode_lm(cfg, state.params, batch["tokens"][:2, :8], 6, pack=pack)
    print(f"served 2 prompts through the mixed pack: {np.asarray(toks)}")

    # per-site ADC energy under each site's OWN resolved spec and shape:
    # the 6-bit MLP class converts at a quarter of the 8-bit energy
    print(f"{'site':<10} {'class':<6} {'shape':<12} {'adc bits':<9} "
          f"{'conversions':<12} adc energy")
    for name, aw in sorted(pack.layer_weights.items()):
        spec = pack.site_spec(name)
        k, n = aw.k, aw.n
        conv = spec.adc_conversions_per_mvm(k, n)
        e = en.adc_energy(spec, k, n)
        print(f"{name:<10} {site_class(name):<6} {f'{k}x{n}':<12} "
              f"{spec.adc.bits:<9} {conv:<12} {e:8.1f} pJ/MVM")


if __name__ == "__main__":
    main()
