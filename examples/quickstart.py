"""Quickstart: the paper's contribution in 40 lines.

Programs a weight matrix onto simulated analog arrays under the paper's
recommended design (differential cells, unsliced weights, analog input
accumulation, calibrated 8-bit ADC) and the ISAAC-like offset baseline,
injects SONOS-measured programming errors, and compares dot-product error.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import analog as A
from repro.core import errors as E
from repro.core.adc import ADCConfig
from repro.core.mapping import MappingConfig


def main():
    key = jax.random.PRNGKey(0)
    w = jax.random.laplace(key, (1152, 256)) * 0.02       # zero-peaked weights
    x = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(1), (64, 1152)))

    ideal = None
    for name, spec in [
        ("design A (differential, unsliced, analog-accum)",
         A.design_a(error=E.sonos())),
        ("design E (offset, 2b slices, digital-accum)",
         A.AnalogSpec(mapping=MappingConfig(scheme="offset", bits_per_cell=2),
                      adc=ADCConfig(style="calibrated", bits=8),
                      error=E.sonos(), input_accum="digital", max_rows=72)),
    ]:
        aw = A.program(w, spec, jax.random.PRNGKey(42))
        # calibrate the ADC range on a held-out batch (Sec. 6.2)
        xc = jax.nn.relu(jax.random.normal(jax.random.PRNGKey(2), (64, 1152)))
        _, stats = A.analog_matmul(xc, aw, spec, collect=True)
        lo, hi = stats[:, 0], stats[:, 1]
        y = A.analog_matmul(x, aw, spec, adc_lo=lo, adc_hi=hi)
        if ideal is None:
            spec0 = dataclasses.replace(
                A.design_a(), adc=ADCConfig(style="none"))
            ideal = A.analog_matmul(x, A.program(w, spec0), spec0)
        err = float(jnp.sqrt(jnp.mean((y - ideal) ** 2)) / jnp.std(ideal))
        print(f"{name}\n  relative dot-product error: {err:.4f}")
    print("\nproportional mapping wins — see benchmarks/ for the full study")


if __name__ == "__main__":
    main()
