"""Fig. 8/9: accuracy sensitivity to state-independent (Fig. 8) and
state-proportional (Fig. 9) cell errors, offset vs differential mappings,
with and without bit slicing.  No ADC (the paper isolates cell errors).

Claims validated:
  * offset systems are ~equally sensitive to both error types;
  * differential beats offset for state-independent errors (~2x);
  * differential + proportional errors is by far the most robust (>4x the
    offset tolerance even on this small model; the paper reports >10x on
    zero-peaked ImageNet nets);
  * finer slicing helps slightly under state-independent errors (the
    sqrt(3) SNR effect of Eq. 9/10).

Each figure is one SweepSpec: a zipped (scheme, input-accumulation) axis
x bits-per-cell x error magnitude.  All points sharing a compiled shape
(same scheme/slicing) run as one jitted evaluation with the error
magnitudes batched as traced scalars and trials vmapped over PRNG keys.
"""

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_independent, state_proportional
from repro.core.mapping import MappingConfig

from repro.sweep import Axis, SweepSpec

from benchmarks.common import (
    Timer, digital_accuracy, emit, emit_sweep, run_bench_sweep, train_mlp,
    trials_for)

ALPHAS_IND = (0.01, 0.02, 0.05)
ALPHAS_PROP = (0.02, 0.05, 0.10)

SCHEME_AXIS = Axis(
    ("mapping.scheme", "input_accum"),
    (("offset", "digital"), ("differential", "analog")),
    labels=("offset", "differential"),
)


def fig_sweep(name: str, make_err, alphas) -> SweepSpec:
    return SweepSpec(
        name=name,
        base=AnalogSpec(
            mapping=MappingConfig(),
            adc=ADCConfig(style="none"),
            max_rows=1152,
        ),
        axes=(
            SCHEME_AXIS,
            Axis("mapping.bits_per_cell", (None, 2),
                 labels=("bpcNone", "bpc2")),
            Axis("error", tuple(make_err(a) for a in alphas),
                 labels=tuple(f"a{a}" for a in alphas)),
        ),
        trials=trials_for(5),
    )


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)
    emit("fig8_9_digital_baseline", 0.0, f"acc={base:.4f}")

    results = {}
    for fig, make_err, alphas in (
        ("fig8", state_independent, ALPHAS_IND),
        ("fig9", state_proportional, ALPHAS_PROP),
    ):
        res = run_bench_sweep(fig_sweep(fig, make_err, alphas))
        emit_sweep(fig, res)
        for scheme in ("offset", "differential"):
            for bpc in ("bpcNone", "bpc2"):
                for a in alphas:
                    results[(fig, scheme, bpc, a)] = res.mean(
                        f"{scheme}_{bpc}_a{a}")

    # claim checks (printed as derived values)
    off_ind = results[("fig8", "offset", "bpcNone", 0.02)]
    dif_ind = results[("fig8", "differential", "bpcNone", 0.02)]
    off_prp = results[("fig9", "offset", "bpcNone", 0.05)]
    dif_prp = results[("fig9", "differential", "bpcNone", 0.05)]
    emit("fig8_claim_diff_beats_offset_ind", 0.0,
         f"diff={dif_ind:.3f} > offset={off_ind:.3f}: {dif_ind > off_ind}")
    emit("fig9_claim_diff_prop_most_robust", 0.0,
         f"diff/prop={dif_prp:.3f} vs offset/prop={off_prp:.3f} vs "
         f"baseline={base:.3f}: drop {base-dif_prp:.3f} vs {base-off_prp:.3f}")
