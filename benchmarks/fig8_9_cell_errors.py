"""Fig. 8/9: accuracy sensitivity to state-independent (Fig. 8) and
state-proportional (Fig. 9) cell errors, offset vs differential mappings,
with and without bit slicing.  No ADC (the paper isolates cell errors).

Claims validated:
  * offset systems are ~equally sensitive to both error types;
  * differential beats offset for state-independent errors (~2x);
  * differential + proportional errors is by far the most robust (>4x the
    offset tolerance even on this small model; the paper reports >10x on
    zero-peaked ImageNet nets);
  * finer slicing helps slightly under state-independent errors (the
    sqrt(3) SNR effect of Eq. 9/10).
"""

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_independent, state_proportional
from repro.core.mapping import MappingConfig

from benchmarks.common import Timer, analog_accuracy, digital_accuracy, emit, train_mlp

ALPHAS_IND = (0.01, 0.02, 0.05)
ALPHAS_PROP = (0.02, 0.05, 0.10)


def spec_for(scheme, bpc, err):
    return AnalogSpec(
        mapping=MappingConfig(scheme=scheme, bits_per_cell=bpc),
        adc=ADCConfig(style="none"),
        error=err,
        input_accum="analog" if scheme == "differential" else "digital",
        max_rows=1152,
    )


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)
    emit("fig8_9_digital_baseline", 0.0, f"acc={base:.4f}")

    results = {}
    for fig, make_err, alphas in (
        ("fig8", state_independent, ALPHAS_IND),
        ("fig9", state_proportional, ALPHAS_PROP),
    ):
        for scheme in ("offset", "differential"):
            for bpc in (None, 2):
                for a in alphas:
                    spec = spec_for(scheme, bpc, make_err(a))
                    import time

                    t0 = time.perf_counter()
                    m, s = analog_accuracy(params, spec, trials=5)
                    us = (time.perf_counter() - t0) * 1e6 / 5
                    key = (fig, scheme, bpc, a)
                    results[key] = m
                    emit(
                        f"{fig}_{scheme}_bpc{bpc}_a{a}", us,
                        f"acc={m:.4f}+-{s:.4f}",
                    )

    # claim checks (printed as derived values)
    off_ind = results[("fig8", "offset", None, 0.02)]
    dif_ind = results[("fig8", "differential", None, 0.02)]
    off_prp = results[("fig9", "offset", None, 0.05)]
    dif_prp = results[("fig9", "differential", None, 0.05)]
    emit("fig8_claim_diff_beats_offset_ind", 0.0,
         f"diff={dif_ind:.3f} > offset={off_ind:.3f}: {dif_ind > off_ind}")
    emit("fig9_claim_diff_prop_most_robust", 0.0,
         f"diff/prop={dif_prp:.3f} vs offset/prop={off_prp:.3f} vs "
         f"baseline={base:.3f}: drop {base-dif_prp:.3f} vs {base-off_prp:.3f}")
