"""Fig. 18/19: bit-line current accumulation and parasitic-resistance
sensitivity.

Claims validated:
  * proportional (differential) mapping reduces bottom-of-line currents by
    an order of magnitude vs offset (Fig. 18);
  * offset subtraction is orders of magnitude more sensitive to normalized
    parasitic resistance than differential cells (Fig. 19(c));
  * differential accuracy loss is negligible at R_p_hat <= 1e-5 (the
    realistic operating point for >=100 kOhm cells in scaled metal).
"""

import time

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec, analog_matmul, program
from repro.core.errors import ErrorModel
from repro.core.mapping import MappingConfig

from benchmarks.common import (
    Timer, analog_accuracy, digital_accuracy, emit, eval_data, train_mlp)


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)

    # --- Fig. 18: accumulated bit-line currents ---------------------------
    xca, _, _, _ = eval_data()
    w = params[1][0]
    for scheme in ("offset", "differential"):
        spec = AnalogSpec(
            mapping=MappingConfig(scheme=scheme),
            adc=ADCConfig(style="none"), error=ErrorModel(),
            input_accum="digital", max_rows=1152)
        aw = program(w, spec)
        # LSB input plane activates the most rows (paper Fig. 18)
        from repro.core.quant import bit_planes, quantize_acts

        h = jax.nn.relu(xca[:64] @ params[0][0] + params[0][1])
        xq = quantize_acts(h, 8, signed=True)
        planes = bit_planes(xq.values, 7)
        lsb = planes[0]
        i_pos = jnp.abs(lsb) @ aw.g_pos[0, 0]          # bottom-of-line current
        emit(f"fig18_current_{scheme}", 0.0,
             f"mean_bitline_current={float(jnp.mean(i_pos)):.2f} "
             f"(units of I_max; rows={w.shape[0]})")

    # --- Fig. 19(c): accuracy vs normalized parasitic resistance ----------
    for scheme, accum in (("differential", "analog"), ("offset", "digital")):
        for r_hat in (1e-5, 1e-4, 1e-3):
            spec = AnalogSpec(
                mapping=MappingConfig(scheme=scheme),
                adc=ADCConfig(style="none"), error=ErrorModel(),
                input_accum=accum, max_rows=256, r_hat=r_hat)
            t0 = time.perf_counter()
            # 256-sample subset: the bit-line circuit solve is the paper's
            # own tractability bottleneck (Sec. 9.4 skips it entirely)
            m, s = analog_accuracy(params, spec, trials=1, test_n=256)
            emit(f"fig19_{scheme}_r{r_hat:g}",
                 (time.perf_counter() - t0) * 1e6,
                 f"acc={m:.4f} (drop={base - m:+.4f})")
