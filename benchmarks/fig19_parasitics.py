"""Fig. 18/19: bit-line current accumulation and parasitic-resistance
sensitivity.

Claims validated:
  * proportional (differential) mapping reduces bottom-of-line currents by
    an order of magnitude vs offset (Fig. 18);
  * offset subtraction is orders of magnitude more sensitive to normalized
    parasitic resistance than differential cells (Fig. 19(c));
  * differential accuracy loss is negligible at R_p_hat <= 1e-5 (the
    realistic operating point for >=100 kOhm cells in scaled metal).

Fig. 18 is a deterministic per-scheme metric (FunctionEvaluator); the
Fig. 19(c) accuracy grid is a scheme x r_hat SweepSpec.  ``r_hat`` is a
*dynamic* field of the evaluator (``AnalogSpec.parasitics_on`` keeps only
the on/off decision static), so the whole parasitic axis runs as ONE
compile group per scheme with ``r_hat`` substituted as a traced scalar —
one tridiagonal-solve program instead of one compilation per level.
``test_n=256`` applies the paper's own subset trick for the solve's cost
(Sec. 9.4 skips it entirely)."""

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec, program
from repro.core.mapping import MappingConfig
from repro.sweep import Axis, FunctionEvaluator, SweepSpec

from benchmarks.common import (
    Timer, digital_accuracy, emit, emit_sweep, eval_data, run_bench_sweep,
    train_mlp)

SCHEME_AXIS = Axis(
    ("mapping.scheme", "input_accum"),
    (("differential", "analog"), ("offset", "digital")),
    labels=("differential", "offset"),
)

R_HATS = (1e-5, 1e-4, 1e-3)


def fig19_sweep(r_hats=R_HATS, *, trials: int = 1,
                test_n: int = 256) -> SweepSpec:
    """The Fig. 19(c) scheme x r_hat accuracy grid (also the golden /
    smoke grid, thinned via the arguments)."""
    return SweepSpec(
        name="fig19",
        base=AnalogSpec(adc=ADCConfig(style="none"), max_rows=256),
        axes=(
            SCHEME_AXIS,
            Axis("r_hat", tuple(r_hats),
                 labels=tuple(f"r{r:g}" for r in r_hats)),
        ),
        trials=trials,
        test_n=test_n,
    )


def main(timer: Timer):
    from benchmarks import common

    params = train_mlp()
    base = digital_accuracy(params)

    # --- Fig. 18: accumulated bit-line currents ---------------------------
    xca, _, _, _ = eval_data()
    w = params[1][0]

    def bitline_current(spec: AnalogSpec):
        from repro.core.quant import bit_planes, quantize_acts

        aw = program(w, spec)
        # LSB input plane activates the most rows (paper Fig. 18)
        h = jax.nn.relu(xca[:64] @ params[0][0] + params[0][1])
        xq = quantize_acts(h, 8, signed=True)
        lsb = bit_planes(xq.values, 7)[0]
        i_pos = jnp.abs(lsb) @ aw.g_pos[0, 0]      # bottom-of-line current
        return jnp.mean(i_pos)

    fig18 = SweepSpec(
        name="fig18",
        base=AnalogSpec(adc=ADCConfig(style="none"), input_accum="digital",
                        max_rows=1152),
        axes=(Axis("mapping.scheme", ("offset", "differential")),),
        trials=0,
    )
    res18 = run_bench_sweep(
        fig18, FunctionEvaluator(
            bitline_current, name="fig18_current",
            data=(w, params[0][0], params[0][1], xca)))
    for r in res18:
        emit(f"fig18_current_{r.coords['mapping.scheme']}", 0.0,
             f"mean_bitline_current={r.values[0]:.2f} "
             f"(units of I_max; rows={w.shape[0]})")

    # --- Fig. 19(c): accuracy vs normalized parasitic resistance ----------
    fig19 = (fig19_sweep((1e-4,), test_n=64) if common.SMOKE
             else fig19_sweep())
    res19 = run_bench_sweep(fig19)
    emit_sweep("fig19", res19,
               fmt=lambda r: f"acc={r.mean:.4f} (drop={base - r.mean:+.4f})")
