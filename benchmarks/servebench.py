"""Continuous vs static batching of the analog LM (`repro.serve.runtime`).

The serving-system benchmark: the trained smoke LM is programmed and
calibrated once (Design A + state-proportional cell error — a valid
sweep design point, served), then a mixed-length request trace is
drained twice through the same jitted slot machinery:

  * **continuous** — iteration-level scheduling: slots refill the moment
    a request retires (``ServeRuntime``);
  * **static** — gang scheduling: admit a full batch, pad every prompt
    to one bucket, drain until the *longest* request finishes
    (``ServeRuntime(gang=True)``) — classic static batching.

Reported per mode: tokens/s, mean time-to-first-token, slot occupancy,
decode-step/prefill-call counts.  Two claims are *gated* (the benchmark
raises, and ``benchmarks.run`` exits nonzero, when they fail):

  * continuous-batching throughput >= 1.5x static on the mixed trace at
    equal analog config;
  * runtime-vs-``decode_lm`` greedy token agreement == 1.0 — scheduling
    must never change what the model says
    (``repro.sweep.serve_eval.runtime_agreement``).

Both modes pay identical per-step costs (same compiled decode/prefill
programs), so the speedup isolates the *scheduling* difference: static
batches burn ``max(max_new)`` steps per gang while continuous burns
``~sum(max_new)/max_slots``.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import analog as A
from repro.core import errors as E
from repro.serve import PagedServeRuntime, ServeRuntime, calibrate_lm, program_lm
from repro.serve.runtime import SamplerConfig
from repro.sweep.serve_eval import paged_runtime_agreement, runtime_agreement

from benchmarks.common import Timer, emit
from benchmarks.lm_accuracy import CALIB_STEP, trained_lm

MAX_SLOTS = 8
MAX_LEN = 80
BUCKETS = (8, 16)
#: long-tail generation budget — the static scheduler pads every gang to it
TAIL_NEW = 64

# paged-vs-dense comparison: equal KV *token* budget.  Dense KV capacity
# is MAX_SLOTS * MAX_LEN = 640 token slots; the paged pool gets exactly
# the same 640 tokens (80 data pages of 8) plus the reserved sink page,
# but may spread them over twice the decode lanes because slots no
# longer pre-own max_len tokens each.
PAGE_SIZE = 8
PAGED_SLOTS = 16
PAGED_PAGES = MAX_SLOTS * MAX_LEN // PAGE_SIZE + 1
#: shared system-prompt length for the prefix-heavy trace (3 full pages)
PREFIX_LEN = 24
#: generation budgets on the prefix trace: moderate and uniform, so the
#: drain is lane-capacity-bound (what paging pools) rather than
#: serialized behind one long straggler whose budget alone sets the
#: step count for both runtimes
PREFIX_NEW_LO, PREFIX_NEW_HI = 6, 15
PREFIX_BUCKETS = (8, 32)


def request_trace(n: int, vocab: int, seed: int = 0):
    """A mixed-length offline trace: prompts 3..14 tokens, generation
    budgets heavy-tailed (one TAIL_NEW-token request per MAX_SLOTS
    arrivals, the rest 2..6) — the regime where gang scheduling burns
    ``max(max_new)`` decode steps per batch while continuous batching
    burns ``~sum(max_new) / max_slots``."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 15))
        n_new = TAIL_NEW if i % MAX_SLOTS == 0 else int(rng.integers(2, 7))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append((prompt, n_new))
    return reqs


def shared_prefix_trace(n: int, vocab: int, seed: int = 3):
    """A prefix-heavy trace: every prompt opens with the same
    PREFIX_LEN-token system prompt (3 full pages — radix-cache fodder)
    followed by a unique 2..6-token tail, and carries a uniform
    moderate PREFIX_NEW_LO..PREFIX_NEW_HI generation budget — enough
    decode work that the drain measures how many lanes the KV budget
    sustains, staggered retirements keeping admission continuous."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=PREFIX_LEN).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(2, 7))).astype(np.int32)
        n_new = int(rng.integers(PREFIX_NEW_LO, PREFIX_NEW_HI))
        reqs.append((np.concatenate([prefix, tail]), n_new))
    return reqs


def serve_pack(cfg, params, ds):
    """Program + calibrate the benchmark's analog design point."""
    spec = A.design_a(error=E.state_proportional(0.02))
    pack = program_lm(cfg, params, spec, jax.random.PRNGKey(7))
    return calibrate_lm(cfg, params, pack, ds.batch(CALIB_STEP)["tokens"])


def drain(rt: ServeRuntime, reqs) -> dict:
    """Submit the whole trace, drain it, and return timing + stats."""
    for i, (prompt, n_new) in enumerate(reqs):
        rt.submit(prompt, max_new_tokens=n_new, uid=i)
    t0 = time.perf_counter()
    outs = rt.run()
    wall = time.perf_counter() - t0
    s = rt.stats
    assert len(outs) == len(reqs)
    return {
        "wall_s": wall,
        "tokens": s["tokens_out"],
        "tok_per_s": s["tokens_out"] / wall,
        "ttft_ms": 1e3 * float(np.mean(s["ttft_s"])),
        "occupancy": s["occupancy"],
        "steps": s["decode_steps"],
        "prefills": s["prefill_calls"],
    }


def bench_mode(cfg, params, pack, reqs, *, gang: bool) -> dict:
    """Throughput and TTFT as separate passes: the TTFT pass blocks on
    each prefill's results (true submit->first-token wall time), which
    defeats dispatch pipelining — so tokens/s comes from a non-blocking
    pass over the same schedule."""
    rt = ServeRuntime(cfg, params, pack=pack, max_slots=MAX_SLOTS,
                      max_len=MAX_LEN, buckets=BUCKETS, gang=gang)
    drain(rt, reqs)                      # warm: compile every (bucket, G)
    runs = []
    for _ in range(2):                   # timed: best of 2 damps CI noise
        rt.reset()
        runs.append(drain(rt, reqs))
    r = min(runs, key=lambda x: x["wall_s"])
    rt.reset()
    rt.measure_ttft = True               # latency pass, same compiled fns
    r["ttft_ms"] = drain(rt, reqs)["ttft_ms"]
    return r


def bench_paged_pair(cfg, params, pack, reqs):
    """Dense (8 slots x 80) vs paged (16 lanes, same 640-token pool) on
    the shared-prefix trace; same warm + best-of-2 protocol as
    ``bench_mode``."""
    rows = {}
    makers = {
        "dense_kv": lambda: ServeRuntime(
            cfg, params, pack=pack, max_slots=MAX_SLOTS, max_len=MAX_LEN,
            buckets=PREFIX_BUCKETS),
        "paged_kv": lambda: PagedServeRuntime(
            cfg, params, pack=pack, max_slots=PAGED_SLOTS, max_len=MAX_LEN,
            buckets=PREFIX_BUCKETS, page_size=PAGE_SIZE,
            num_pages=PAGED_PAGES),
    }
    for mode, make_rt in makers.items():
        rt = make_rt()
        drain(rt, reqs)                  # warm: compile every group once
        runs = []
        for _ in range(2):
            rt.reset()
            runs.append(drain(rt, reqs))
        r = rows[mode] = min(runs, key=lambda x: x["wall_s"])
        r["tok_per_step"] = r["tokens"] / max(r["steps"], 1)
        extra = ""
        if isinstance(rt, PagedServeRuntime):
            rt.check()                   # pool/radix invariants post-drain
            s = rt.stats
            extra = (f" prefix_hits={s['prefix_hits']} "
                     f"reused_toks={s['prefix_tokens_reused']} "
                     f"evictions={s['cache_evictions']} "
                     f"stalls={s['admission_stalls']}")
        emit(f"servebench_{mode}", r["wall_s"] * 1e6 / r["tokens"],
             f"tok/s={r['tok_per_s']:.1f} tok/step={r['tok_per_step']:.2f} "
             f"occupancy={r['occupancy']:.2f} steps={r['steps']} "
             f"prefills={r['prefills']}{extra}")
    return rows


def main(timer: Timer):
    from benchmarks import common

    n_requests = 24 if common.SMOKE else 48
    cfg, ds, params = trained_lm()
    pack = serve_pack(cfg, params, ds)
    reqs = request_trace(n_requests, cfg.vocab)

    rows = {}
    for mode, gang in (("continuous", False), ("static", True)):
        r = rows[mode] = bench_mode(cfg, params, pack, reqs, gang=gang)
        emit(f"servebench_{mode}", r["wall_s"] * 1e6 / r["tokens"],
             f"tok/s={r['tok_per_s']:.1f} ttft_ms={r['ttft_ms']:.1f} "
             f"occupancy={r['occupancy']:.2f} steps={r['steps']} "
             f"prefills={r['prefills']}")

    speedup = rows["continuous"]["tok_per_s"] / rows["static"]["tok_per_s"]
    step_ratio = ((rows["static"]["steps"] + rows["static"]["prefills"])
                  / (rows["continuous"]["steps"]
                     + rows["continuous"]["prefills"]))
    emit("servebench_claim_continuous_speedup", 0.0,
         f"tok/s ratio={speedup:.2f} step ratio={step_ratio:.2f} "
         f"(>=1.5 required): {speedup >= 1.5}")

    # agreement gate: the runtime must say exactly what decode_lm says,
    # token for token, at the same analog config (few distinct shapes to
    # bound eager decode_lm reference cost)
    agree_reqs = [(reqs[i][0][:6], 5) for i in range(0, 6)] \
        + [(reqs[6][0][:12], 8)]
    agreement = runtime_agreement(cfg, params, agree_reqs, pack=pack,
                                  max_slots=MAX_SLOTS, max_len=MAX_LEN,
                                  buckets=BUCKETS)
    emit("servebench_agreement", 0.0,
         f"runtime-vs-decode_lm greedy agreement={agreement:.4f}")

    # paged KV + prefix sharing vs dense slots at equal KV token budget
    # on a shared-prefix heavy-tailed trace
    sreqs = shared_prefix_trace(n_requests, cfg.vocab)
    prows = bench_paged_pair(cfg, params, pack, sreqs)
    step_gain = (prows["paged_kv"]["tok_per_step"]
                 / prows["dense_kv"]["tok_per_step"])
    tokps_gain = (prows["paged_kv"]["tok_per_s"]
                  / prows["dense_kv"]["tok_per_s"])
    paged_gain = max(step_gain, tokps_gain)
    emit("servebench_claim_paged_gain", 0.0,
         f"tok/step ratio={step_gain:.2f} tok/s ratio={tokps_gain:.2f} "
         f"(>=1.3 required): {paged_gain >= 1.3}")

    # paged-vs-dense bit-exactness at the served analog config, greedy
    # AND seeded sampling, on the mixed servebench trace
    agree_paged = [(p[:12], min(n, 8)) for p, n in reqs[:8]]
    pg_greedy = paged_runtime_agreement(
        cfg, params, agree_paged, pack=pack, max_slots=4,
        page_size=PAGE_SIZE)
    pg_seeded = paged_runtime_agreement(
        cfg, params, agree_paged, pack=pack, max_slots=4,
        page_size=PAGE_SIZE,
        sampler=SamplerConfig(kind="top_k", temperature=0.8, top_k=16),
        seed=11)
    emit("servebench_paged_agreement", 0.0,
         f"paged-vs-dense agreement greedy={pg_greedy:.4f} "
         f"seeded={pg_seeded:.4f}")

    if pg_greedy != 1.0 or pg_seeded != 1.0:
        raise RuntimeError(
            f"paged runtime diverged from the dense-slot oracle: "
            f"greedy {pg_greedy} / seeded {pg_seeded} != 1.0")
    if paged_gain < 1.3:
        raise RuntimeError(
            f"paged KV gain {paged_gain:.2f}x < 1.3x over dense slots at "
            f"equal KV budget (tok/step {step_gain:.2f}x, "
            f"tok/s {tokps_gain:.2f}x)")
    if agreement != 1.0:
        raise RuntimeError(
            f"continuous-batching runtime diverged from decode_lm: "
            f"agreement {agreement} != 1.0")
    if speedup < 1.5:
        raise RuntimeError(
            f"continuous batching speedup {speedup:.2f}x < 1.5x over "
            f"static batching (step ratio {step_ratio:.2f})")
