"""Continuous vs static batching of the analog LM (`repro.serve.runtime`).

The serving-system benchmark: the trained smoke LM is programmed and
calibrated once (Design A + state-proportional cell error — a valid
sweep design point, served), then a mixed-length request trace is
drained twice through the same jitted slot machinery:

  * **continuous** — iteration-level scheduling: slots refill the moment
    a request retires (``ServeRuntime``);
  * **static** — gang scheduling: admit a full batch, pad every prompt
    to one bucket, drain until the *longest* request finishes
    (``ServeRuntime(gang=True)``) — classic static batching.

Reported per mode: tokens/s, mean time-to-first-token, slot occupancy,
decode-step/prefill-call counts.  Two claims are *gated* (the benchmark
raises, and ``benchmarks.run`` exits nonzero, when they fail):

  * continuous-batching throughput >= 1.5x static on the mixed trace at
    equal analog config;
  * runtime-vs-``decode_lm`` greedy token agreement == 1.0 — scheduling
    must never change what the model says
    (``repro.sweep.serve_eval.runtime_agreement``);
  * fused decode chain token agreement == 1.0 (kernel-vs-oracle under
    flash attention, fused-vs-composed greedy and seeded;
    ``repro.sweep.serve_eval.fused_runtime_agreement``) and >= 1.3x over
    the composed chain at steady-state full-occupancy decode, measured
    at serving-scale width (``SCALE_D_MODEL``) where the analog MVM
    chain dominates the step; the d_model=64 smoke LM Amdahl-dilutes
    the chain to ~1.3x, so its ratio is emitted as an informative row
    rather than gated.

Both modes pay identical per-step costs (same compiled decode/prefill
programs), so the speedup isolates the *scheduling* difference: static
batches burn ``max(max_new)`` steps per gang while continuous burns
``~sum(max_new)/max_slots``.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import analog as A
from repro.core import errors as E
from repro.serve import PagedServeRuntime, ServeRuntime, calibrate_lm, program_lm
from repro.serve.runtime import SamplerConfig
from repro.sweep.serve_eval import (
    fused_runtime_agreement, pack_with_fused, paged_runtime_agreement,
    runtime_agreement)

from benchmarks.common import Timer, emit
from benchmarks.lm_accuracy import CALIB_STEP, trained_lm

MAX_SLOTS = 8
MAX_LEN = 80
BUCKETS = (8, 16)
#: long-tail generation budget — the static scheduler pads every gang to it
TAIL_NEW = 64

#: serving-scale width for the fused decode-step gate: at the smoke
#: LM's d_model=64 the analog MVMs are a minority of the decode step
#: (attention + sampling + slot bookkeeping dominate), so the fused
#: ratio there sits at ~1.3x and inside container noise; at d_model=256
#: the chain dominates and the ratio is ~3x with real margin.
SCALE_D_MODEL = 256
SCALE_D_FF = 384

# paged-vs-dense comparison: equal KV *token* budget.  Dense KV capacity
# is MAX_SLOTS * MAX_LEN = 640 token slots; the paged pool gets exactly
# the same 640 tokens (80 data pages of 8) plus the reserved sink page,
# but may spread them over twice the decode lanes because slots no
# longer pre-own max_len tokens each.
PAGE_SIZE = 8
PAGED_SLOTS = 16
PAGED_PAGES = MAX_SLOTS * MAX_LEN // PAGE_SIZE + 1
#: shared system-prompt length for the prefix-heavy trace (3 full pages)
PREFIX_LEN = 24
#: generation budgets on the prefix trace: moderate and uniform, so the
#: drain is lane-capacity-bound (what paging pools) rather than
#: serialized behind one long straggler whose budget alone sets the
#: step count for both runtimes
PREFIX_NEW_LO, PREFIX_NEW_HI = 6, 15
PREFIX_BUCKETS = (8, 32)


def request_trace(n: int, vocab: int, seed: int = 0):
    """A mixed-length offline trace: prompts 3..14 tokens, generation
    budgets heavy-tailed (one TAIL_NEW-token request per MAX_SLOTS
    arrivals, the rest 2..6) — the regime where gang scheduling burns
    ``max(max_new)`` decode steps per batch while continuous batching
    burns ``~sum(max_new) / max_slots``."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 15))
        n_new = TAIL_NEW if i % MAX_SLOTS == 0 else int(rng.integers(2, 7))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append((prompt, n_new))
    return reqs


def shared_prefix_trace(n: int, vocab: int, seed: int = 3):
    """A prefix-heavy trace: every prompt opens with the same
    PREFIX_LEN-token system prompt (3 full pages — radix-cache fodder)
    followed by a unique 2..6-token tail, and carries a uniform
    moderate PREFIX_NEW_LO..PREFIX_NEW_HI generation budget — enough
    decode work that the drain measures how many lanes the KV budget
    sustains, staggered retirements keeping admission continuous."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, vocab, size=PREFIX_LEN).astype(np.int32)
    reqs = []
    for i in range(n):
        tail = rng.integers(0, vocab,
                            size=int(rng.integers(2, 7))).astype(np.int32)
        n_new = int(rng.integers(PREFIX_NEW_LO, PREFIX_NEW_HI))
        reqs.append((np.concatenate([prefix, tail]), n_new))
    return reqs


def serve_pack(cfg, params, ds):
    """Program + calibrate the benchmark's analog design point."""
    spec = A.design_a(error=E.state_proportional(0.02))
    pack = program_lm(cfg, params, spec, jax.random.PRNGKey(7))
    return calibrate_lm(cfg, params, pack, ds.batch(CALIB_STEP)["tokens"])


def drain(rt: ServeRuntime, reqs) -> dict:
    """Submit the whole trace, drain it, and return timing + stats."""
    for i, (prompt, n_new) in enumerate(reqs):
        rt.submit(prompt, max_new_tokens=n_new, uid=i)
    t0 = time.perf_counter()
    outs = rt.run()
    wall = time.perf_counter() - t0
    s = rt.stats
    assert len(outs) == len(reqs)
    return {
        "wall_s": wall,
        "tokens": s["tokens_out"],
        "tok_per_s": s["tokens_out"] / wall,
        "ttft_ms": 1e3 * float(np.mean(s["ttft_s"])),
        "occupancy": s["occupancy"],
        "steps": s["decode_steps"],
        "prefills": s["prefill_calls"],
    }


def bench_mode(cfg, params, pack, reqs, *, gang: bool) -> dict:
    """Throughput and TTFT as separate passes: the TTFT pass blocks on
    each prefill's results (true submit->first-token wall time), which
    defeats dispatch pipelining — so tokens/s comes from a non-blocking
    pass over the same schedule."""
    rt = ServeRuntime(cfg, params, pack=pack, max_slots=MAX_SLOTS,
                      max_len=MAX_LEN, buckets=BUCKETS, gang=gang)
    drain(rt, reqs)                      # warm: compile every (bucket, G)
    runs = []
    for _ in range(2):                   # timed: best of 2 damps CI noise
        rt.reset()
        runs.append(drain(rt, reqs))
    r = min(runs, key=lambda x: x["wall_s"])
    rt.reset()
    rt.measure_ttft = True               # latency pass, same compiled fns
    r["ttft_ms"] = drain(rt, reqs)["ttft_ms"]
    return r


def bench_paged_pair(cfg, params, pack, reqs):
    """Dense (8 slots x 80) vs paged (16 lanes, same 640-token pool) on
    the shared-prefix trace; same warm + best-of-2 protocol as
    ``bench_mode``."""
    rows = {}
    makers = {
        "dense_kv": lambda: ServeRuntime(
            cfg, params, pack=pack, max_slots=MAX_SLOTS, max_len=MAX_LEN,
            buckets=PREFIX_BUCKETS),
        "paged_kv": lambda: PagedServeRuntime(
            cfg, params, pack=pack, max_slots=PAGED_SLOTS, max_len=MAX_LEN,
            buckets=PREFIX_BUCKETS, page_size=PAGE_SIZE,
            num_pages=PAGED_PAGES),
    }
    for mode, make_rt in makers.items():
        rt = make_rt()
        drain(rt, reqs)                  # warm: compile every group once
        runs = []
        for _ in range(2):
            rt.reset()
            runs.append(drain(rt, reqs))
        r = rows[mode] = min(runs, key=lambda x: x["wall_s"])
        r["tok_per_step"] = r["tokens"] / max(r["steps"], 1)
        extra = ""
        if isinstance(rt, PagedServeRuntime):
            rt.check()                   # pool/radix invariants post-drain
            s = rt.stats
            extra = (f" prefix_hits={s['prefix_hits']} "
                     f"reused_toks={s['prefix_tokens_reused']} "
                     f"evictions={s['cache_evictions']} "
                     f"stalls={s['admission_stalls']}")
        emit(f"servebench_{mode}", r["wall_s"] * 1e6 / r["tokens"],
             f"tok/s={r['tok_per_s']:.1f} tok/step={r['tok_per_step']:.2f} "
             f"occupancy={r['occupancy']:.2f} steps={r['steps']} "
             f"prefills={r['prefills']}{extra}")
    return rows


def decode_timer(cfg, params, pk, *, reps: int = 30):
    """Bring a runtime to steady-state full occupancy (every slot live
    on a long generation budget, admission queue empty) and return a
    closure that times the raw jitted decode step — ``rt._decode_fn``
    on frozen state, mean of ``reps`` calls with a sync at the end
    (``step()`` dispatches asynchronously; timing it unsynced measures
    enqueueing, not execution)."""
    rng = np.random.default_rng(5)
    rt = ServeRuntime(cfg, params, pack=pk, max_slots=MAX_SLOTS,
                      max_len=MAX_LEN, buckets=BUCKETS)
    for i in range(MAX_SLOTS):
        prompt = rng.integers(0, cfg.vocab, size=6).astype(np.int32)
        rt.submit(prompt, max_new_tokens=MAX_LEN - 8, uid=i)
    for _ in range(4):                   # admit + warm
        rt.step()
    state, fn, pk2 = rt._state, rt._decode_fn, rt.pack
    jax.block_until_ready(fn(state, pk2).tok)

    def timed() -> float:
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(state, pk2)
        jax.block_until_ready(out.tok)
        return (time.perf_counter() - t0) / reps * 1e6

    return timed


def fused_decode_ratio(cfg, params, pk, *, rounds: int = 5):
    """(composed_us, fused_us) for the steady-state decode step: both
    arms timed in interleaved rounds so slow host phases hit them
    equally, min over rounds per arm (timing noise is one-sided)."""
    tc = decode_timer(cfg, params, pk)
    tf = decode_timer(cfg, params, pack_with_fused(pk, "oracle"))
    cs, fs = [], []
    for _ in range(rounds):
        cs.append(tc())
        fs.append(tf())
    return min(cs), min(fs)


def serving_scale_pack():
    """The smoke-LM architecture widened to serving-scale MVM shapes,
    programmed at the same analog design point.  Weights are random
    init — the fused-vs-composed decode gate is throughput-only (token
    agreement is gated on the trained LM above)."""
    import dataclasses

    from repro.configs import get_smoke_config
    from repro.models.registry import get_model

    cfg = dataclasses.replace(get_smoke_config("qwen1.5-4b"),
                              d_model=SCALE_D_MODEL, d_ff=SCALE_D_FF)
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    spec = A.design_a(error=E.state_proportional(0.02))
    pack = program_lm(cfg, params, spec, jax.random.PRNGKey(7))
    rng = np.random.default_rng(0)
    calib = jax.numpy.asarray(rng.integers(0, cfg.vocab, size=(4, 16)))
    return cfg, params, calibrate_lm(cfg, params, pack, calib)


def main(timer: Timer):
    from benchmarks import common

    n_requests = 24 if common.SMOKE else 48
    cfg, ds, params = trained_lm()
    pack = serve_pack(cfg, params, ds)
    reqs = request_trace(n_requests, cfg.vocab)

    rows = {}
    for mode, gang in (("continuous", False), ("static", True)):
        r = rows[mode] = bench_mode(cfg, params, pack, reqs, gang=gang)
        emit(f"servebench_{mode}", r["wall_s"] * 1e6 / r["tokens"],
             f"tok/s={r['tok_per_s']:.1f} ttft_ms={r['ttft_ms']:.1f} "
             f"occupancy={r['occupancy']:.2f} steps={r['steps']} "
             f"prefills={r['prefills']}")

    speedup = rows["continuous"]["tok_per_s"] / rows["static"]["tok_per_s"]
    step_ratio = ((rows["static"]["steps"] + rows["static"]["prefills"])
                  / (rows["continuous"]["steps"]
                     + rows["continuous"]["prefills"]))
    emit("servebench_claim_continuous_speedup", 0.0,
         f"tok/s ratio={speedup:.2f} step ratio={step_ratio:.2f} "
         f"(>=1.5 required): {speedup >= 1.5}")

    # agreement gate: the runtime must say exactly what decode_lm says,
    # token for token, at the same analog config (few distinct shapes to
    # bound eager decode_lm reference cost)
    agree_reqs = [(reqs[i][0][:6], 5) for i in range(0, 6)] \
        + [(reqs[6][0][:12], 8)]
    agreement = runtime_agreement(cfg, params, agree_reqs, pack=pack,
                                  max_slots=MAX_SLOTS, max_len=MAX_LEN,
                                  buckets=BUCKETS)
    emit("servebench_agreement", 0.0,
         f"runtime-vs-decode_lm greedy agreement={agreement:.4f}")

    # paged KV + prefix sharing vs dense slots at equal KV token budget
    # on a shared-prefix heavy-tailed trace
    sreqs = shared_prefix_trace(n_requests, cfg.vocab)
    prows = bench_paged_pair(cfg, params, pack, sreqs)
    step_gain = (prows["paged_kv"]["tok_per_step"]
                 / prows["dense_kv"]["tok_per_step"])
    tokps_gain = (prows["paged_kv"]["tok_per_s"]
                  / prows["dense_kv"]["tok_per_s"])
    paged_gain = max(step_gain, tokps_gain)
    emit("servebench_claim_paged_gain", 0.0,
         f"tok/step ratio={step_gain:.2f} tok/s ratio={tokps_gain:.2f} "
         f"(>=1.3 required): {paged_gain >= 1.3}")

    # paged-vs-dense bit-exactness at the served analog config, greedy
    # AND seeded sampling, on the mixed servebench trace
    agree_paged = [(p[:12], min(n, 8)) for p, n in reqs[:8]]
    pg_greedy = paged_runtime_agreement(
        cfg, params, agree_paged, pack=pack, max_slots=4,
        page_size=PAGE_SIZE)
    pg_seeded = paged_runtime_agreement(
        cfg, params, agree_paged, pack=pack, max_slots=4,
        page_size=PAGE_SIZE,
        sampler=SamplerConfig(kind="top_k", temperature=0.8, top_k=16),
        seed=11)
    emit("servebench_paged_agreement", 0.0,
         f"paged-vs-dense agreement greedy={pg_greedy:.4f} "
         f"seeded={pg_seeded:.4f}")

    # fused decode chain: the single-launch analog kernels (+ flash
    # attention) must say exactly what the composed chain says, token
    # for token — kernel-vs-oracle under flash, fused-vs-composed
    # greedy AND seeded — and must buy decode throughput on the same
    # heavy-tailed trace through the same scheduler.
    agree_fused = [(p[:12], min(n, 8)) for p, n in reqs[:8]]
    fz_kernel = fused_runtime_agreement(
        cfg, params, agree_fused, pack=pack, max_slots=4, max_len=MAX_LEN)
    fz_composed = fused_runtime_agreement(
        cfg, params, agree_fused, pack=pack, max_slots=4, max_len=MAX_LEN,
        modes=("kernel", "off"), attn=("stream", "stream"))
    fz_seeded = fused_runtime_agreement(
        cfg, params, agree_fused, pack=pack, max_slots=4, max_len=MAX_LEN,
        modes=("kernel", "off"), attn=("stream", "stream"),
        sampler=SamplerConfig(kind="top_k", temperature=0.8, top_k=16),
        seed=11)
    emit("servebench_fused_agreement", 0.0,
         f"kernel-vs-oracle(flash)={fz_kernel:.4f} "
         f"fused-vs-composed greedy={fz_composed:.4f} "
         f"seeded={fz_seeded:.4f}")

    # throughput: every analog site fused, timed through the jnp
    # lowering (the Pallas kernel is parity- and agreement-gated above;
    # interpret-mode wall-clock measures the emulator, not the launch
    # structure) vs the composed chain in the same runtime.  The smoke
    # LM row is informative (its 64-wide MVMs are a minority of the
    # step); the gate runs at serving-scale width where the chain
    # dominates.
    us_c, us_f = fused_decode_ratio(cfg, params, pack)
    emit("servebench_fused_decode_step", us_f,
         f"composed_us={us_c:.1f} ratio={us_c / us_f:.2f}x "
         f"slots={MAX_SLOTS} d_model={cfg.d_model} (informative)")
    scfg, sparams, spack = serving_scale_pack()
    sus_c, sus_f = fused_decode_ratio(scfg, sparams, spack)
    fused_gain = sus_c / sus_f
    emit("servebench_fused_decode_scale", sus_f,
         f"composed_us={sus_c:.1f} decode_tok/s="
         f"{MAX_SLOTS / sus_f * 1e6:.0f} vs {MAX_SLOTS / sus_c * 1e6:.0f} "
         f"slots={MAX_SLOTS} d_model={scfg.d_model}")
    emit("servebench_claim_fused_speedup", 0.0,
         f"fused/composed decode-step ratio={fused_gain:.2f} at "
         f"d_model={scfg.d_model} (>=1.3 required): {fused_gain >= 1.3}")

    if pg_greedy != 1.0 or pg_seeded != 1.0:
        raise RuntimeError(
            f"paged runtime diverged from the dense-slot oracle: "
            f"greedy {pg_greedy} / seeded {pg_seeded} != 1.0")
    if paged_gain < 1.3:
        raise RuntimeError(
            f"paged KV gain {paged_gain:.2f}x < 1.3x over dense slots at "
            f"equal KV budget (tok/step {step_gain:.2f}x, "
            f"tok/s {tokps_gain:.2f}x)")
    if agreement != 1.0:
        raise RuntimeError(
            f"continuous-batching runtime diverged from decode_lm: "
            f"agreement {agreement} != 1.0")
    if speedup < 1.5:
        raise RuntimeError(
            f"continuous batching speedup {speedup:.2f}x < 1.5x over "
            f"static batching (step ratio {step_ratio:.2f})")
    if fz_kernel != 1.0 or fz_composed != 1.0 or fz_seeded != 1.0:
        raise RuntimeError(
            f"fused serving runtime diverged: kernel-vs-oracle "
            f"{fz_kernel} / fused-vs-composed greedy {fz_composed} / "
            f"seeded {fz_seeded} != 1.0")
    if fused_gain < 1.3:
        raise RuntimeError(
            f"fused decode chain {fused_gain:.2f}x < 1.3x over the "
            f"composed chain at steady-state full-occupancy decode, "
            f"d_model={scfg.d_model} ({sus_f:.1f}us vs {sus_c:.1f}us "
            f"per step)")
