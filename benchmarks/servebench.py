"""Continuous vs static batching of the analog LM (`repro.serve.runtime`).

The serving-system benchmark: the trained smoke LM is programmed and
calibrated once (Design A + state-proportional cell error — a valid
sweep design point, served), then a mixed-length request trace is
drained twice through the same jitted slot machinery:

  * **continuous** — iteration-level scheduling: slots refill the moment
    a request retires (``ServeRuntime``);
  * **static** — gang scheduling: admit a full batch, pad every prompt
    to one bucket, drain until the *longest* request finishes
    (``ServeRuntime(gang=True)``) — classic static batching.

Reported per mode: tokens/s, mean time-to-first-token, slot occupancy,
decode-step/prefill-call counts.  Two claims are *gated* (the benchmark
raises, and ``benchmarks.run`` exits nonzero, when they fail):

  * continuous-batching throughput >= 1.5x static on the mixed trace at
    equal analog config;
  * runtime-vs-``decode_lm`` greedy token agreement == 1.0 — scheduling
    must never change what the model says
    (``repro.sweep.serve_eval.runtime_agreement``).

Both modes pay identical per-step costs (same compiled decode/prefill
programs), so the speedup isolates the *scheduling* difference: static
batches burn ``max(max_new)`` steps per gang while continuous burns
``~sum(max_new)/max_slots``.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.core import analog as A
from repro.core import errors as E
from repro.serve import ServeRuntime, calibrate_lm, program_lm
from repro.sweep.serve_eval import runtime_agreement

from benchmarks.common import Timer, emit
from benchmarks.lm_accuracy import CALIB_STEP, trained_lm

MAX_SLOTS = 8
MAX_LEN = 80
BUCKETS = (8, 16)
#: long-tail generation budget — the static scheduler pads every gang to it
TAIL_NEW = 64


def request_trace(n: int, vocab: int, seed: int = 0):
    """A mixed-length offline trace: prompts 3..14 tokens, generation
    budgets heavy-tailed (one TAIL_NEW-token request per MAX_SLOTS
    arrivals, the rest 2..6) — the regime where gang scheduling burns
    ``max(max_new)`` decode steps per batch while continuous batching
    burns ``~sum(max_new) / max_slots``."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(3, 15))
        n_new = TAIL_NEW if i % MAX_SLOTS == 0 else int(rng.integers(2, 7))
        prompt = rng.integers(0, vocab, size=plen).astype(np.int32)
        reqs.append((prompt, n_new))
    return reqs


def serve_pack(cfg, params, ds):
    """Program + calibrate the benchmark's analog design point."""
    spec = A.design_a(error=E.state_proportional(0.02))
    pack = program_lm(cfg, params, spec, jax.random.PRNGKey(7))
    return calibrate_lm(cfg, params, pack, ds.batch(CALIB_STEP)["tokens"])


def drain(rt: ServeRuntime, reqs) -> dict:
    """Submit the whole trace, drain it, and return timing + stats."""
    for i, (prompt, n_new) in enumerate(reqs):
        rt.submit(prompt, max_new_tokens=n_new, uid=i)
    t0 = time.perf_counter()
    outs = rt.run()
    wall = time.perf_counter() - t0
    s = rt.stats
    assert len(outs) == len(reqs)
    return {
        "wall_s": wall,
        "tokens": s["tokens_out"],
        "tok_per_s": s["tokens_out"] / wall,
        "ttft_ms": 1e3 * float(np.mean(s["ttft_s"])),
        "occupancy": s["occupancy"],
        "steps": s["decode_steps"],
        "prefills": s["prefill_calls"],
    }


def bench_mode(cfg, params, pack, reqs, *, gang: bool) -> dict:
    """Throughput and TTFT as separate passes: the TTFT pass blocks on
    each prefill's results (true submit->first-token wall time), which
    defeats dispatch pipelining — so tokens/s comes from a non-blocking
    pass over the same schedule."""
    rt = ServeRuntime(cfg, params, pack=pack, max_slots=MAX_SLOTS,
                      max_len=MAX_LEN, buckets=BUCKETS, gang=gang)
    drain(rt, reqs)                      # warm: compile every (bucket, G)
    runs = []
    for _ in range(2):                   # timed: best of 2 damps CI noise
        rt.reset()
        runs.append(drain(rt, reqs))
    r = min(runs, key=lambda x: x["wall_s"])
    rt.reset()
    rt.measure_ttft = True               # latency pass, same compiled fns
    r["ttft_ms"] = drain(rt, reqs)["ttft_ms"]
    return r


def main(timer: Timer):
    from benchmarks import common

    n_requests = 24 if common.SMOKE else 48
    cfg, ds, params = trained_lm()
    pack = serve_pack(cfg, params, ds)
    reqs = request_trace(n_requests, cfg.vocab)

    rows = {}
    for mode, gang in (("continuous", False), ("static", True)):
        r = rows[mode] = bench_mode(cfg, params, pack, reqs, gang=gang)
        emit(f"servebench_{mode}", r["wall_s"] * 1e6 / r["tokens"],
             f"tok/s={r['tok_per_s']:.1f} ttft_ms={r['ttft_ms']:.1f} "
             f"occupancy={r['occupancy']:.2f} steps={r['steps']} "
             f"prefills={r['prefills']}")

    speedup = rows["continuous"]["tok_per_s"] / rows["static"]["tok_per_s"]
    step_ratio = ((rows["static"]["steps"] + rows["static"]["prefills"])
                  / (rows["continuous"]["steps"]
                     + rows["continuous"]["prefills"]))
    emit("servebench_claim_continuous_speedup", 0.0,
         f"tok/s ratio={speedup:.2f} step ratio={step_ratio:.2f} "
         f"(>=1.5 required): {speedup >= 1.5}")

    # agreement gate: the runtime must say exactly what decode_lm says,
    # token for token, at the same analog config (few distinct shapes to
    # bound eager decode_lm reference cost)
    agree_reqs = [(reqs[i][0][:6], 5) for i in range(0, 6)] \
        + [(reqs[6][0][:12], 8)]
    agreement = runtime_agreement(cfg, params, agree_reqs, pack=pack,
                                  max_slots=MAX_SLOTS, max_len=MAX_LEN,
                                  buckets=BUCKETS)
    emit("servebench_agreement", 0.0,
         f"runtime-vs-decode_lm greedy agreement={agreement:.4f}")

    if agreement != 1.0:
        raise RuntimeError(
            f"continuous-batching runtime diverged from decode_lm: "
            f"agreement {agreement} != 1.0")
    if speedup < 1.5:
        raise RuntimeError(
            f"continuous batching speedup {speedup:.2f}x < 1.5x over "
            f"static batching (step ratio {step_ratio:.2f})")
