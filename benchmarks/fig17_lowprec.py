"""Fig. 17: coarse activation quantization suppresses cell-error
propagation.  The paper compares an 8-bit network against a 4-bit-trained
network; here the same trained classifier is deployed at 8-bit and 4-bit
weight/activation precision (PTQ) and swept over state-proportional error.

Claim validated: under the same cell error, the relative accuracy drop of
the 4-bit deployment is smaller — the coarse activation grid rounds away
accumulated analog error (even though its error-free accuracy is lower and
its average conductance is higher, both as the paper notes)."""

import dataclasses
import time

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig

from benchmarks.common import Timer, analog_accuracy, emit, train_mlp


def spec_bits(weight_bits, err_alpha):
    return AnalogSpec(
        mapping=MappingConfig(scheme="differential",
                              weight_bits=weight_bits),
        adc=ADCConfig(style="calibrated", bits=8),
        error=state_proportional(err_alpha),
        input_accum="analog", max_rows=1152,
        input_bits=weight_bits,
    )


def main(timer: Timer):
    params = train_mlp()
    base = {}
    for wb in (8, 4):
        t0 = time.perf_counter()
        m0, _ = analog_accuracy(params, spec_bits(wb, 0.0), trials=1)
        base[wb] = m0
        emit(f"fig17_{wb}bit_ideal", (time.perf_counter() - t0) * 1e6,
             f"acc={m0:.4f}")
    drops = {}
    for wb in (8, 4):
        for a in (0.1, 0.2):
            m, s = analog_accuracy(params, spec_bits(wb, a), trials=5)
            drops[(wb, a)] = base[wb] - m
            emit(f"fig17_{wb}bit_prop{a}", 0.0,
                 f"acc={m:.4f}+-{s:.4f} (rel drop={base[wb]-m:+.4f})")
    emit("fig17_claim_coarse_quant_suppresses", 0.0,
         f"drop@0.2: 4bit={drops[(4, 0.2)]:.4f} vs 8bit={drops[(8, 0.2)]:.4f} "
         f"(claim: 4bit <= 8bit)")
