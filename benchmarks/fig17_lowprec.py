"""Fig. 17: coarse activation quantization suppresses cell-error
propagation.  The paper compares an 8-bit network against a 4-bit-trained
network; here the same trained classifier is deployed at 8-bit and 4-bit
weight/activation precision (PTQ) and swept over state-proportional error.

Claim validated: under the same cell error, the relative accuracy drop of
the 4-bit deployment is smaller — the coarse activation grid rounds away
accumulated analog error (even though its error-free accuracy is lower and
its average conductance is higher, both as the paper notes).

Two SweepSpecs sharing a zipped precision axis: the ideal (error-free,
single-trial) baselines, and the error grid (precision x alpha, trials
vmapped, alphas batched as traced scalars within each precision's compile
group)."""

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig

from repro.sweep import Axis, SweepSpec

from benchmarks.common import (
    Timer, emit, emit_sweep, run_bench_sweep, trials_for)

ALPHAS = (0.1, 0.2)

BITS_AXIS = Axis(
    ("mapping.weight_bits", "input_bits"),
    ((8, 8), (4, 4)),
    labels=("8bit", "4bit"),
)

BASE = AnalogSpec(
    mapping=MappingConfig(scheme="differential"),
    adc=ADCConfig(style="calibrated", bits=8),
    input_accum="analog",
    max_rows=1152,
)


def main(timer: Timer):
    ideal = run_bench_sweep(SweepSpec(
        name="fig17_ideal",
        base=BASE,
        axes=(BITS_AXIS,),
        trials=1,
    ))
    base = {wb: ideal.mean(f"{wb}bit") for wb in (8, 4)}
    for wb in (8, 4):
        emit(f"fig17_{wb}bit_ideal", ideal[f"{wb}bit"].wall_s * 1e6,
             f"acc={base[wb]:.4f}")

    swept = run_bench_sweep(SweepSpec(
        name="fig17_prop",
        base=BASE,
        axes=(
            BITS_AXIS,
            Axis("error", tuple(state_proportional(a) for a in ALPHAS),
                 labels=tuple(f"prop{a}" for a in ALPHAS)),
        ),
        trials=trials_for(5),
    ))
    drops = {}
    for wb in (8, 4):
        for a in ALPHAS:
            r = swept[f"{wb}bit_prop{a}"]
            drops[(wb, a)] = base[wb] - r.mean
            emit(f"fig17_{wb}bit_prop{a}", r.wall_s * 1e6 / swept.sweep.trials,
                 f"acc={r.mean:.4f}+-{r.std:.4f} "
                 f"(rel drop={base[wb]-r.mean:+.4f})")
    emit("fig17_claim_coarse_quant_suppresses", 0.0,
         f"drop@0.2: 4bit={drops[(4, 0.2)]:.4f} vs 8bit={drops[(8, 0.2)]:.4f} "
         f"(claim: 4bit <= 8bit)")
