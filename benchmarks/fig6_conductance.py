"""Fig. 6: average cell conductance per bit slice for each mapping scheme,
on the trained classifier's weights.  The paper's headline: differential
mappings of zero-peaked trained weights sit orders of magnitude below the
~0.5*G_max of offset mappings.

Declared as a mapping-scheme x bits-per-cell grid over a
:class:`~repro.sweep.FunctionEvaluator` (a deterministic per-point
metric: no programming trials, no accuracy)."""

import jax.numpy as jnp

from repro.core.analog import AnalogSpec
from repro.core.mapping import average_conductance, program_weights
from repro.core.quant import quantize_weights
from repro.sweep import Axis, FunctionEvaluator, SweepSpec

from benchmarks.common import Timer, emit, run_bench_sweep, train_mlp


def main(timer: Timer):
    params = train_mlp()
    w = params[1][0]  # a representative trained hidden-layer matrix

    def avg_g(spec: AnalogSpec):
        mc = spec.mapping
        mag = None if mc.scheme == "offset" else mc.magnitude_bits
        qt = quantize_weights(w, 8, magnitude_bits=mag)
        return average_conductance(
            program_weights(qt.values.astype(jnp.int32), mc))

    sweep = SweepSpec(
        name="fig6",
        base=AnalogSpec(),
        axes=(
            Axis("mapping.scheme", ("offset", "differential"),
                 labels=("offset", "differential")),
            Axis("mapping.bits_per_cell", (None, 1, 2, 4),
                 labels=("bpcNone", "bpc1", "bpc2", "bpc4")),
        ),
        trials=0,
    )
    res = run_bench_sweep(
        sweep, FunctionEvaluator(avg_g, name="fig6_avg_conductance",
                                 data=(w,)))
    for r in res:
        slices = "/".join(f"{x:.4f}" for x in r.values[0])
        emit(f"fig6_{r.tag}", r.wall_s * 1e6, f"avg_g_per_slice={slices}")

    off_u = res["offset_bpcNone"].values[0][0]
    dif_u = res["differential_bpcNone"].values[0][0]
    emit("fig6_ratio_offset_vs_diff", 0.0,
         f"offset_avg={off_u:.4f} diff_avg={dif_u:.4f} "
         f"ratio={off_u / max(dif_u, 1e-9):.1f}x (paper: orders of magnitude)")
