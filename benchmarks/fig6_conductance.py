"""Fig. 6: average cell conductance per bit slice for each mapping scheme,
on the trained classifier's weights.  The paper's headline: differential
mappings of zero-peaked trained weights sit orders of magnitude below the
~0.5*G_max of offset mappings."""

import jax.numpy as jnp

from benchmarks.common import Timer, emit, train_mlp
from repro.core.mapping import MappingConfig, average_conductance, program_weights
from repro.core.quant import quantize_weights


def main(timer: Timer):
    params = train_mlp()
    w = params[1][0]  # a representative trained hidden-layer matrix

    rows = []
    for scheme in ("offset", "differential"):
        for bpc in (None, 1, 2, 4):
            mc = MappingConfig(scheme=scheme, bits_per_cell=bpc)
            mag = None if scheme == "offset" else mc.magnitude_bits
            qt = quantize_weights(w, 8, magnitude_bits=mag)

            def run():
                pw = program_weights(qt.values.astype(jnp.int32), mc)
                return average_conductance(pw)

            us = timer.time(run)
            g = run()
            slices = "/".join(f"{float(x):.4f}" for x in g)
            rows.append((scheme, bpc, g))
            emit(f"fig6_{scheme}_bpc{bpc}", us, f"avg_g_per_slice={slices}")

    off_u = float(rows[0][2][0])      # offset unsliced
    dif_u = float(rows[4][2][0])      # differential unsliced
    emit("fig6_ratio_offset_vs_diff", 0.0,
         f"offset_avg={off_u:.4f} diff_avg={dif_u:.4f} "
         f"ratio={off_u / max(dif_u, 1e-9):.1f}x (paper: orders of magnitude)")
