"""Table 4: accuracy of the five core designs with measured-SONOS
programming errors (saturating-exponential state-dependent model fit to
Fig. 20(b)), calibrated 8-bit ADCs.

Claims validated: differential/unsliced designs (A, C, D) lose only a
small amount of accuracy; the 1-bit-sliced design (B) is the most robust;
the offset design (E) loses by far the most.

An explicit-point SweepSpec over the named designs; each design is its
own compile group (distinct shapes), with its five programming trials
vmapped into one jitted evaluation."""

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import SONOS_ON_OFF, sonos
from repro.core.mapping import MappingConfig
from repro.sweep import SweepSpec

from benchmarks.common import (
    Timer, digital_accuracy, emit, run_bench_sweep, train_mlp, trials_for)

DESIGNS = [
    ("A", "differential", None, 1152, "analog"),
    ("B", "differential", 1, 1152, "analog"),
    ("C", "differential", None, 144, "analog"),
    ("D", "differential", None, 1152, "digital"),
    ("E", "offset", 2, 72, "digital"),
]


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)
    emit("table4_ideal_cells", 0.0, f"acc={base:.4f}")

    sweep = SweepSpec.from_points(
        "table4",
        [
            (name, AnalogSpec(
                mapping=MappingConfig(scheme=scheme, bits_per_cell=bpc,
                                      on_off_ratio=SONOS_ON_OFF),
                adc=ADCConfig(style="calibrated", bits=8),
                error=sonos(), input_accum=accum, max_rows=rows))
            for name, scheme, bpc, rows, accum in DESIGNS
        ],
        trials=trials_for(5),
    )
    res = run_bench_sweep(sweep)
    for r in res:
        emit(f"table4_design{r.tag}", r.wall_s * 1e6 / sweep.trials,
             f"acc={r.mean:.4f}+-{r.std:.4f} (drop={base - r.mean:+.4f})")
    accs = {name: res.mean(name) for name, *_ in DESIGNS}
    emit("table4_claim_ordering", 0.0,
         f"E worst: {accs['E']:.3f} < min(A,C,D)="
         f"{min(accs['A'], accs['C'], accs['D']):.3f}; "
         f"B best-or-equal: {accs['B']:.3f}")
