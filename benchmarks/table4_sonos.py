"""Table 4: accuracy of the five core designs with measured-SONOS
programming errors (saturating-exponential state-dependent model fit to
Fig. 20(b)), calibrated 8-bit ADCs.

Claims validated: differential/unsliced designs (A, C, D) lose only a
small amount of accuracy; the 1-bit-sliced design (B) is the most robust;
the offset design (E) loses by far the most.
"""

import time

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import SONOS_ON_OFF, sonos
from repro.core.mapping import MappingConfig

from benchmarks.common import Timer, analog_accuracy, digital_accuracy, emit, train_mlp

DESIGNS = [
    ("A", "differential", None, 1152, "analog"),
    ("B", "differential", 1, 1152, "analog"),
    ("C", "differential", None, 144, "analog"),
    ("D", "differential", None, 1152, "digital"),
    ("E", "offset", 2, 72, "digital"),
]


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)
    emit("table4_ideal_cells", 0.0, f"acc={base:.4f}")
    accs = {}
    for name, scheme, bpc, rows, accum in DESIGNS:
        spec = AnalogSpec(
            mapping=MappingConfig(scheme=scheme, bits_per_cell=bpc,
                                  on_off_ratio=SONOS_ON_OFF),
            adc=ADCConfig(style="calibrated", bits=8),
            error=sonos(), input_accum=accum, max_rows=rows)
        t0 = time.perf_counter()
        m, s = analog_accuracy(params, spec, trials=5)
        accs[name] = m
        emit(f"table4_design{name}", (time.perf_counter() - t0) * 1e6 / 5,
             f"acc={m:.4f}+-{s:.4f} (drop={base - m:+.4f})")
    emit("table4_claim_ordering", 0.0,
         f"E worst: {accs['E']:.3f} < min(A,C,D)="
         f"{min(accs['A'], accs['C'], accs['D']):.3f}; "
         f"B best-or-equal: {accs['B']:.3f}")
