"""Heterogeneous per-site precision: the Fig. 15/16 ADC-resolution story,
but *per layer class* of a served LM.

The paper's closing argument is that proportional mapping lets designers
"match the precision of the hardware to the needs of the algorithm".
With ``repro.hw.Profile`` that is finally expressible: this benchmark
sweeps attention-class ADC bits × MLP-class ADC bits (lm_head kept
digital — the ``digital`` fallback in action) over the trained smoke LM,
served end to end per design point (``program → calibrate → serve →
decode``, ``repro.sweep.ServeEvaluator``), and reports the cheapest
mixed-precision design whose loss matches the uniform 8-bit baseline.

Claims:

* **gated** — at least one mixed design with ≥1 fewer ADC bit on at
  least one layer class matches the uniform-8-bit loss within the
  ``tests/test_system.py`` tolerance (``loss < uniform * 1.35 + 0.2``);
  the benchmark raises (and ``benchmarks.run`` exits nonzero) otherwise.
* The mixed grid stays cheap to compile: every (attn bits, mlp bits)
  cell is one profile signature = one compile group, with the cell-error
  axis batched as a traced scalar inside it (pinned by
  ``tests/test_profile.py::test_hetero_grid_compile_groups``).

The per-class ADC energy rows use ``core.energy`` on each site's own
spec and array shape — fewer MLP ADC bits cut the dominant per-column
conversion energy on the widest matrices of the network.
"""

from __future__ import annotations

from repro.core import energy as en
from repro.core.analog import design_a
from repro.core.errors import state_proportional
from repro.hw import DIGITAL, Profile
from repro.sweep import Axis, SweepSpec
from repro.train.step import loss_fn

from benchmarks.common import Timer, emit, run_bench_sweep, trials_for
from benchmarks.lm_accuracy import EVAL_STEP, lm_evaluator, trained_lm

#: the paper's recommended Design A (differential, analog accumulation,
#: calibrated 8-bit ADC) under a realistic proportional cell error
BASE_SPEC = design_a(error=state_proportional(0.05))

ATTN_BITS = (6, 8)
MLP_BITS = (4, 6, 8)

#: the test_system tolerance formula, applied against the uniform
#: baseline instead of the digital model (matched-loss criterion)
MATCH = "loss < uniform * 1.35 + 0.2"


def matched(loss: float, uniform: float) -> bool:
    return loss < uniform * 1.35 + 0.2


def base_profile() -> Profile:
    """attn + mlp on BASE_SPEC arrays, lm_head kept digital."""
    return Profile.by_class(attn=BASE_SPEC, mlp=BASE_SPEC, head=DIGITAL)


def hetero_sweep(*, smoke: bool = False) -> SweepSpec:
    """The attention-ADC-bits × MLP-ADC-bits serving grid.

    ``smoke`` thins to attention fixed at 8 bits × mlp ∈ {6, 8} — still
    two distinct profile signatures (two compile groups) and a real
    mixed-vs-uniform comparison for the CI gate.
    """
    attn_bits = (8,) if smoke else ATTN_BITS
    mlp_bits = (6, 8) if smoke else MLP_BITS
    return SweepSpec(
        name="hetero_precision_smoke" if smoke else "hetero_precision",
        base=base_profile(),
        axes=(
            Axis("attn:adc.bits", attn_bits,
                 labels=tuple(f"attn{b}b" for b in attn_bits)),
            Axis("mlp:adc.bits", mlp_bits,
                 labels=tuple(f"mlp{b}b" for b in mlp_bits)),
        ),
        trials=trials_for(3),
        seed=1234,
    )


def _site_dims(cfg):
    """(k, n) per site class: the largest projection of each class."""
    d, h, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "attn": (d, max(h * hd, d)),        # wq / wo
        "mlp": (d, cfg.d_ff),               # w_gate / w_up
    }


def class_adc_energy(cfg, attn_bits: int, mlp_bits: int) -> dict:
    """Per-class ADC-conversion count and ADC energy for one MVM."""
    import dataclasses

    out = {}
    for cls, bits in (("attn", attn_bits), ("mlp", mlp_bits)):
        spec = dataclasses.replace(
            BASE_SPEC, adc=dataclasses.replace(BASE_SPEC.adc, bits=bits))
        k, n = _site_dims(cfg)[cls]
        out[cls] = {
            "conversions": spec.adc_conversions_per_mvm(k, n),
            "energy_pj": en.adc_energy(spec, k, n),
        }
    return out


def main(timer: Timer):
    from benchmarks import common

    cfg, ds, params = trained_lm()
    eval_batch = ds.batch(EVAL_STEP)
    dig = float(loss_fn(cfg, params, eval_batch)[0])
    emit("hetero_digital_baseline", 0.0, f"loss={dig:.4f}")

    sweep = hetero_sweep(smoke=common.SMOKE)
    res = run_bench_sweep(sweep, lm_evaluator())
    trials = max(sweep.trials, 1)
    for r in res:
        emit(f"hetero_{r.tag}", r.wall_s * 1e6 / trials,
             f"loss={r.metric_mean('loss'):.4f} "
             f"top1={r.metric_mean('top1'):.4f} "
             f"decode_match={r.metric_mean('decode_match'):.2f}")

    uniform_tag = "attn8b_mlp8b"
    uniform = res.metric(uniform_tag, "loss")

    # the cheapest matched mixed design: fewest total ADC bits, then loss
    best = None
    for p in sweep.expand():
        ab = int(p.coord("attn:adc.bits"))
        mb = int(p.coord("mlp:adc.bits"))
        if ab == 8 and mb == 8:
            continue
        loss = res.metric(p.tag, "loss")
        if matched(loss, uniform):
            cand = (ab + mb, loss, p.tag, ab, mb)
            if best is None or cand < best:
                best = cand
    if best is None:
        raise RuntimeError(
            f"no mixed-precision design matched the uniform 8-bit baseline "
            f"(uniform loss {uniform:.4f}, criterion {MATCH}); the "
            f"heterogeneous-profile claim failed")
    _, loss, tag, ab, mb = best
    emit("hetero_claim_mixed_matches_uniform", 0.0,
         f"{tag}: loss={loss:.4f} vs uniform={uniform:.4f} "
         f"({MATCH}) with {8 - ab} fewer attn / {8 - mb} fewer mlp ADC bits")

    e_mix = class_adc_energy(cfg, ab, mb)
    e_uni = class_adc_energy(cfg, 8, 8)
    for cls in ("attn", "mlp"):
        emit(f"hetero_adc_energy_{cls}", 0.0,
             f"mixed={e_mix[cls]['energy_pj']:.0f}pJ "
             f"uniform={e_uni[cls]['energy_pj']:.0f}pJ "
             f"conversions={e_mix[cls]['conversions']}")
