"""Table 3 / Fig. 22: core energy & area for the five named design points,
from the component model fit to the paper's published numbers.

Claims validated: Design E (ISAAC-like offset/near-FPG) costs ~100x the
energy and ~45x the area of Design A (differential, unsliced, analog input
accumulation); unsliced beats sliced; larger arrays amortize ADC cost;
analog input accumulation buys 2-4x.

The five designs are an explicit-point SweepSpec over a deterministic
FunctionEvaluator returning the named energy/area metrics per point."""

from repro.core import energy as en
from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.mapping import MappingConfig
from repro.sweep import FunctionEvaluator, SweepSpec

from benchmarks.common import Timer, emit, run_bench_sweep

# (name, scheme, bpc, rows, accum, g_avg, paper_fj_op, paper_area_mm2)
DESIGNS = [
    ("A", "differential", None, 1152, "analog", 0.02, 8.4, 0.24),
    ("B", "differential", 1, 1152, "analog", 0.08, 63.1, 2.02),
    ("C", "differential", None, 144, "analog", 0.02, 43.3, 1.30),
    ("D", "differential", None, 1152, "digital", 0.02, 25.8, 0.27),
    ("E", "offset", 2, 72, "digital", 0.5, 902.0, 11.14),
]


def spec_of(scheme, bpc, rows, accum):
    return AnalogSpec(
        mapping=MappingConfig(scheme=scheme, bits_per_cell=bpc),
        adc=ADCConfig(style="calibrated", bits=8),
        input_accum=accum, max_rows=rows)


def _design_key(spec: AnalogSpec):
    """The fields that identify a Table 3 design (robust to repr changes)."""
    return (spec.mapping.scheme, spec.mapping.bits_per_cell,
            spec.max_rows, spec.input_accum)


def main(timer: Timer):
    g_avg_of = {_design_key(spec_of(s, b, r, a)): g
                for _, s, b, r, a, g, _, _ in DESIGNS}
    assert len(g_avg_of) == len(DESIGNS), "designs must be distinguishable"

    def core_metrics(spec: AnalogSpec):
        g_avg = g_avg_of[_design_key(spec)]
        costs = en.core_costs(spec, 1152, 256, g_avg=g_avg)
        bd = en.energy_breakdown(spec, 1152, 256, g_avg=g_avg)
        return {
            "energy_fj_per_op": costs.energy_fj_per_op,
            "area_mm2": costs.area_mm2,
            "adc_conversions": costs.adc_conversions,
            "n_arrays": costs.n_arrays,
            "breakdown_nj": {k: v / 1e3 for k, v in bd.items()},
        }

    sweep = SweepSpec.from_points(
        "table3",
        [(name, spec_of(s, b, r, a)) for name, s, b, r, a, _, _, _ in DESIGNS],
        trials=0,
    )
    res = run_bench_sweep(
        sweep, FunctionEvaluator(core_metrics, name="table3_core_costs",
                                 data=(DESIGNS,)))

    vals = {}
    for (name, *_), p_e, p_a in [(d[:6], d[6], d[7]) for d in DESIGNS]:
        m = res[name].values[0]
        vals[name] = m
        emit(
            f"table3_design{name}", 0.0,
            f"model={m['energy_fj_per_op']:.1f}fJ/op (paper {p_e}) "
            f"area={m['area_mm2']:.2f}mm2 (paper {p_a}) "
            f"adc_conv={m['adc_conversions']} arrays={m['n_arrays']}",
        )
        emit(
            f"fig22b_breakdown_{name}", 0.0,
            " ".join(f"{k}={v:.1f}nJ" for k, v in m["breakdown_nj"].items()),
        )
    ra = vals["E"]["energy_fj_per_op"] / vals["A"]["energy_fj_per_op"]
    rarea = vals["E"]["area_mm2"] / vals["A"]["area_mm2"]
    emit("table3_claim_E_vs_A", 0.0,
         f"energy_ratio={ra:.0f}x (paper 107x) area_ratio={rarea:.0f}x "
         f"(paper 46x)")
    emit("table3_claim_analog_accum", 0.0,
         f"D/A={vals['D']['energy_fj_per_op']/vals['A']['energy_fj_per_op']:.1f}x "
         f"(paper ~3x: analog input accumulation wins)")
    fpg_bits_a = spec_of("differential", None, 1152, "analog").fpg_adc_bits(1152)
    emit("table3_Bout_designA", 0.0,
         f"B_out={fpg_bits_a} bits (paper 26.2) vs 8b ADC used")
