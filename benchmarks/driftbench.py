"""Self-healing analog serving under conductance drift + stuck-cell faults.

The paper evaluates accelerators at programming time; a deployed chip
keeps aging afterwards — conductances decay by the retention power law
``g(t) = g0 * (t/t0)^-nu`` (per-cell lognormal exponents) and cells fail
as a Poisson process pinned at G_min/G_max (related work: Rasch et al.,
arXiv:2302.08469; Wan et al., arXiv:2008.02400).  This benchmark measures
both halves of that story on the trained smoke LM:

1. **Degradation surface** — a ``ServeEvaluator`` sweep over drift
   exponent ``drift.nu`` × device age (``drift.t``/``fault.t`` zipped):
   program → calibrate → serve per design point.  Kind is static,
   horizon and magnitude are traced (``AnalogSpec.aging_on``), so the
   whole age grid is one compile group per shape — the same
   static-vs-traced split that collapses the Fig. 19 parasitic axis.
2. **Healing** — the same mixed trace served twice through
   ``ServeRuntime`` with a ``PackManager`` + ``DriftClock`` aging the
   pack as decode steps accumulate: once with no ``HealPolicy`` (the
   pack just ages) and once self-healing (probe loss vs fresh-pack
   reference triggers band-by-band background reprogramming between
   decode steps + recalibration, in-flight requests untouched).

Claims (**gated** — the benchmark raises, and ``benchmarks.run`` exits
nonzero, when they fail):

* heal-on serves a pack whose calibration-probe loss stays within the
  ``tests/test_system.py`` tolerance of the fresh pack
  (``loss < ref * 1.35 + 0.2``) at the end of the trace;
* heal-off degrades measurably: its final probe loss breaks that same
  tolerance (otherwise the horizon is too soft to demonstrate anything).
"""

from __future__ import annotations

import numpy as np

from repro.core.analog import design_a
from repro.core.errors import power_law_drift, state_proportional, stuck_faults
from repro.serve import DriftClock, HealPolicy, PackManager, ServeRuntime
from repro.sweep import Axis, SweepSpec

from benchmarks.common import Timer, emit, run_bench_sweep, trials_for
from benchmarks.lm_accuracy import CALIB_STEP, lm_evaluator, trained_lm

#: Design A under proportional cell error, aging with the literature's
#: canonical retention exponent (nu ~ 0.2, lognormal per-cell spread)
#: and a stuck-cell arrival rate of 1e-5 per cell per t0 of age.
DRIFT_SPEC = design_a(
    error=state_proportional(0.05),
    drift=power_law_drift(0.2, sigma_nu=0.3),
    fault=stuck_faults(1e-5),
)

NU_VALUES = (0.1, 0.2, 0.3)
HORIZONS = (1.0, 16.0, 64.0, 256.0, 1024.0)

#: the test_system tolerance formula, against the fresh-pack reference
TOL = "loss < ref * 1.35 + 0.2"

#: healing trace: enough decode steps (requests x budget / slots) for the
#: drift clock to reach HEAL_HORIZON with several health probes en route
N_REQUESTS, MAX_NEW, MAX_SLOTS = 8, 8, 2
HEAL_HORIZON = 256.0


def within_tol(loss: float, ref: float) -> bool:
    return loss < ref * 1.35 + 0.2


def drift_sweep(*, smoke: bool = False) -> SweepSpec:
    """The drift-exponent × device-age serving grid.

    ``drift.t`` and ``fault.t`` are zipped into one age axis (a device
    ages as a whole); ``smoke`` thins to the canonical nu over three
    ages — still the fresh-age bit-identity anchor (t=1 must reproduce
    the no-aging loss) plus a degrading tail for the CI gate.
    """
    nus = (0.2,) if smoke else NU_VALUES
    ages = (1.0, 64.0, 256.0) if smoke else HORIZONS
    return SweepSpec(
        name="driftbench_smoke" if smoke else "driftbench",
        base=DRIFT_SPEC,
        axes=(
            Axis("drift.nu", nus, labels=tuple(f"nu{v:g}" for v in nus)),
            Axis(("drift.t", "fault.t"), tuple((t, t) for t in ages),
                 labels=tuple(f"t{t:g}" for t in ages)),
        ),
        trials=trials_for(3),
        seed=1234,
    )


def request_trace(vocab: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, vocab, size=int(rng.integers(3, 9))).astype(np.int32),
         MAX_NEW)
        for _ in range(N_REQUESTS)
    ]


def serve_aging(cfg, params, calib, reqs, *, heal: bool):
    """Drain ``reqs`` through a runtime whose pack ages on a drift clock;
    returns (final probe loss, final device age, stats, manager)."""
    import jax

    m = PackManager(cfg, params, DRIFT_SPEC, jax.random.PRNGKey(1234),
                    calib_tokens=calib)
    # total decode steps ~= N_REQUESTS * MAX_NEW / MAX_SLOTS; scale the
    # per-step aging so the trace ends around HEAL_HORIZON
    steps_est = N_REQUESTS * MAX_NEW / MAX_SLOTS
    clock = DriftClock(dt_per_step=HEAL_HORIZON / steps_est, update_every=8)
    policy = HealPolicy(check_every=8, bands_per_step=1) if heal else None
    rt = ServeRuntime(cfg, params, manager=m, max_slots=MAX_SLOTS,
                      max_len=24, clock=clock, heal=policy)
    for i, (p, n) in enumerate(reqs):
        rt.submit(p, max_new_tokens=n, uid=i)
    out = rt.run()
    assert len(out) == len(reqs)
    s = rt.stats
    return m.probe_loss(rt.pack), clock.at(s["decode_steps"]), s, m


def main(timer: Timer):
    from benchmarks import common

    cfg, ds, params = trained_lm()
    calib = ds.batch(CALIB_STEP)["tokens"]

    # 1) degradation surface: nu x age, one compile group per shape
    sweep = drift_sweep(smoke=common.SMOKE)
    res = run_bench_sweep(sweep, lm_evaluator())
    trials = max(sweep.trials, 1)
    for r in res:
        emit(f"driftbench_{r.tag}", r.wall_s * 1e6 / trials,
             f"loss={r.metric_mean('loss'):.4f} "
             f"top1={r.metric_mean('top1'):.4f} "
             f"decode_match={r.metric_mean('decode_match'):.2f}")

    # 2) self-healing vs unhealed serving on the same trace
    reqs = request_trace(cfg.vocab)
    l_noheal, t_end, s_off, m = serve_aging(cfg, params, calib, reqs,
                                            heal=False)
    ref = m.ref_loss
    emit("driftbench_ref", 0.0, f"loss={ref:.4f} tol={ref * 1.35 + 0.2:.4f}")
    emit("driftbench_noheal", 0.0,
         f"loss={l_noheal:.4f} t={t_end:.0f} steps={s_off['decode_steps']}")

    l_heal, t_end, s_on, _ = serve_aging(cfg, params, calib, reqs, heal=True)
    emit("driftbench_heal", 0.0,
         f"loss={l_heal:.4f} t={t_end:.0f} "
         f"heals={s_on['heal_events']} bands={s_on['bands_reprogrammed']} "
         f"recals={s_on['recalibrations']}")

    if not within_tol(l_heal, ref):
        raise RuntimeError(
            f"self-healing failed to hold the served pack within tolerance: "
            f"probe loss {l_heal:.4f} vs fresh {ref:.4f} ({TOL}) after "
            f"{s_on['heal_events']} heal events")
    if within_tol(l_noheal, ref):
        raise RuntimeError(
            f"unhealed serving did not degrade past tolerance by t={t_end:.0f} "
            f"(probe loss {l_noheal:.4f} vs fresh {ref:.4f}, {TOL}); the "
            f"horizon is too soft to demonstrate healing")
    emit("driftbench_claim_heal_within_tol", 0.0,
         f"heal={l_heal:.4f} <= tol={ref * 1.35 + 0.2:.4f} "
         f"while noheal={l_noheal:.4f} breaks it ({TOL})")
