"""LM-scale analogue of Figs. 8/15: end-to-end analog *serving* accuracy
of a trained LM over an error-alpha × ADC-resolution × mapping-scheme
grid.

The classifier benchmarks probe the analog pipeline one matmul stack at a
time; this one runs the paper's actual experiment shape — a full trained
network's end metric — through program → calibrate → serve per design
point (``repro.sweep.ServeEvaluator``):

  * ``loss``  — teacher-forced cross-entropy on held-out synthetic data;
  * ``top1``  — next-token accuracy;
  * ``decode_match`` — fraction of greedy KV-cached decode tokens that
    agree with the digital model over a prompt batch (the serving
    configuration, not teacher forcing).

Claims validated at LM scale:
  * proportional mapping (differential, unsliced, analog accumulation)
    tracks the digital loss closely at the paper's baseline point
    (8-bit calibrated ADC) while the offset/fixed-precision-slicing
    scheme loses more under the same cell errors;
  * a calibrated 8-bit ADC is ~free for the differential scheme even
    though B_out >> 8 (the Full Precision Fallacy at network scale);
  * Fig. 19 at serving scale (``lm_parasitics``): an ``r_hat`` axis swept
    end-to-end through program -> calibrate -> serve -> decode, the whole
    axis one compile group with ``r_hat`` traced — differential mapping
    degrades gracefully up to the realistic parasitic operating point.

The trained smoke LM is cached under ``benchmarks/_cache`` like the MLP
vehicle; sweep results cache and resume under ``_cache/sweeps``.
"""

from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig
from repro.data.synthetic import SyntheticLM
from repro.sweep import Axis, ServeEvaluator, SweepSpec
from repro.train.step import loss_fn, make_train_state, train_step_fn

from benchmarks.common import (
    CACHE, Timer, emit, run_bench_sweep, trials_for)

ARCH = "qwen1.5-4b"
SEQ_LEN = 32
BATCH = 8
TRAIN_STEPS = 120
SEED = 0

#: calibration / eval / prompt batches (deterministic synthetic steps,
#: disjoint from the training step range)
CALIB_STEP, EVAL_STEP = 998, 999
N_PROMPTS, PROMPT_LEN, DECODE_NEW = 4, 8, 8

SCHEME_AXIS = Axis(
    ("mapping.scheme", "input_accum"),
    (("differential", "analog"), ("offset", "digital")),
    labels=("proportional", "offset"),
)


def _save_params(path: str, params) -> None:
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    np.savez(path, **{jax.tree_util.keystr(p): np.asarray(v)
                      for p, v in leaves})


def _load_params(path: str, like) -> dict:
    z = np.load(path)
    return jax.tree_util.tree_map_with_path(
        lambda p, v: jnp.asarray(z[jax.tree_util.keystr(p)]), like)


@functools.lru_cache(maxsize=1)
def trained_lm(seed: int = SEED):
    """(cfg, dataset, trained params) — trained once, cached as npz."""
    cfg = get_smoke_config(ARCH)
    ds = SyntheticLM(cfg=cfg, seq_len=SEQ_LEN, global_batch=BATCH, seed=seed)
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"lm_{ARCH.replace('.', '_')}_{seed}.npz")
    state = make_train_state(cfg, jax.random.PRNGKey(seed), lr=3e-3)
    if os.path.exists(path):
        return cfg, ds, _load_params(path, state.params)
    step = jax.jit(train_step_fn(cfg, microbatches=1, lr=3e-3))
    for i in range(TRAIN_STEPS):
        state, m = step(state, ds.batch(i))
    _save_params(path, state.params)
    return cfg, ds, state.params


@functools.lru_cache(maxsize=1)
def lm_evaluator() -> ServeEvaluator:
    """The shared serve evaluator: trained smoke LM + eval splits."""
    cfg, ds, params = trained_lm()
    eval_batch = ds.batch(EVAL_STEP)
    return ServeEvaluator(
        cfg, params,
        ds.batch(CALIB_STEP)["tokens"],
        eval_batch["tokens"], eval_batch["targets"],
        prompts=eval_batch["tokens"][:N_PROMPTS, :PROMPT_LEN],
        decode_new=DECODE_NEW,
    )


def lm_sweep(*, smoke: bool = False) -> SweepSpec:
    """The error-alpha × ADC-bits × mapping-scheme serving grid.

    ``smoke`` thins the grid to one (alpha, bits) cell per scheme — the
    CI path still exercises both compile groups end to end.
    """
    alphas = (0.05,) if smoke else (0.02, 0.05, 0.1)
    bits = (8,) if smoke else (6, 8)
    return SweepSpec(
        name="lm_accuracy_smoke" if smoke else "lm_accuracy",
        base=AnalogSpec(
            mapping=MappingConfig(on_off_ratio=1e4),
            adc=ADCConfig(style="calibrated"),
            error=state_proportional(0.0),
            max_rows=1152,
        ),
        axes=(
            SCHEME_AXIS,
            Axis("adc.bits", bits, labels=tuple(f"{b}b" for b in bits)),
            Axis("error.alpha", alphas,
                 labels=tuple(f"a{a}" for a in alphas)),
        ),
        trials=trials_for(3),
        seed=1234,
    )


#: the LM-serving Fig. 19 axis; the paper's realistic operating point is
#: r_hat <= 1e-5 for differential cells (Sec. 8)
R_HATS = (1e-5, 1e-4, 1e-3)


def lm_parasitics_sweep(*, smoke: bool = False) -> SweepSpec:
    """The serving-scale Fig. 19 grid: an ``r_hat`` axis on Design-A-style
    points (differential, analog accumulation, calibrated 8-bit ADC).

    All levels share one compile group — ``r_hat`` is a traced dynamic
    field of :class:`~repro.sweep.ServeEvaluator`, only the (static)
    parasitics on/off bit changes the program.  ``test_n`` applies the
    paper's subset trick: the per-bit tridiagonal solves make these the
    most expensive serving points.
    """
    r_hats = (1e-4,) if smoke else R_HATS
    return SweepSpec(
        name="lm_parasitics_smoke" if smoke else "lm_parasitics",
        base=AnalogSpec(
            mapping=MappingConfig(on_off_ratio=1e4),
            adc=ADCConfig(style="calibrated", bits=8),
            error=state_proportional(0.02),
            max_rows=1152,
        ),
        axes=(Axis("r_hat", r_hats,
                   labels=tuple(f"r{r:g}" for r in r_hats)),),
        trials=trials_for(2),
        seed=1234,
        test_n=4,
    )


def main(timer: Timer):
    from benchmarks import common

    cfg, ds, params = trained_lm()
    eval_batch = ds.batch(EVAL_STEP)
    dig = float(loss_fn(cfg, params, eval_batch)[0])
    emit("lm_digital_baseline", 0.0, f"loss={dig:.4f}")

    sweep = lm_sweep(smoke=common.SMOKE)
    res = run_bench_sweep(sweep, lm_evaluator())
    trials = max(sweep.trials, 1)
    for r in res:
        emit(f"lm_{r.tag}", r.wall_s * 1e6 / trials,
             f"loss={r.metric_mean('loss'):.4f} "
             f"top1={r.metric_mean('top1'):.4f} "
             f"decode_match={r.metric_mean('decode_match'):.2f}")

    # claim check: proportional mapping beats offset at the paper's
    # baseline point (8-bit calibrated ADC) under the same cell error
    a = "a0.05"
    prop = res.metric(f"proportional_8b_{a}", "loss")
    off = res.metric(f"offset_8b_{a}", "loss")
    emit("lm_claim_proportional_beats_offset", 0.0,
         f"prop={prop:.4f} < offset={off:.4f}: {prop < off} "
         f"(digital={dig:.4f})")

    # Fig. 19 at serving scale: r_hat swept end to end through
    # program -> calibrate -> serve -> decode, one compile group
    psweep = lm_parasitics_sweep(smoke=common.SMOKE)
    pres = run_bench_sweep(psweep, lm_evaluator())
    ptrials = max(psweep.trials, 1)
    for r in pres:
        emit(f"lm_{psweep.name}_{r.tag}", r.wall_s * 1e6 / ptrials,
             f"loss={r.metric_mean('loss'):.4f} "
             f"top1={r.metric_mean('top1'):.4f} "
             f"decode_match={r.metric_mean('decode_match'):.2f}")
    if not common.SMOKE:
        lo_l = pres.metric(f"r{R_HATS[0]:g}", "loss")
        hi_l = pres.metric(f"r{R_HATS[-1]:g}", "loss")
        emit("lm_claim_parasitics_graceful", 0.0,
             f"loss@r{R_HATS[0]:g}={lo_l:.4f} <= "
             f"loss@r{R_HATS[-1]:g}={hi_l:.4f}: {lo_l <= hi_l} "
             f"(digital={dig:.4f})")
