"""Fig. 10: On/Off ratio sensitivity under state-proportional errors with
differential cells.  Claim: On/Off >= 100 is nearly indistinguishable from
an infinite On/Off ratio.

The whole figure is ONE compile group: every point shares the
differential/unsliced shape and differs only in ``on_off_ratio``, which
the sweep engine batches as a traced scalar — four design points x five
trials in a single jitted evaluation."""

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig

from repro.sweep import Axis, SweepSpec

from benchmarks.common import (
    Timer, emit, emit_sweep, run_bench_sweep, trials_for)

ONOFFS = (10.0, 100.0, 1000.0, float("inf"))


def main(timer: Timer):
    sweep = SweepSpec(
        name="fig10",
        base=AnalogSpec(
            mapping=MappingConfig(scheme="differential"),
            adc=ADCConfig(style="none"),
            error=state_proportional(0.06),
            input_accum="analog",
            max_rows=1152,
        ),
        axes=(
            Axis("mapping.on_off_ratio", ONOFFS,
                 labels=tuple(f"onoff{o}" for o in ONOFFS)),
        ),
        trials=trials_for(5),
    )
    res = run_bench_sweep(sweep)
    emit_sweep("fig10", res)
    accs = {o: res.mean(f"onoff{o}") for o in ONOFFS}
    emit("fig10_claim_onoff100_near_inf", 0.0,
         f"onoff100={accs[100.0]:.4f} vs inf={accs[float('inf')]:.4f} "
         f"gap={abs(accs[100.0]-accs[float('inf')]):.4f} (claim: ~0); "
         f"onoff10={accs[10.0]:.4f} (worse)")
