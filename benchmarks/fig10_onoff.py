"""Fig. 10: On/Off ratio sensitivity under state-proportional errors with
differential cells.  Claim: On/Off >= 100 is nearly indistinguishable from
an infinite On/Off ratio."""

import time

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig

from benchmarks.common import Timer, analog_accuracy, emit, train_mlp


def main(timer: Timer):
    params = train_mlp()
    accs = {}
    for onoff in (10.0, 100.0, 1000.0, float("inf")):
        spec = AnalogSpec(
            mapping=MappingConfig(scheme="differential", on_off_ratio=onoff),
            adc=ADCConfig(style="none"),
            error=state_proportional(0.06),
            input_accum="analog",
            max_rows=1152,
        )
        t0 = time.perf_counter()
        m, s = analog_accuracy(params, spec, trials=5)
        accs[onoff] = m
        emit(f"fig10_onoff{onoff}", (time.perf_counter() - t0) * 1e6 / 5,
             f"acc={m:.4f}+-{s:.4f}")
    emit("fig10_claim_onoff100_near_inf", 0.0,
         f"onoff100={accs[100.0]:.4f} vs inf={accs[float('inf')]:.4f} "
         f"gap={abs(accs[100.0]-accs[float('inf')]):.4f} (claim: ~0); "
         f"onoff10={accs[10.0]:.4f} (worse)")
