"""Kernel microbenchmarks: Pallas (interpret on CPU) vs jnp oracle, plus
the vectorized analog path at serving-relevant shapes.  On TPU the same
entry points compile to Mosaic; interpret-mode timings only demonstrate
correctness-path overhead, the derived column carries the work sizes."""

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

from benchmarks.common import Timer, emit


def main(timer: Timer):
    for (m, p, rows, n) in [(128, 1, 1152, 256), (256, 2, 1152, 512)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40)
        gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
        gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
        lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
        args = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, gain=127.0)
        f_k = jax.jit(lambda x, gp, gm: ops.analog_mvm(x, gp, gm, **args))
        f_r = jax.jit(lambda x, gp, gm: ref.analog_mvm_diff(x, gp, gm, **args))
        us_k = timer.time(f_k, x, gp, gm)
        us_r = timer.time(f_r, x, gp, gm)
        macs = m * p * rows * n
        emit(f"kernel_analog_mvm_{m}x{p}x{rows}x{n}", us_k,
             f"ref_us={us_r:.1f} macs={macs} interpret=True")

        fb_k = jax.jit(lambda x, gp, gm: ops.analog_mvm_bitserial(
            x, gp, gm, n_bits=7, **args))
        fb_r = jax.jit(lambda x, gp, gm: ref.analog_mvm_bitserial(
            x, gp, gm, n_bits=7, **args))
        us_bk = timer.time(fb_k, x, gp, gm)
        us_br = timer.time(fb_r, x, gp, gm)
        emit(f"kernel_bitserial_{m}x{p}x{rows}x{n}", us_bk,
             f"ref_us={us_br:.1f} bits=7 (in-VMEM planes vs 8x HBM planes)")

    for (m, k, n, r) in [(128, 1152, 128, 1e-5)]:
        kx, kg = jax.random.split(jax.random.PRNGKey(1), 2)
        x = jnp.sign(jax.random.normal(kx, (m, k)))
        g = jax.random.uniform(kg, (k, n))
        f_k = jax.jit(lambda g, x: ops.bitline_mvm(g, x, r))
        f_r = jax.jit(lambda g, x: ref.bitline_mvm(g, x, r))
        us_k = timer.time(f_k, g, x)
        us_r = timer.time(f_r, g, x)
        emit(f"kernel_bitline_{m}x{k}x{n}", us_k,
             f"ref_us={us_r:.1f} tridiag_solves={m*n}")
