"""Kernel microbenchmarks + sweep-engine wall-clock comparison.

Part 1: Pallas (interpret on CPU) vs jnp oracle at serving-relevant
shapes.  On TPU the same entry points compile to Mosaic; interpret-mode
timings only demonstrate correctness-path overhead, the derived column
carries the work sizes.

Part 2: the tentpole speedup measurement — a 16-point design grid
(2 mapping schemes x 8 error magnitudes, 3 programming trials each)
evaluated (a) by the legacy serial per-point loop the benchmarks used to
hand-roll (``repro.sweep.serial_accuracy``, one eager trial at a time)
and (b) by the vectorized sweep engine (trials vmapped, same-shape
points batched as traced scalars, one jitted call per scheme).  Emits
both wall-clocks and the speedup.

Part 3: the parasitic bit-line production path — (a) the Pallas Thomas
kernel vs the dense vmap-of-scan solve on an (M, N, K) grid, (b) the
fused parasitic Design-A kernel vs its jnp oracle, and (c) the Fig. 19
grid vectorized (one compile group per scheme, ``r_hat`` traced) vs the
legacy serial per-level loop — each row carries the speedup in the
derived column.

Part 4 (also the ``--smoke`` payload, alongside the paged-decode gate):
the fused decode chain — ``analog_matmul`` routed through the
single-launch fused kernels vs the legacy composed per-slice/per-bit
chain at serving decode shapes, parity- and speedup-gated, plus the
flash-decode attention kernel vs its chunked-gather oracle."""

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig
from repro.core.parasitics import bitline_currents
from repro.kernels import ops, ref
from repro.sweep import Axis, SweepSpec

from benchmarks.common import (
    Timer, analog_accuracy, emit, eval_data, run_bench_sweep,
    surface_error, train_mlp)


def kernel_micro(timer: Timer):
    for (m, p, rows, n) in [(128, 1, 1152, 256), (256, 2, 1152, 512)]:
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40)
        gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
        gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
        lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
        args = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, gain=127.0)
        f_k = jax.jit(lambda x, gp, gm: ops.analog_mvm(x, gp, gm, **args))
        f_r = jax.jit(lambda x, gp, gm: ref.analog_mvm_diff(x, gp, gm, **args))
        us_k = timer.time(f_k, x, gp, gm)
        us_r = timer.time(f_r, x, gp, gm)
        macs = m * p * rows * n
        emit(f"kernel_analog_mvm_{m}x{p}x{rows}x{n}", us_k,
             f"ref_us={us_r:.1f} macs={macs} interpret=True")

        fb_k = jax.jit(lambda x, gp, gm: ops.analog_mvm_bitserial(
            x, gp, gm, n_bits=7, **args))
        fb_r = jax.jit(lambda x, gp, gm: ref.analog_mvm_bitserial(
            x, gp, gm, n_bits=7, **args))
        us_bk = timer.time(fb_k, x, gp, gm)
        us_br = timer.time(fb_r, x, gp, gm)
        emit(f"kernel_bitserial_{m}x{p}x{rows}x{n}", us_bk,
             f"ref_us={us_br:.1f} bits=7 (in-VMEM planes vs 8x HBM planes)")

    for (m, k, n, r) in [(128, 1152, 128, 1e-5)]:
        kx, kg = jax.random.split(jax.random.PRNGKey(1), 2)
        x = jnp.sign(jax.random.normal(kx, (m, k)))
        g = jax.random.uniform(kg, (k, n))
        f_k = jax.jit(lambda g, x: ops.bitline_mvm(g, x, r))
        f_r = jax.jit(lambda g, x: ref.bitline_mvm(g, x, r))
        us_k = timer.time(f_k, g, x)
        us_r = timer.time(f_r, g, x)
        emit(f"kernel_bitline_{m}x{k}x{n}", us_k,
             f"ref_us={us_r:.1f} tridiag_solves={m*n}")


def paged_decode_bench(timer: Timer):
    """Paged-attention decode grid vs the jnp gather oracle.

    Long-cache decode shapes (B rows x 1 query token x K cached tokens):
    the block-table walk the serving runtime's ``backend="pallas"`` path
    runs every step.  Bitwise equality against
    ``ref.paged_attention_decode`` is a *gate* — any mismatch raises and
    fails the benchmark run, mirroring the ``array_equal`` pin in
    ``tests/test_kernels.py`` at larger shapes."""
    import numpy as np

    # (B, KV heads, group, head dim, page size, pages per row)
    shapes = [
        (1, 4, 2, 64, 8, 16),    # single row, 128-token cache
        (4, 4, 2, 64, 8, 16),
        (8, 2, 4, 64, 8, 32),    # 256-token cache, GQA 4x
        (4, 8, 1, 32, 16, 16),   # MHA, 256-token cache, big pages
    ]
    for (b, kv, g, hd, ps, npg) in shapes:
        h = kv * g
        pool = 1 + b * npg
        rng = np.random.default_rng(b * npg)
        ks = jax.random.split(jax.random.PRNGKey(b + npg), 3)
        q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
        kp = jax.random.normal(ks[1], (pool, ps, kv, hd), jnp.float32)
        vp = jax.random.normal(ks[2], (pool, ps, kv, hd), jnp.float32)
        perm = rng.permutation(np.arange(1, pool))
        ptab = np.zeros((b, npg), np.int32)
        kv_len = np.zeros((b,), np.int32)
        for i in range(b):       # ragged fills, shuffled non-sink pages
            n = int(rng.integers(ps, npg * ps + 1))
            used = -(-n // ps)
            ptab[i, :used] = perm[i * npg:i * npg + used]
            kv_len[i] = n
        ptab, kv_len = jnp.asarray(ptab), jnp.asarray(kv_len)
        f_k = jax.jit(lambda q, kp, vp, t, l: ops.paged_attention(
            q, kp, vp, t, l))
        f_r = jax.jit(lambda q, kp, vp, t, l: ref.paged_attention_decode(
            q, kp, vp, t, l))
        out_k = f_k(q, kp, vp, ptab, kv_len)
        out_r = f_r(q, kp, vp, ptab, kv_len)
        if not np.array_equal(np.asarray(out_k), np.asarray(out_r)):
            bad = int(np.sum(np.asarray(out_k) != np.asarray(out_r)))
            raise RuntimeError(
                f"paged decode kernel diverged from gather oracle at "
                f"B={b} KV={kv} g={g} hd={hd} ps={ps} NP={npg}: "
                f"{bad} mismatched elements")
        us_k = timer.time(f_k, q, kp, vp, ptab, kv_len)
        us_r = timer.time(f_r, q, kp, vp, ptab, kv_len)
        emit(f"kernel_paged_decode_b{b}_k{kv}x{g}x{hd}_p{ps}x{npg}", us_k,
             f"ref_us={us_r:.1f} cache_toks={int(np.max(kv_len))} "
             f"bitwise=True interpret=True")


def bitline_bench(timer: Timer):
    """Pallas bit-line solve vs the dense vmap-of-scan reference, plus the
    fused parasitic Design-A kernel, on an (M, N, K) grid."""
    r = 1e-4
    for (m, n, k) in [(128, 128, 256), (128, 128, 1152), (256, 256, 576)]:
        kx, kg = jax.random.split(jax.random.PRNGKey(k), 2)
        x = jnp.sign(jax.random.normal(kx, (m, k)))
        g = jax.random.uniform(kg, (k, n))
        f_k = jax.jit(lambda g, x: ops.bitline_mvm(g, x, r))
        f_d = jax.jit(lambda g, x: bitline_currents(g, x, r))
        us_k = timer.time(f_k, g, x)
        us_d = timer.time(f_d, g, x)
        emit(f"bitline_pallas_{m}x{n}x{k}", us_k,
             f"dense_us={us_d:.1f} speedup={us_d / max(us_k, 1e-9):.2f}x "
             f"tridiag_solves={m * n} depth={k} interpret=True")

    for (m, p, rows, n) in [(128, 1, 256, 128), (128, 2, 576, 128)]:
        ks = jax.random.split(jax.random.PRNGKey(m + rows), 3)
        x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40)
        gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
        gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
        args = dict(r_hat=r, n_bits=7, adc_lo=jnp.float32(-50.0),
                    adc_hi=jnp.float32(50.0), adc_bits=8, gain=127.0)
        f_k = jax.jit(lambda x, gp, gm: ops.analog_mvm_parasitic(
            x, gp, gm, **args))
        f_r = jax.jit(lambda x, gp, gm: ref.analog_mvm_parasitic_diff(
            x, gp, gm, **args))
        us_k = timer.time(f_k, x, gp, gm)
        us_r = timer.time(f_r, x, gp, gm)
        emit(f"bitline_fused_diff_{m}x{p}x{rows}x{n}", us_k,
             f"ref_us={us_r:.1f} speedup={us_r / max(us_k, 1e-9):.2f}x "
             f"bits=7 interpret=True")


def fig19_engine_speedup():
    """Fig. 19 batched (traced r_hat, one compile group per scheme) vs the
    pre-dynamic-r_hat behavior (every parasitic level its own compiled
    program) — the compile-amortization win the dynamic field buys.

    Both paths run the same vectorized evaluator; the serial arm feeds it
    one single-point sweep at a time, so ``r_hat`` is a constant in every
    group and each level pays its own tridiagonal-solve compilation —
    exactly how the grid executed when ``r_hat`` was a static field.
    (The fully-eager legacy loop is minutes per parasitic point; see
    ``sweep_engine_speedup`` for that comparison on the error grid.)
    """
    from benchmarks.fig19_parasitics import fig19_sweep

    train_mlp()
    eval_data()
    sweep = fig19_sweep((1e-5, 3e-5, 1e-4, 3e-4, 1e-3), trials=1,
                        test_n=32)
    points = sweep.expand()

    t0 = time.perf_counter()
    per_point = {}
    for pt in points:
        one = SweepSpec(name=f"fig19_pt{pt.index}", base=pt.spec,
                        trials=sweep.trials, seed=sweep.seed,
                        test_n=sweep.test_n)
        per_point[pt.tag] = run_bench_sweep(one, cache=False).results[0].mean
    t_serial = time.perf_counter() - t0         # one compile per level

    t0 = time.perf_counter()
    res = run_bench_sweep(sweep, cache=False)
    t_cold = time.perf_counter() - t0           # 2 compiles, all levels

    t0 = time.perf_counter()
    run_bench_sweep(sweep, cache=False)         # compiled fns reused
    t_warm = time.perf_counter() - t0

    max_dev = max(abs(res.mean(tag) - acc) for tag, acc in per_point.items())
    n = len(points)
    emit("fig19_per_point_compile", t_serial * 1e6,
         f"points={n} wall_s={t_serial:.2f} (one compile per r_hat level)")
    emit("fig19_batched_cold", t_cold * 1e6,
         f"points={n} wall_s={t_cold:.2f} (r_hat traced: 2 compile groups)")
    emit("fig19_batched_warm", t_warm * 1e6,
         f"points={n} wall_s={t_warm:.2f}")
    emit("fig19_speedup", 0.0,
         f"per_point={t_serial:.2f}s vs batched cold={t_cold:.2f}s "
         f"({t_serial / max(t_cold, 1e-9):.2f}x) / warm={t_warm:.2f}s "
         f"({t_serial / max(t_warm, 1e-9):.2f}x) max_acc_dev={max_dev:.4f}")


def sweep_engine_speedup():
    """Vectorized sweep engine vs the legacy serial loop, 16-point grid."""
    params = train_mlp()
    eval_data()   # warm the dataset cache so neither path pays for it
    alphas = (0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.07, 0.08)
    trials = 3
    sweep = SweepSpec(
        name="kernelbench_grid",
        base=AnalogSpec(adc=ADCConfig(style="none"), max_rows=1152),
        axes=(
            Axis(("mapping.scheme", "input_accum"),
                 (("differential", "analog"), ("offset", "digital")),
                 labels=("differential", "offset")),
            Axis("error", tuple(state_proportional(a) for a in alphas),
                 labels=tuple(f"a{a}" for a in alphas)),
        ),
        trials=trials,
    )
    points = sweep.expand()

    t0 = time.perf_counter()
    res = run_bench_sweep(sweep, cache=False)   # no cache: honest timing
    t_cold = time.perf_counter() - t0           # includes jit compilation

    t0 = time.perf_counter()
    run_bench_sweep(sweep, cache=False)         # compiled fns reused
    t_warm = time.perf_counter() - t0

    t0 = time.perf_counter()
    serial = {pt.tag: analog_accuracy(params, pt.spec, trials=trials)[0]
              for pt in points}
    t_serial = time.perf_counter() - t0

    max_dev = max(abs(res.mean(tag) - acc) for tag, acc in serial.items())
    n = len(points)
    emit(f"sweep_vectorized_{n}pt_cold", t_cold * 1e6,
         f"points={n} trials={trials} wall_s={t_cold:.2f} "
         f"(includes compile)")
    emit(f"sweep_vectorized_{n}pt_warm", t_warm * 1e6,
         f"points={n} trials={trials} wall_s={t_warm:.2f}")
    emit(f"sweep_legacy_serial_{n}pt", t_serial * 1e6,
         f"points={len(points)} trials={trials} wall_s={t_serial:.2f}")
    emit("sweep_speedup", 0.0,
         f"serial={t_serial:.2f}s vs vectorized cold={t_cold:.2f}s "
         f"({t_serial / max(t_cold, 1e-9):.2f}x) / warm={t_warm:.2f}s "
         f"({t_serial / max(t_warm, 1e-9):.2f}x) "
         f"max_acc_dev={max_dev:.4f}")


#: fused decode grid: (tag, spec mutation, (M live lanes, K, N)).
#: M=8 is a full continuous-batching decode gang; K/N span the smoke
#: LM's MVM sites up to a max_rows-deep partition.
def _fused_decode_grid():
    from repro.core import analog as A
    from repro.core.errors import ErrorModel

    base = A.design_a(error=ErrorModel())
    sliced = dataclasses.replace(
        base, mapping=MappingConfig(scheme="differential", weight_bits=8,
                                    bits_per_cell=2, on_off_ratio=1e4))
    return [
        ("designA_8x256x256", base, (8, 256, 256)),
        ("designA_8x1152x512", base, (8, 1152, 512)),
        ("designA_P2_8x2304x256", base, (8, 2304, 256)),
        ("digital_8x256x256",
         dataclasses.replace(base, input_accum="digital"), (8, 256, 256)),
        ("sliced_8x576x512", sliced, (8, 576, 512)),
        ("parasitic_8x256x256",
         dataclasses.replace(base, r_hat=1e-4), (8, 256, 256)),
    ]


def fused_decode_bench(timer: Timer):
    """Fused decode chain vs the legacy composed ``analog_matmul`` at
    serving decode shapes — the single-launch-per-site-class payoff.

    Two *gates* (a failure raises; ``benchmarks.run`` exits nonzero):

      * parity — the fused Pallas kernel matches the fused jnp oracle
        within 2 float32 ULPs under jit at every grid point.  The oracle
        is the arithmetic spec of the kernel; XLA may contract the final
        dequant multiply differently per shape, which moves the last
        bit or two but can never flip an ADC code
        (``tests/test_fastpath_routing.py`` pins bitwise equality at the
        shapes the smoke LM actually serves);
      * speedup — the fused chain beats the composed per-slice/per-bit
        chain by >= 1.5x geometric mean, jitted and warm.  The fused arm
        is timed through its jnp lowering (``fused="oracle"``): off-TPU
        the Pallas kernel only runs under the interpreter, whose
        wall-clock measures the emulator, not the launch structure.
    """
    import numpy as np
    from repro.core import analog as A
    from repro.core.calibrate import calibrate_adc_for_matmul

    speedups = {}
    for tag, spec, (m, k, n) in _fused_decode_grid():
        kw_, kx = jax.random.split(jax.random.PRNGKey(k + n))
        w = jax.random.normal(kw_, (k, n)) * 0.1
        x = jax.random.normal(kx, (m, k))
        aw = A.program(w, spec, key=jax.random.PRNGKey(1))
        lo, hi = calibrate_adc_for_matmul(x, aw, spec)
        arms = {
            mode: jax.jit(lambda x, s=dataclasses.replace(spec, fused=mode):
                          A.analog_matmul(x, aw, s, adc_lo=lo, adc_hi=hi))
            for mode in ("off", "oracle", "kernel")
        }
        y_k = np.asarray(arms["kernel"](x))
        y_o = np.asarray(arms["oracle"](x))
        d = np.abs(y_k - y_o)
        mag = np.maximum(np.abs(y_k), np.abs(y_o))
        ulp = float(np.max(np.where(d > 0, d / np.spacing(
            mag.astype(np.float32)), 0.0)))
        if ulp > 2.0:
            raise RuntimeError(
                f"fused kernel diverged from its oracle at {tag}: "
                f"max {ulp:.1f} ULPs (>2) — not an fp-contraction artifact")
        us_c = timer.time(arms["off"], x)
        us_f = timer.time(arms["oracle"], x)
        speedups[tag] = us_c / max(us_f, 1e-9)
        emit(f"fused_decode_{tag}", us_f,
             f"composed_us={us_c:.1f} speedup={speedups[tag]:.2f}x "
             f"kernel_max_ulp={ulp:.1f} slices={aw.g_pos.shape[0]} "
             f"partitions={aw.g_pos.shape[1]}")
    geomean = float(np.exp(np.mean(np.log(list(speedups.values())))))
    emit("fused_decode_claim_speedup", 0.0,
         f"geomean={geomean:.2f}x over composed chain "
         f"(>=1.5 required): {geomean >= 1.5}")
    if geomean < 1.5:
        raise RuntimeError(
            f"fused decode chain speedup {geomean:.2f}x < 1.5x over the "
            f"composed analog_matmul chain: "
            + " ".join(f"{t}={s:.2f}x" for t, s in speedups.items()))


def flash_decode_bench(timer: Timer):
    """Flash-decode attention kernel vs its chunked-gather oracle on
    ragged dense decode caches.  Bitwise equality is a *gate* — the
    serving runtime's fused-vs-oracle agreement contract rests on it."""
    import numpy as np

    # (B rows, cache S, KV heads, GQA group, head dim)
    shapes = [(4, 64, 4, 2, 64), (8, 96, 2, 4, 64), (3, 40, 8, 1, 32)]
    for (b, s, kv, g, hd) in shapes:
        h = kv * g
        ks = jax.random.split(jax.random.PRNGKey(b + s), 3)
        q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
        ck = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
        cv = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
        import numpy.random as npr
        fills = jnp.asarray(npr.default_rng(b).integers(1, s + 1, size=b),
                            jnp.int32)
        f_k = jax.jit(lambda q, ck, cv, f: ops.flash_attention_decode(
            q, ck, cv, f, backend="kernel"))
        f_r = jax.jit(lambda q, ck, cv, f: ops.flash_attention_decode(
            q, ck, cv, f, backend="oracle"))
        out_k, out_r = f_k(q, ck, cv, fills), f_r(q, ck, cv, fills)
        if not np.array_equal(np.asarray(out_k), np.asarray(out_r)):
            bad = int(np.sum(np.asarray(out_k) != np.asarray(out_r)))
            raise RuntimeError(
                f"flash-decode kernel diverged from chunked-gather oracle "
                f"at B={b} S={s} KV={kv} g={g} hd={hd}: {bad} mismatches")
        us_k = timer.time(f_k, q, ck, cv, fills)
        us_r = timer.time(f_r, q, ck, cv, fills)
        emit(f"flash_decode_b{b}_s{s}_k{kv}x{g}x{hd}", us_k,
             f"oracle_us={us_r:.1f} bitwise=True interpret=True")


def main(timer: Timer):
    from benchmarks import common

    # the parts are independent: a Pallas interpret-mode failure (the
    # kernels are TPU-first) must not mask the sweep-engine measurements.
    if not common.SMOKE:
        try:
            kernel_micro(timer)
        except Exception as e:
            emit("kernel_micro_ERROR", 0.0, surface_error("kernel_micro", e))
    # NOT wrapped: the decode gates (paged bitwise equality, fused parity
    # + speedup, flash bitwise equality) must fail the run
    # (benchmarks.run exits nonzero) — this is the whole --smoke payload
    paged_decode_bench(timer)
    fused_decode_bench(timer)
    flash_decode_bench(timer)
    if common.SMOKE:
        return  # the engine-speedup measurements below are minutes-scale
    try:
        bitline_bench(timer)
    except Exception as e:
        emit("bitline_bench_ERROR", 0.0, surface_error("bitline_bench", e))
    sweep_engine_speedup()
    fig19_engine_speedup()
