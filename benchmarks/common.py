"""Shared benchmark vehicle: a small trained classifier + sweep wiring.

The paper's accuracy claims are about *trained* networks (zero-peaked
weight distributions are the mechanism behind proportional mapping), so
every sensitivity benchmark runs on an MLP classifier trained here on a
deterministic synthetic 16-class task (CPU, seconds).  The trained weights
are cached under ``benchmarks/_cache``.

Each benchmark script declares its design grid as a
:class:`repro.sweep.SweepSpec` and evaluates it with
:func:`run_bench_sweep`, which wires in the shared
:class:`~repro.sweep.ClassifierEvaluator` (the trained MLP + calibration/
test splits), the on-disk sweep cache (``benchmarks/_cache/sweeps``), and
the device mesh when more than one device is visible.  The legacy
one-point-at-a-time loop survives only as :func:`analog_accuracy`, the
serial reference that ``kernelbench`` times the vectorized engine against
and ``tests/test_sweep.py`` pins it to.
"""

from __future__ import annotations

import functools
import os
import time
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog import AnalogSpec
from repro.core.quant import calibrate_act_range
from repro.sweep import (
    ClassifierEvaluator,
    SweepResults,
    SweepSpec,
    run_sweep,
    serial_accuracy,
    sweep_mesh,
)

CACHE = os.path.join(os.path.dirname(__file__), "_cache")
N_CLASSES = 64
DIMS = (64, 256, 256, 256, N_CLASSES)

#: set by ``benchmarks.run --smoke``: one trial per point, for CI.
SMOKE = False


def trials_for(n: int) -> int:
    """The paper's trial count, reduced to 1 under ``--smoke``."""
    return 1 if SMOKE else n


def make_dataset(key, n: int):
    """Heavily-overlapping Gaussian clusters with class-dependent warps:
    hard enough that accuracy sits well below 100% and analog errors bite
    (the sensitivity regime the paper's Fig. 5 shows for ImageNet)."""
    kc, kx, kn = jax.random.split(key, 3)
    labels = jax.random.randint(kc, (n,), 0, N_CLASSES)
    centers = jax.random.normal(jax.random.PRNGKey(42), (N_CLASSES, DIMS[0]))
    x = centers[labels] * 0.9
    x = x + 1.2 * jax.random.normal(kx, (n, DIMS[0]))
    warp = jax.random.normal(jax.random.PRNGKey(43), (N_CLASSES, DIMS[0]))
    x = x + 0.5 * warp[labels] * jnp.tanh(x)
    return x, labels


def mlp_forward(params, x, *, act_fn=jax.nn.relu):
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i < len(params) - 1:
            h = act_fn(h)
    return h


def train_mlp(seed: int = 0, steps: int = 1500, lr: float = 3e-3):
    os.makedirs(CACHE, exist_ok=True)
    path = os.path.join(CACHE, f"mlp_{seed}.npz")
    if os.path.exists(path):
        z = np.load(path)
        n = len(DIMS) - 1
        return [(jnp.asarray(z[f"w{i}"]), jnp.asarray(z[f"b{i}"]))
                for i in range(n)]
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, len(DIMS))
    params = [
        (jax.random.normal(ks[i], (DIMS[i], DIMS[i + 1])) * DIMS[i] ** -0.5,
         jnp.zeros((DIMS[i + 1],)))
        for i in range(len(DIMS) - 1)
    ]
    xtr, ytr = make_dataset(jax.random.PRNGKey(100), 8192)

    def loss(p, x, y):
        logits = mlp_forward(p, x)
        return jnp.mean(
            jax.nn.logsumexp(logits, -1)
            - jnp.take_along_axis(logits, y[:, None], -1)[:, 0])

    @jax.jit
    def step(p, k):
        idx = jax.random.randint(k, (256,), 0, xtr.shape[0])
        g = jax.grad(loss)(p, xtr[idx], ytr[idx])
        return jax.tree.map(lambda a, b: a - lr * b, p, g)

    for i in range(steps):
        params = step(params, jax.random.fold_in(key, i))
    np.savez(path, **{f"w{i}": np.asarray(w) for i, (w, b) in enumerate(params)},
             **{f"b{i}": np.asarray(b) for i, (w, b) in enumerate(params)})
    return params


@functools.lru_cache(maxsize=1)
def eval_data():
    xca, yca = make_dataset(jax.random.PRNGKey(200), 512)    # calibration
    xte, yte = make_dataset(jax.random.PRNGKey(300), 2048)   # test
    return xca, yca, xte, yte


def digital_accuracy(params, *, weight_bits=8, act_bits=8) -> float:
    """8-bit quantized digital baseline (the paper's reference point)."""
    from repro.core.quant import quantize_acts, quantize_weights

    xca, _, xte, yte = eval_data()
    h = xte
    for i, (w, b) in enumerate(params):
        qw = quantize_weights(w, weight_bits)
        _, hi = calibrate_act_range(
            _layer_inputs(params, xca, i), act_bits)
        qx = quantize_acts(h, act_bits, clip_hi=hi)
        h = qx.dequant() @ qw.dequant() + b
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return float(jnp.mean(jnp.argmax(h, -1) == yte))


def _layer_inputs(params, x, layer: int):
    h = x
    for i, (w, b) in enumerate(params):
        if i == layer:
            return h
        h = jax.nn.relu(h @ w + b)
    return h


# ---------------------------------------------------------------------------
# sweep wiring
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=1)
def mlp_evaluator() -> ClassifierEvaluator:
    """The shared vectorized evaluator: trained MLP + eval splits."""
    params = train_mlp()
    xca, _, xte, yte = eval_data()
    return ClassifierEvaluator(params, xca, xte, yte)


def run_bench_sweep(sweep: SweepSpec, evaluator=None, *,
                    cache: bool = True, force: bool = False) -> SweepResults:
    """Run a benchmark sweep: shared evaluator, on-disk cache, device mesh."""
    ev = evaluator if evaluator is not None else mlp_evaluator()
    return run_sweep(
        sweep,
        ev,
        cache_dir=CACHE if (cache and not SMOKE) else None,
        force=force,
        mesh=sweep_mesh(),
        verbose=True,
    )


def emit_sweep(prefix: str, results: SweepResults, *, fmt=None) -> None:
    """One CSV row per design point; wall-clock is per programming trial."""
    trials = max(results.sweep.trials, 1)
    for r in results:
        derived = fmt(r) if fmt else f"acc={r.mean:.4f}+-{r.std:.4f}"
        emit(f"{prefix}_{r.tag}", r.wall_s * 1e6 / trials, derived)


def analog_accuracy(
    params,
    spec: AnalogSpec,
    *,
    trials: int = 5,
    seed: int = 1234,
    test_n: Optional[int] = None,
) -> Tuple[float, float]:
    """(mean, std) accuracy via the LEGACY serial per-point loop.

    One eager programming trial at a time — the pre-sweep-engine path,
    kept as the reference implementation (see
    :func:`repro.sweep.serial_accuracy`).  Benchmarks route through
    :func:`run_bench_sweep` instead; ``kernelbench`` times this loop
    against the vectorized engine.
    """
    xca, _, xte, yte = eval_data()
    if test_n is not None:
        xte, yte = xte[:test_n], yte[:test_n]
    mean, std, _ = serial_accuracy(
        params, spec, xca, xte, yte, trials=trials, seed=seed)
    return mean, std


class Timer:
    """us-per-call timer for the benchmark CSV."""

    def __init__(self, reps: int = 5):
        self.reps = reps

    def time(self, fn, *args) -> float:
        fn(*args)  # compile/warm
        t0 = time.perf_counter()
        for _ in range(self.reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / self.reps * 1e6


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


def surface_error(name: str, exc: BaseException) -> str:
    """Benchmark catch blocks: full traceback to stderr, short repr back.

    A bare ``repr(e)[:200]`` in the CSV ``derived`` column swallows the
    stack of a deep JAX trace — the part that says *which* kernel shape
    or sweep point died.  Callers do
    ``emit(f"{name}_ERROR", 0.0, surface_error(name, e))``: the CSV row
    stays one line, the stderr log carries the whole story.
    """
    import sys
    import traceback

    tb = "".join(traceback.format_exception(type(exc), exc,
                                            exc.__traceback__))
    print(f"# {name} FAILED\n{tb}", file=sys.stderr, flush=True)
    return repr(exc)[:200]
