"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage::

    PYTHONPATH=src python -m benchmarks.run [--only fig8_9] [--smoke]

``--smoke`` is the CI fast path: one benchmark (Fig. 10's On/Off sweep —
a single compile group exercising the whole vectorized engine), one
programming trial per point, fresh (uncached) evaluation.
"""

import argparse
import sys
import time


MODULES = [
    "fig6_conductance",
    "eq9_snr",
    "fig8_9_cell_errors",
    "fig10_onoff",
    "fig15_16_adc",
    "fig17_lowprec",
    "fig19_parasitics",
    "table3_energy",
    "table4_sonos",
    "kernelbench",
    "roofline",
]

SMOKE_MODULES = ["fig10_onoff"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast path: one sweep, one trial per point")
    args = ap.parse_args()

    from benchmarks import common
    from benchmarks.common import Timer, emit

    modules = MODULES
    if args.smoke:
        common.SMOKE = True
        modules = SMOKE_MODULES

    timer = Timer(reps=3)
    print("name,us_per_call,derived")
    for mod_name in modules:
        if args.only and args.only not in mod_name:
            continue
        t0 = time.time()
        if mod_name == "roofline":
            # roofline reads the dry-run results, no model eval
            from repro.launch import roofline as rl

            rows = rl.load_all()
            for r in rows:
                if r["mesh"] != "pod16x16":
                    continue
                emit(
                    f"roofline_{r['arch']}_{r['shape']}", 0.0,
                    f"comp={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s "
                    f"coll={r['collective_s']:.2e}s dom={r['dominant']} "
                    f"useful={r['useful_ratio']:.2f} "
                    f"roofline={100*r['roofline_fraction']:.1f}%",
                )
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        try:
            mod.main(timer)
        except Exception as e:  # keep the harness running
            emit(f"{mod_name}_ERROR", 0.0, repr(e)[:200])
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
