"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Usage::

    PYTHONPATH=src python -m benchmarks.run [module ...] [--only fig8_9] [--smoke]

Positional ``module`` names (substring match, like ``--only``) restrict
the run, e.g. ``python -m benchmarks.run lm_accuracy --smoke``.

``--smoke`` is the CI fast path: the Fig. 10 On/Off sweep (a single
compile group exercising the whole vectorized engine), the Fig. 19
parasitic grid (the traced-``r_hat`` bit-line solve path), the LM
serving sweeps (``lm_accuracy`` — program → calibrate → serve end to
end, including the serving-scale parasitic axis), the heterogeneous
per-site precision grid (``hetero_precision`` — mixed attn/MLP ADC
bits through ``repro.hw.Profile``, with the matched-loss claim gate),
the serving runtime (``servebench`` — continuous vs static
batching, with the runtime-vs-``decode_lm`` agreement gate), and the
drift/fault aging story (``driftbench`` — the nu × device-age
degradation surface plus the self-healing-vs-unhealed serving gate),
and the fused decode kernels (``kernelbench`` — fused-vs-oracle parity
and the fused-vs-composed speedup gate on decode shapes);
one programming trial per point, fresh (uncached) evaluation.
"""

import argparse
import sys
import time


MODULES = [
    "fig6_conductance",
    "eq9_snr",
    "fig8_9_cell_errors",
    "fig10_onoff",
    "fig15_16_adc",
    "fig17_lowprec",
    "fig19_parasitics",
    "table3_energy",
    "table4_sonos",
    "lm_accuracy",
    "hetero_precision",
    "servebench",
    "driftbench",
    "kernelbench",
    "roofline",
]

SMOKE_MODULES = ["fig10_onoff", "fig19_parasitics", "lm_accuracy",
                 "hetero_precision", "servebench", "driftbench",
                 "kernelbench"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("modules", nargs="*", default=[],
                    help="restrict to modules matching any of these "
                         "substrings (e.g. lm_accuracy)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast path: thinned sweeps, one trial per point")
    args = ap.parse_args()

    from benchmarks import common
    from benchmarks.common import Timer, emit

    common.SMOKE = args.smoke
    # --smoke alone runs the CI subset; an explicit selection (positional
    # or --only) picks from ALL modules, with --smoke just thinning the
    # sweeps — so `run.py fig8_9 --smoke` means the fig8_9 smoke grid.
    modules = MODULES
    if args.smoke and not (args.modules or args.only):
        modules = SMOKE_MODULES
    selected = [
        m for m in modules
        if (not args.only or args.only in m)
        and (not args.modules or any(s in m for s in args.modules))
    ]
    if not selected:
        ap.error(f"no benchmark matches {args.modules or [args.only]}; "
                 f"choose from {', '.join(MODULES)}")

    failed = []
    timer = Timer(reps=3)
    print("name,us_per_call,derived")
    for mod_name in selected:
        t0 = time.time()
        if mod_name == "roofline":
            # roofline reads the dry-run results, no model eval
            from repro.launch import roofline as rl

            rows = rl.load_all()
            for r in rows:
                if r["mesh"] != "pod16x16":
                    continue
                emit(
                    f"roofline_{r['arch']}_{r['shape']}", 0.0,
                    f"comp={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s "
                    f"coll={r['collective_s']:.2e}s dom={r['dominant']} "
                    f"useful={r['useful_ratio']:.2f} "
                    f"roofline={100*r['roofline_fraction']:.1f}%",
                )
            continue
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["main"])
        try:
            mod.main(timer)
        except Exception as e:  # keep the harness running
            emit(f"{mod_name}_ERROR", 0.0, common.surface_error(mod_name, e))
            failed.append(mod_name)
        print(f"# {mod_name} done in {time.time()-t0:.1f}s", file=sys.stderr)

    if failed:
        # every other module still ran, but CI must see the breakage
        print(f"# FAILED: {', '.join(failed)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
