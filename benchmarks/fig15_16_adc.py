"""Fig. 15/16: ADC resolution sensitivity, calibrated vs uncalibrated
range, and the 8-bit-ADC design space (array size x bits/cell).

Claims validated:
  * range calibration buys many bits — especially for differential cells
    (paper: 5-9 bits), because the useful signal is a tiny fraction of the
    full-scale range (Fig. 14);
  * with differential cells + analog input accumulation (dot-product
    proportionality), a calibrated 8-bit ADC loses ~nothing regardless of
    array size / bits-per-cell, even though B_out >> 8 (the Full Precision
    Fallacy, Sec. 3.3);
  * offset subtraction needs small arrays + fine slicing to live with an
    8-bit ADC (Fig. 16).
"""

import dataclasses
import time

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import ErrorModel
from repro.core.mapping import MappingConfig

from benchmarks.common import Timer, analog_accuracy, digital_accuracy, emit, train_mlp


def _acc(params, spec):
    t0 = time.perf_counter()
    m, s = analog_accuracy(params, spec, trials=1)   # ADC is deterministic
    return m, s, (time.perf_counter() - t0) * 1e6


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)
    emit("fig15_digital_baseline", 0.0, f"acc={base:.4f}")

    # --- Fig. 15: ADC bits sweep, calibrated vs FPG-range(uncalibrated) ---
    for scheme, accum in (("differential", "analog"), ("offset", "digital")):
        mc = MappingConfig(scheme=scheme, bits_per_cell=None)
        for bits in (5, 6, 7, 8, 10):
            spec_c = AnalogSpec(
                mapping=mc, adc=ADCConfig(style="calibrated", bits=bits),
                error=ErrorModel(), input_accum=accum, max_rows=1152)
            m, s, us = _acc(params, spec_c)
            emit(f"fig15_{scheme}_calib_{bits}b", us, f"acc={m:.4f}")
        # uncalibrated: FPG-style full range at the SAME (low) resolution
        for bits in (8, 12, 16):
            spec_u = dataclasses.replace(
                spec_c, adc=ADCConfig(style="fpg", bits=bits))
            # fpg style derives its own bits; emulate "uncalibrated at N
            # bits" by range=full but resolution=bits via calibrated ranges
            # set to the full analytic range:
            from repro.core import adc as adc_lib

            m, s, us = _acc(params, dataclasses.replace(
                spec_c, adc=ADCConfig(style="calibrated", bits=bits)))
            del m, s  # calibrated reference at this resolution
            spec_full = AnalogSpec(
                mapping=mc, adc=ADCConfig(style="fpg", bits=bits),
                error=ErrorModel(), input_accum=accum, max_rows=1152)
            bfpg = spec_full.fpg_adc_bits(256)
            emit(f"fig15_{scheme}_fpg_bits", 0.0,
                 f"B_out={bfpg} (vs 8b calibrated sufficing)")
            break

    # --- Fig. 16: fixed 8-bit calibrated ADC, sweep rows x bits/cell ------
    for scheme, accum in (("differential", "analog"), ("offset", "digital")):
        for bpc in (2, None):
            for rows in (72, 144, 1152):
                spec = AnalogSpec(
                    mapping=MappingConfig(scheme=scheme, bits_per_cell=bpc),
                    adc=ADCConfig(style="calibrated", bits=8),
                    error=ErrorModel(), input_accum=accum, max_rows=rows)
                m, s, us = _acc(params, spec)
                emit(f"fig16_{scheme}_bpc{bpc}_rows{rows}", us,
                     f"acc={m:.4f} (drop={base - m:+.4f})")
