"""Fig. 15/16: ADC resolution sensitivity, calibrated vs uncalibrated
range, and the 8-bit-ADC design space (array size x bits/cell).

Claims validated:
  * range calibration buys many bits — especially for differential cells
    (paper: 5-9 bits), because the useful signal is a tiny fraction of the
    full-scale range (Fig. 14);
  * with differential cells + analog input accumulation (dot-product
    proportionality), a calibrated 8-bit ADC loses ~nothing regardless of
    array size / bits-per-cell, even though B_out >> 8 (the Full Precision
    Fallacy, Sec. 3.3);
  * offset subtraction needs small arrays + fine slicing to live with an
    8-bit ADC (Fig. 16).

Two SweepSpecs: Fig. 15 sweeps ADC resolution per scheme, Fig. 16 fixes
the 8-bit calibrated ADC and sweeps array depth x bits/cell.  The ADC is
deterministic, so both run single-trial; distinct (scheme, slicing,
array-depth) combinations compile once each and their points batch
within the group."""

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.mapping import MappingConfig

from repro.sweep import Axis, SweepSpec

from benchmarks.common import (
    Timer, digital_accuracy, emit, emit_sweep, run_bench_sweep, train_mlp)

SCHEME_AXIS = Axis(
    ("mapping.scheme", "input_accum"),
    (("differential", "analog"), ("offset", "digital")),
    labels=("differential", "offset"),
)


def fig15_sweep() -> SweepSpec:
    """Fig. 15: ADC bits sweep (calibrated ranges)."""
    return SweepSpec(
        name="fig15",
        base=AnalogSpec(
            mapping=MappingConfig(bits_per_cell=None),
            adc=ADCConfig(style="calibrated"),
            max_rows=1152,
        ),
        axes=(
            SCHEME_AXIS,
            Axis("adc.bits", (5, 6, 7, 8, 10),
                 labels=tuple(f"calib_{b}b" for b in (5, 6, 7, 8, 10))),
        ),
        trials=1,   # ADC is deterministic
    )


def fig16_sweep() -> SweepSpec:
    """Fig. 16: fixed 8-bit calibrated ADC, sweep rows x bits/cell."""
    return SweepSpec(
        name="fig16",
        base=AnalogSpec(
            adc=ADCConfig(style="calibrated", bits=8),
        ),
        axes=(
            SCHEME_AXIS,
            Axis("mapping.bits_per_cell", (2, None),
                 labels=("bpc2", "bpcNone")),
            Axis("max_rows", (72, 144, 1152),
                 labels=tuple(f"rows{r}" for r in (72, 144, 1152))),
        ),
        trials=1,
    )


def main(timer: Timer):
    params = train_mlp()
    base = digital_accuracy(params)
    emit("fig15_digital_baseline", 0.0, f"acc={base:.4f}")

    emit_sweep("fig15", run_bench_sweep(fig15_sweep()),
               fmt=lambda r: f"acc={r.mean:.4f}")

    # uncalibrated reference: Eq. (4)'s Full Precision Guarantee resolution
    # at this depth — the analytic B_out an uncalibrated full-range ADC
    # would need, vs the 8 calibrated bits sufficing above.
    for scheme, accum in (("differential", "analog"), ("offset", "digital")):
        spec_full = AnalogSpec(
            mapping=MappingConfig(scheme=scheme, bits_per_cell=None),
            adc=ADCConfig(style="fpg", bits=8),
            input_accum=accum, max_rows=1152)
        emit(f"fig15_{scheme}_fpg_bits", 0.0,
             f"B_out={spec_full.fpg_adc_bits(256)} "
             f"(vs 8b calibrated sufficing)")

    res16 = run_bench_sweep(fig16_sweep())
    emit_sweep("fig16", res16,
               fmt=lambda r: f"acc={r.mean:.4f} (drop={base - r.mean:+.4f})")
