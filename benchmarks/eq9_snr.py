"""Eq. 9/10: the bit-slicing SNR benefit is bounded by sqrt(3).

Monte-Carlo of the dot-product SNR for offset mapping with
state-independent errors: slicing 8-bit weights into 1-bit cells should
improve SNR by at most sqrt(3) ~ 1.286x for 2-bit cells (Eq. 10) — a
small benefit, nowhere near the 'slicing fixes bad cells' assumption.

The Monte-Carlo is a bits-per-cell sweep with a key-taking
FunctionEvaluator: the six programming trials per point run as one
vmapped, jitted evaluation instead of a Python loop."""

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec, analog_matmul, program
from repro.core.errors import ErrorModel, state_independent
from repro.core.mapping import MappingConfig
from repro.sweep import Axis, FunctionEvaluator, SweepSpec

from benchmarks.common import Timer, emit, run_bench_sweep

K, N, M, ALPHA = 512, 64, 64, 0.03
BPCS = (None, 4, 2, 1)


def _problem():
    kw, kx = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(kw, (K, N)) * 0.05
    x = jax.nn.relu(jax.random.normal(kx, (M, K)))
    return w, x


def main(timer: Timer):
    w, x = _problem()

    def trial_rmse(spec: AnalogSpec, key: jax.Array):
        """RMS dot-product error of one programming trial vs error-free."""
        spec0 = dataclasses.replace(spec, error=ErrorModel())
        y0 = analog_matmul(x, program(w, spec0), spec0)
        y = analog_matmul(x, program(w, spec, key), spec)
        return jnp.sqrt(jnp.mean((y - y0) ** 2))

    sweep = SweepSpec(
        name="eq9",
        base=AnalogSpec(
            mapping=MappingConfig(scheme="offset"),
            adc=ADCConfig(style="none"),
            error=state_independent(ALPHA),
            input_accum="digital",
            max_rows=2048,
        ),
        axes=(Axis("mapping.bits_per_cell", BPCS,
                   labels=tuple(f"bpc{b}" for b in BPCS)),),
        trials=6,
        seed=99,
    )
    res = run_bench_sweep(
        sweep,
        FunctionEvaluator(trial_rmse, name="eq9_trial_rmse", takes_key=True,
                          data=(w, x)))

    snrs = {}
    for bpc in BPCS:
        spec0 = AnalogSpec(
            mapping=MappingConfig(scheme="offset", bits_per_cell=bpc),
            adc=ADCConfig(style="none"), input_accum="digital", max_rows=2048)
        sig = float(jnp.std(analog_matmul(x, program(w, spec0), spec0)))
        r = res[f"bpc{bpc}"]
        snrs[bpc] = sig / r.mean
        emit(f"eq9_snr_bpc{bpc}", r.wall_s * 1e6 / sweep.trials,
             f"snr={snrs[bpc]:.3f}")
    gain2 = snrs[2] / snrs[None]
    gain1 = snrs[1] / snrs[None]
    emit("eq9_claim_sqrt3_bound", 0.0,
         f"gain(2b)={gain2:.3f} (Eq.10 predicts 1.286), "
         f"gain(1b)={gain1:.3f} (bound sqrt(3)=1.732): "
         f"bounded={gain1 < 1.8 and gain2 < 1.5}")
