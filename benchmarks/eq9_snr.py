"""Eq. 9/10: the bit-slicing SNR benefit is bounded by sqrt(3).

Monte-Carlo of the dot-product SNR for offset mapping with
state-independent errors: slicing 8-bit weights into 1-bit cells should
improve SNR by at most sqrt(3) ~ 1.286x for 2-bit cells (Eq. 10) — a
small benefit, nowhere near the 'slicing fixes bad cells' assumption."""

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec, analog_matmul, ideal_matmul_int, program
from repro.core.errors import state_independent
from repro.core.mapping import MappingConfig

from benchmarks.common import Timer, emit


def snr_for(bpc, key, *, k=512, n=64, m=64, alpha=0.03):
    spec = AnalogSpec(
        mapping=MappingConfig(scheme="offset", bits_per_cell=bpc),
        adc=ADCConfig(style="none"), error=state_independent(alpha),
        input_accum="digital", max_rows=2048)
    kw, kx = jax.random.split(jax.random.PRNGKey(0), 2)
    w = jax.random.normal(kw, (k, n)) * 0.05
    x = jax.nn.relu(jax.random.normal(kx, (m, k)))
    spec0 = AnalogSpec(mapping=spec.mapping, adc=ADCConfig(style="none"),
                       input_accum="digital", max_rows=2048)
    y0 = analog_matmul(x, program(w, spec0), spec0)
    errs = []
    for t in range(6):
        aw = program(w, spec, jax.random.fold_in(key, t))
        y = analog_matmul(x, aw, spec)
        errs.append(jnp.sqrt(jnp.mean((y - y0) ** 2)))
    sig = jnp.std(y0)
    return float(sig / jnp.mean(jnp.asarray(errs)))


def main(timer: Timer):
    key = jax.random.PRNGKey(99)
    snrs = {}
    for bpc in (None, 4, 2, 1):
        snrs[bpc] = snr_for(bpc, key)
        emit(f"eq9_snr_bpc{bpc}", 0.0, f"snr={snrs[bpc]:.3f}")
    gain2 = snrs[2] / snrs[None]
    gain1 = snrs[1] / snrs[None]
    emit("eq9_claim_sqrt3_bound", 0.0,
         f"gain(2b)={gain2:.3f} (Eq.10 predicts 1.286), "
         f"gain(1b)={gain1:.3f} (bound sqrt(3)=1.732): "
         f"bounded={gain1 < 1.8 and gain2 < 1.5}")
