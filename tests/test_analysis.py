"""Analyzer tests: the fixture corpus pins each rule to its exact
expected findings (including the two historical PR 3 bugs reproduced
verbatim), the suppression/baseline machinery round-trips, the repo's
static compile contracts hold, and — the zero-false-positive gate —
current ``src/repro`` analyzes clean."""

import json
import os
import subprocess
import sys

import pytest

from repro.analysis import (
    Baseline,
    CompileContract,
    Finding,
    analyze_file,
    analyze_paths,
    analyze_source,
    check_contract,
    rule_ids,
)
from repro.analysis.repo_contracts import static_contracts

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "fixtures", "analysis")


def _fix(name):
    return os.path.join(FIXTURES, name)


def _rules_at(findings):
    return [(f.rule, f.line) for f in findings]


# ---------------------------------------------------------------------------
# known-bad fixtures: exact findings
# ---------------------------------------------------------------------------

def test_rope_concat_fixture_flags_the_pr3_bug():
    """The verbatim pre-PR-3 rope must produce exactly one spmd-concat
    finding, at the concatenate, naming the sliced base."""
    fs = analyze_file(_fix("bad_rope_concat.py"))
    assert _rules_at(fs) == [("spmd-concat", 22)]
    assert "slices of 'x'" in fs[0].msg


def test_tile_fixture_flags_the_pick_tile_bug():
    """A 64-wide lane tile (the `_pick_tile` bug class) flags on both
    the in_spec and the out_spec BlockSpec."""
    fs = analyze_file(_fix("bad_tile.py"))
    assert _rules_at(fs) == [("pallas-tile", 17), ("pallas-tile", 18)]
    assert all("multiple of 128" in f.msg for f in fs)


def test_key_reuse_fixture():
    fs = analyze_file(_fix("bad_key_reuse.py"))
    assert _rules_at(fs) == [("prng-reuse", 8)]
    assert "'key'" in fs[0].msg and "line 7" in fs[0].msg


def test_literal_seed_fixture():
    fs = analyze_file(_fix("bad_literal_seed.py"))
    assert _rules_at(fs) == [("prng-seed", 7)]


def test_host_sync_fixture():
    """.item() behind a decorated jit root; float()/np.asarray inside a
    jitted factory's returned closure."""
    fs = analyze_file(_fix("bad_host_sync.py"))
    assert _rules_at(fs) == [
        ("host-sync", 9), ("host-sync", 19), ("host-sync", 19)]
    sites = {f.msg.split(" inside")[0] for f in fs}
    assert sites == {".item()", "float()", "np.asarray"}


def test_assert_except_fixture():
    fs = analyze_file(_fix("bad_assert_except.py"))
    assert _rules_at(fs) == [("bare-assert", 5), ("silent-except", 13)]


# ---------------------------------------------------------------------------
# known-good counterparts: pinned clean
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", [
    "good_rope_roll.py", "good_tile.py", "good_key_split.py",
    "good_host_sync.py",
])
def test_good_fixture_is_clean(name):
    assert analyze_file(_fix(name)) == []


def test_zero_false_positives_on_src_repro():
    """The acceptance gate: the shipped tree analyzes clean (true
    positives were fixed in this PR, not baselined)."""
    assert analyze_paths([os.path.join(ROOT, "src", "repro")]) == []


# ---------------------------------------------------------------------------
# rule behavior details
# ---------------------------------------------------------------------------

def test_newaxis_slices_not_flagged():
    src = (
        "import jax.numpy as jnp\n"
        "def f(a, b):\n"
        "    return jnp.concatenate([a[:, None], b[:, None]], axis=-1)\n"
    )
    assert analyze_source(src) == []


def test_concat_of_different_bases_not_flagged():
    src = (
        "import jax.numpy as jnp\n"
        "def f(a, b, h):\n"
        "    return jnp.concatenate([a[:h], b[h:]], axis=-1)\n"
    )
    assert analyze_source(src) == []


def test_alias_resolution_sees_through_import_names():
    src = (
        "from jax.numpy import concatenate as cat\n"
        "def f(x, h):\n"
        "    return cat([x[:, :h], x[:, h:]], axis=1)\n"
    )
    assert [f.rule for f in analyze_source(src)] == ["spmd-concat"]


def test_variable_tile_dims_not_flagged():
    """Non-literal BlockSpec dims (runtime-picked tiles) are out of
    scope for the static rule — no guessing."""
    src = (
        "from jax.experimental import pallas as pl\n"
        "def f(bm, bn):\n"
        "    return pl.BlockSpec((bm, bn), lambda i, j: (i, j))\n"
    )
    assert analyze_source(src) == []


def test_folded_constant_tile_flagged():
    """One-step constant folding sees through ``bn = 64``."""
    src = (
        "from jax.experimental import pallas as pl\n"
        "def f():\n"
        "    bn = 64\n"
        "    return pl.BlockSpec((8, bn), lambda i, j: (i, j))\n"
    )
    assert [f.rule for f in analyze_source(src)] == ["pallas-tile"]


def test_eval_shape_literal_seed_exempt():
    src = (
        "import jax\n"
        "def shapes(fn):\n"
        "    return jax.eval_shape(lambda: fn(jax.random.PRNGKey(0)))\n"
    )
    assert analyze_source(src) == []


def test_branch_consumers_not_double_counted():
    """Consumers on exclusive if/else branches are not sequential."""
    src = (
        "import jax\n"
        "def f(key, mode, shape):\n"
        "    if mode == 'n':\n"
        "        return jax.random.normal(key, shape)\n"
        "    else:\n"
        "        return jax.random.uniform(key, shape)\n"
    )
    assert analyze_source(src) == []


def test_syntax_error_reported_as_finding():
    fs = analyze_source("def f(:\n", path="broken.py")
    assert len(fs) == 1 and fs[0].rule == "syntax-error"


# ---------------------------------------------------------------------------
# suppression + baseline
# ---------------------------------------------------------------------------

def test_inline_suppression_drops_finding():
    src = (
        "def tile(m, bm):\n"
        "    assert m % bm == 0  # repro: ignore[bare-assert]\n"
        "    return m // bm\n"
    )
    assert analyze_source(src) == []


def test_suppression_is_rule_scoped():
    src = (
        "def tile(m, bm):\n"
        "    assert m % bm == 0  # repro: ignore[silent-except]\n"
        "    return m // bm\n"
    )
    assert [f.rule for f in analyze_source(src)] == ["bare-assert"]


def test_baseline_roundtrip_and_line_insensitivity(tmp_path):
    fs = analyze_file(_fix("bad_key_reuse.py"))
    p = str(tmp_path / "baseline.json")
    Baseline.write(p, fs)
    bl = Baseline.load(p)
    assert bl.filter(fs) == []
    # identity ignores line numbers: an edit above the finding moves it
    moved = [Finding(f.rule, f.path, f.line + 7, f.msg) for f in fs]
    assert bl.filter(moved) == []
    # a different finding is not covered
    other = [Finding("bare-assert", "x.py", 1, "msg")]
    assert bl.filter(other) == other


def test_missing_baseline_is_empty():
    bl = Baseline.load("/nonexistent/baseline.json")
    assert len(bl) == 0


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def _cli(*args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "analyze.py"), *args],
        capture_output=True, text=True, env=env, cwd=ROOT)


def test_cli_ci_green_on_shipped_tree():
    """tools/analyze.py --ci must pass on the committed tree + baseline
    (lint of src/repro plus the static contract suite)."""
    r = _cli("--ci")
    assert r.returncode == 0, r.stdout + r.stderr


def test_cli_exits_nonzero_on_bad_fixture():
    r = _cli("--ci", _fix("bad_key_reuse.py"))
    assert r.returncode == 1
    assert "prng-reuse" in r.stdout


def test_cli_baseline_gates(tmp_path):
    p = str(tmp_path / "bl.json")
    fs = analyze_file(_fix("bad_key_reuse.py"))
    Baseline.write(p, fs)
    # static contracts still run under --ci; restrict via --contracts none
    r = _cli("--ci", "--contracts", "none", "--baseline", p,
             _fix("bad_key_reuse.py"))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "baselined" in r.stdout


def test_cli_list_rules():
    r = _cli("--list-rules")
    assert r.returncode == 0
    assert set(r.stdout.split()) == set(rule_ids())


# ---------------------------------------------------------------------------
# compile contracts (static level)
# ---------------------------------------------------------------------------

def test_repo_static_contracts_hold():
    for c in static_contracts():
        assert check_contract(c, "static") == [], c.name


def test_contract_detects_budget_violation():
    """A deliberately wrong declaration must produce findings — the
    checker is itself checked (see also the canary in test_sweep.py)."""
    base = {c.name: c for c in static_contracts()}
    wrong = base["sweep/alpha-axis-one-group"]
    import dataclasses

    v = check_contract(
        dataclasses.replace(wrong, max_groups=0), "static")
    assert len(v) == 1 and "budget is 0" in v[0].msg

    v = check_contract(
        dataclasses.replace(wrong, require_dynamic=("nope.field",)), "static")
    assert len(v) == 1 and "nope.field" in v[0].msg

    v = check_contract(
        dataclasses.replace(wrong, expect_dynamic=((),)), "static")
    assert len(v) == 1 and "allowed sets" in v[0].msg

    v = check_contract(
        dataclasses.replace(wrong, min_groups=5), "static")
    assert len(v) == 1 and "at least 5" in v[0].msg


def test_contract_findings_are_findings():
    import dataclasses

    wrong = dataclasses.replace(static_contracts()[0], max_groups=0)
    (f,) = check_contract(wrong, "static")
    assert f.rule == "compile-contract"
    assert f.path == f"contract {wrong.name!r}"
    assert f.line == 0
