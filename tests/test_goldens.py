"""Golden-value regression tests: smoke-grid sweep outputs frozen as
checked-in JSON, asserted bit-stable across refactors.

The sweeps are the benchmark grids of ``fig8_9_cell_errors``,
``fig15_16_adc``, ``fig19_parasitics``, ``hetero_precision``, and
``driftbench`` reduced to the smoke protocol (one programming trial per
point), evaluated fresh (no on-disk cache) on the trained MLP vehicle
(``benchmarks/common``) — the ``hetero`` and ``drift`` grids run on the
committed trained smoke LM (``benchmarks/_cache/lm_qwen1_5-4b_0.npz``),
``hetero`` through the heterogeneous profile serve path and ``drift``
through the traced drift-horizon × nu aging path.  Every floating-point accuracy must
match the golden file *exactly*: the engine is deterministic given
(weights, seeds, platform, jax version), so any drift is a behaviour
change — either a bug, or an intentional numerics change that must be
made visible by regenerating the goldens.

Update procedure (after an INTENTIONAL numerics change, with the reason
in the commit message)::

    PYTHONPATH=src python tests/test_goldens.py --regen

Goldens live in ``tests/goldens/`` and are version-scoped: the file
records the jax version it was generated under; a different installed
major/minor jax version skips the exact comparison instead of failing
(last-ULP float changes between jax releases are not our regressions).
"""

import dataclasses
import json
import os
import sys

import jax
import pytest

# the benchmark grids live in the top-level ``benchmarks`` package; make
# it importable regardless of how this module was invoked (pytest from
# the repo root, or ``python tests/test_goldens.py --regen``)
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _REPO_ROOT not in sys.path:
    sys.path.insert(0, _REPO_ROOT)

from repro.sweep import run_sweep

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "goldens")


def _mlp_evaluator():
    from benchmarks.common import mlp_evaluator

    return mlp_evaluator()


def _lm_evaluator():
    from benchmarks.lm_accuracy import lm_evaluator

    return lm_evaluator()


def _smoke_sweeps():
    """(name, (SweepSpec, evaluator factory)) per golden grid, at one
    trial per point."""
    from benchmarks.driftbench import drift_sweep
    from benchmarks.fig8_9_cell_errors import (
        ALPHAS_IND, ALPHAS_PROP, fig_sweep)
    from benchmarks.fig15_16_adc import fig15_sweep, fig16_sweep
    from benchmarks.fig19_parasitics import fig19_sweep
    from benchmarks.hetero_precision import hetero_sweep
    from repro.core.errors import state_independent, state_proportional

    sweeps = [
        (fig_sweep("fig8", state_independent, ALPHAS_IND), _mlp_evaluator),
        (fig_sweep("fig9", state_proportional, ALPHAS_PROP), _mlp_evaluator),
        (fig15_sweep(), _mlp_evaluator),
        (fig16_sweep(), _mlp_evaluator),
        # thinned Fig. 19 grid: pins the traced-r_hat bit-line solve path
        # (scheme x r_hat, one compile group per scheme) bit-stable
        (fig19_sweep((1e-4, 1e-3), test_n=64), _mlp_evaluator),
        # heterogeneous per-site profile grid on the committed trained LM:
        # pins the profile resolver -> per-site program -> calibrate ->
        # serve -> decode chain bit-stable (tag "hetero")
        (dataclasses.replace(hetero_sweep(smoke=True), name="hetero"),
         _lm_evaluator),
        # drift horizon x nu grid on the committed trained LM: pins the
        # traced drift/fault aging path bit-stable, with the t=1 point
        # doubling as the fresh-age bit-identity anchor (tag "drift")
        (dataclasses.replace(drift_sweep(smoke=True), name="drift"),
         _lm_evaluator),
    ]
    return [
        (s.name,
         (dataclasses.replace(s, name=f"golden_{s.name}", trials=1), ev))
        for s, ev in sweeps
    ]


def _compute(sweep, evaluator_factory):
    res = run_sweep(sweep, evaluator_factory())    # fresh, no disk cache
    return {r.tag: r.values for r in res}


def _golden_path(name):
    return os.path.join(GOLDEN_DIR, f"{name}_smoke.json")


def _jax_minor(version):
    return ".".join(version.split(".")[:2])


@pytest.mark.parametrize("name", ["fig8", "fig9", "fig15", "fig16",
                                  "fig19", "hetero", "drift"])
def test_smoke_grid_matches_golden(name):
    path = _golden_path(name)
    assert os.path.exists(path), (
        f"missing golden {path}; generate with "
        f"`PYTHONPATH=src python tests/test_goldens.py --regen`")
    with open(path) as f:
        golden = json.load(f)
    if _jax_minor(golden["jax_version"]) != _jax_minor(jax.__version__):
        pytest.skip(f"golden generated under jax {golden['jax_version']}, "
                    f"running {jax.__version__}: exact comparison is only "
                    f"meaningful within one jax minor version")
    sweep, ev = dict(_smoke_sweeps())[name]
    values = _compute(sweep, ev)
    assert set(values) == set(golden["points"]), (
        "design-point table changed; regenerate goldens if intentional")
    for tag, vals in values.items():
        assert vals == golden["points"][tag], (
            f"{name}:{tag} drifted from golden: {vals} != "
            f"{golden['points'][tag]} (bit-stability regression, or an "
            f"intentional numerics change needing --regen)")


def regen():
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for name, (sweep, ev) in _smoke_sweeps():
        payload = {
            "jax_version": jax.__version__,
            "protocol": sweep.point_protocol(),
            "points": _compute(sweep, ev),
        }
        path = _golden_path(name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"wrote {path} ({len(payload['points'])} points)")


if __name__ == "__main__":
    if "--regen" in sys.argv:
        regen()
    else:
        print(__doc__)
