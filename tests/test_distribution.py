"""Distribution tests on an 8-device host mesh (subprocess: the main test
process must keep 1 device for everything else).

Covers: sharded train step == single-device numerics, dry-run lowering on
the debug mesh for representative archs, compressed int8 ring all-reduce
correctness under shard_map, sharding-rule divisibility fallbacks.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_in_subprocess(body: str) -> str:
    code = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import jax.numpy as jnp
        import numpy as np
        """
    ) + textwrap.dedent(body)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=600)
    # include the stdout tail: the subprocess bodies print their diagnostics
    # (LOSS1/LOSS2, WORST, ...) to stdout before the failing assert, and a
    # bare AssertionError traceback in stderr is useless without them
    assert out.returncode == 0, (
        f"stdout tail:\n{out.stdout[-2000:]}\nstderr tail:\n{out.stderr[-4000:]}"
    )
    return out.stdout


def test_sharded_train_step_matches_single_device():
    out = run_in_subprocess("""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import build_train_step
        from repro.config import ShapeConfig
        from repro.train.step import make_train_state, train_step_fn
        from repro.data.synthetic import SyntheticLM

        cfg = get_smoke_config("qwen3-14b")
        shape = ShapeConfig("t", 32, 8, "train")
        ds = SyntheticLM(cfg=cfg, seq_len=32, global_batch=8, seed=0)
        batch = ds.batch(0)

        # single device
        state1 = make_train_state(cfg, jax.random.PRNGKey(0))
        step1 = train_step_fn(cfg, microbatches=2)
        state1, m1 = jax.jit(step1)(state1, batch)

        # 8-device mesh
        mesh = make_debug_mesh(2, 4)
        with mesh:
            jitted, _ = build_train_step(cfg, mesh, shape, microbatches=2)
            state2 = make_train_state(cfg, jax.random.PRNGKey(0))
            state2, m2 = jitted(state2, batch)
        print("LOSS1", float(m1["loss"]), "LOSS2", float(m2["loss"]))
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
        # parameters after one step agree
        import numpy as np
        d1 = jax.tree.leaves(state1.params)
        d2 = jax.tree.leaves(state2.params)
        worst = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(d1, d2))
        print("WORST", worst)
        assert worst < 5e-3, worst
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.parametrize("arch", ["gemma-2b", "arctic-480b", "zamba2-7b",
                                  "rwkv6-3b", "whisper-large-v3"])
def test_debug_mesh_lowering_all_kinds(arch):
    out = run_in_subprocess(f"""
        from repro.configs import get_smoke_config
        from repro.launch.mesh import make_debug_mesh
        from repro.launch.steps import build_step
        from repro.config import ShapeConfig

        cfg = get_smoke_config("{arch}")
        mesh = make_debug_mesh(2, 4)
        for kind in ("train", "prefill", "decode"):
            sh = ShapeConfig(kind, 32, 8, kind)
            with mesh:
                jitted, structs = build_step(
                    cfg, mesh, sh,
                    **({{"microbatches": 2}} if kind == "train" else {{}}))
                compiled = jitted.lower(*structs).compile()
            assert compiled.cost_analysis() is not None
            print(kind, "OK")
        print("ALL_OK")
    """)
    assert "ALL_OK" in out


def test_int8_ring_allreduce_matches_psum():
    out = run_in_subprocess("""
        from functools import partial
        from jax.sharding import PartitionSpec as P
        from repro.launch.mesh import make_debug_mesh
        from repro.optim.compress import ring_allreduce_int8, _quant_int8

        # jax >= 0.5 exposes jax.shard_map (check_vma); 0.4.x has it under
        # jax.experimental with the older check_rep spelling
        if hasattr(jax, "shard_map"):
            shard_map, check = jax.shard_map, {"check_vma": False}
        else:
            from jax.experimental.shard_map import shard_map
            check = {"check_rep": False}

        mesh = make_debug_mesh(8, 1)
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))

        @partial(shard_map, mesh=mesh, in_specs=P("data"),
                 out_specs=P("data"), **check)
        def ring(x):
            q, s = _quant_int8(x)
            return ring_allreduce_int8(q, s, "data")

        got = ring(x)[0]
        want = jnp.mean(x, axis=0)
        err = float(jnp.max(jnp.abs(got - want)))
        rel = err / float(jnp.max(jnp.abs(want)))
        print("REL", rel)
        assert rel < 0.05  # int8 wire quantization tolerance
        print("OK")
    """)
    assert "OK" in out


def test_sharding_rules_divisibility_fallbacks():
    out = run_in_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.launch.mesh import make_debug_mesh
        from repro.sharding.rules import param_spec, _FakePath

        mesh = make_debug_mesh(2, 4)
        cfg = get_config("gemma-2b")
        # ff divisible by 4 -> model sharded.  A single dp axis is emitted
        # as the bare name; jax 0.4.x does not normalize P(("data",)) to
        # P("data"), so compare against the emitted spelling.
        spec = param_spec(cfg, _FakePath(["layers", "mlp", "w_up"]),
                          (18, 2048, 16384), mesh)
        assert spec == P(None, "data", "model"), spec
        # vocab 256000 % 4 == 0 -> model sharded
        spec = param_spec(cfg, _FakePath(["embed"]), (256000, 2048), mesh)
        assert spec == P("model", "data"), spec
        # odd vocab falls back to replication on that dim
        cfg2 = get_config("internvl2-26b")
        spec = param_spec(cfg2, _FakePath(["embed"]), (92553, 6144), mesh)
        assert spec[0] is None, spec
        # norm scales replicate
        spec = param_spec(cfg, _FakePath(["layers", "norm1", "scale"]),
                          (18, 2048), mesh)
        assert spec == P(), spec
        print("OK")
    """)
    assert "OK" in out
