"""Serve-engine unit tests (tier-1, no training): PRNG-key determinism of
``program_lm`` and the batched greedy decode loop.

The key-assignment regression: programming keys are folded from a stable
per-hook name hash (``serve.analog_engine.hook_key``), never from a
running counter — adding or removing a projection must not reshuffle any
other layer's programming noise."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.models import transformer
from repro.models.registry import get_model
from repro.serve.analog_engine import (
    decode_lm,
    lm_program_codes,
    program_lm,
    program_lm_from_codes,
)


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


SPEC = A.design_a(error=E.state_independent(0.05))
KEY = jax.random.PRNGKey(5)


def _drop(params, parent, leaf):
    """Copy of ``params`` without one projection leaf."""
    layers = dict(params["layers"])
    layers[parent] = {k: v for k, v in layers[parent].items() if k != leaf}
    return {**params, "layers": layers}


def test_program_lm_is_deterministic(lm):
    cfg, params = lm
    p1 = program_lm(cfg, params, SPEC, KEY)
    p2 = program_lm(cfg, params, SPEC, KEY)
    for name in p1.layer_weights:
        np.testing.assert_array_equal(
            np.asarray(p1.layer_weights[name].g_pos),
            np.asarray(p2.layer_weights[name].g_pos))
    np.testing.assert_array_equal(np.asarray(p1.head.g_pos),
                                  np.asarray(p2.head.g_pos))


def test_hook_keys_stable_under_projection_removal(lm):
    """Removing a projection must not change any other hook's noise."""
    cfg, params = lm
    full = program_lm(cfg, params, SPEC, KEY)
    sub = program_lm(cfg, _drop(params, "mlp", "w_up"), SPEC, KEY)
    assert "w_up" in full.layer_weights and "w_up" not in sub.layer_weights
    for name in sub.layer_weights:
        np.testing.assert_array_equal(
            np.asarray(full.layer_weights[name].g_pos),
            np.asarray(sub.layer_weights[name].g_pos),
            err_msg=f"{name} reprogrammed after unrelated hook removal")
    np.testing.assert_array_equal(np.asarray(full.head.g_pos),
                                  np.asarray(sub.head.g_pos))


def test_head_key_independent_of_layer_hooks(lm):
    cfg, params = lm
    with_head = program_lm(cfg, params, SPEC, KEY, include_head=True)
    only_head = program_lm(cfg, _drop(_drop(params, "attn", "wq"),
                                      "mlp", "w_gate"),
                           SPEC, KEY, include_head=True)
    np.testing.assert_array_equal(np.asarray(with_head.head.g_pos),
                                  np.asarray(only_head.head.g_pos))


def test_program_lm_codes_split_identity(lm):
    """program_lm == program_lm_from_codes ∘ lm_program_codes, the
    contract the ServeEvaluator's pack cache rests on."""
    cfg, params = lm
    direct = program_lm(cfg, params, SPEC, KEY)
    split = program_lm_from_codes(
        cfg, lm_program_codes(cfg, params, SPEC), SPEC, KEY)
    for name in direct.layer_weights:
        for field in ("g_pos", "g_neg"):
            a = getattr(direct.layer_weights[name], field)
            b = getattr(split.layer_weights[name], field)
            if a is None:
                assert b is None
            else:
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_greedy_decode_matches_eager_loop(lm):
    """The scanned decode loop reproduces the step-by-step eager path."""
    cfg, params = lm
    prompts = jnp.arange(2 * 6, dtype=jnp.int32).reshape(2, 6) % cfg.vocab
    n_new = 5
    fast = decode_lm(cfg, params, prompts, n_new, pack=None)

    logits, cache = transformer.prefill(cfg, params, prompts, 6 + n_new)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    slow = []
    for _ in range(n_new):
        slow.append(tok)
        logits, cache = transformer.decode_step(cfg, params, tok[:, None],
                                                cache)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(fast),
                                  np.stack([np.asarray(t) for t in slow], 1))


def test_greedy_decode_through_analog_pack(lm):
    cfg, params = lm
    from repro.data.synthetic import SyntheticLM
    from repro.serve.analog_engine import calibrate_lm

    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    pack = program_lm(cfg, params, A.design_a(), KEY)
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    toks = decode_lm(cfg, params, ds.batch(2)["tokens"][:3, :8], 4, pack=pack)
    assert toks.shape == (3, 4)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))
