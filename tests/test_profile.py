"""Heterogeneous-profile tests (tier-1): resolver semantics, the
uniform-profile == global-spec bit-identity contract (codes, programming
noise, calibration ranges, decode tokens), the per-site serial reference
for a heterogeneous 2-class profile, layer-band scan splitting, the
profile sweep-axis/compile-group composition, the continuous-batching
runtime agreement over a mixed pack, and the ValueError validation /
dispatch-fallback satellites."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec, program_codes, program_from_codes
from repro.core.errors import ErrorModel
from repro.core.mapping import MappingConfig
from repro.data.synthetic import SyntheticLM
from repro.hw import DIGITAL, Profile, Rule, as_profile
from repro.models.registry import get_model
from repro.serve.analog_engine import (
    HEAD,
    calibrate_lm,
    decode_lm,
    hook_key,
    lm_program_codes,
    program_lm,
    program_lm_from_codes,
)
from repro.sweep.spec import get_field, set_field

SPEC8 = A.design_a(error=E.state_proportional(0.05))
SPEC6 = dataclasses.replace(SPEC8, adc=dataclasses.replace(SPEC8.adc, bits=6))
KEY = jax.random.PRNGKey(5)


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    return cfg, params, ds


# ---------------------------------------------------------------------------
# resolver semantics
# ---------------------------------------------------------------------------

def test_resolver_patterns_and_fallback():
    p = Profile(rules=(
        Rule("wq", SPEC6),                  # exact site name
        Rule("attn.*", SPEC8),              # class-qualified glob
        Rule("mlp", SPEC8),                 # bare class
        Rule("head", DIGITAL),
    ), default=DIGITAL)
    assert p.resolve("wq") is SPEC6         # first match wins
    assert p.resolve("wk") is SPEC8
    assert p.resolve("w_down") is SPEC8
    assert p.resolve(HEAD) == DIGITAL
    assert p.resolve("rwkv_wr") == DIGITAL  # unmatched -> default
    assert p.is_digital("rwkv_wr") and not p.is_digital("wq")


def test_resolver_layer_bands():
    p = Profile(rules=(
        Rule("attn.*", SPEC8, layers=(0, 2)),
        Rule("attn.*", SPEC6, layers=(2, 4)),
        Rule("mlp.*", SPEC8),
    ))
    assert p.resolve("wq", 1) is SPEC8
    assert p.resolve("wq", 2) is SPEC6
    assert p.resolve("wq") == DIGITAL       # band rules need a layer index
    sites = ["wq", "w_up"]
    assert p.layer_bands(sites, 4) == ((0, 2), (2, 4))
    assert Profile.uniform(SPEC8).layer_bands(sites, 4) == ((0, 4),)
    assert p.first_analog("wq", 4) is SPEC8


def test_profile_validation_and_as_profile():
    with pytest.raises(ValueError, match="AnalogSpec or the string"):
        Profile(rules=(Rule("wq", "analog"),))
    with pytest.raises(ValueError, match="half-open band"):
        Rule("wq", SPEC8, layers=(3, 3))
    with pytest.raises(ValueError, match="expects an AnalogSpec"):
        Profile.uniform(DIGITAL)
    with pytest.raises(ValueError, match="AnalogSpec or hw.Profile"):
        as_profile("nope")
    assert as_profile(SPEC8).resolve("wq") is SPEC8
    assert as_profile(Profile.uniform(SPEC8)).resolve("head") is SPEC8


def test_with_field_and_sweep_set_field():
    p = Profile.by_class(attn=SPEC8, mlp=SPEC8, head=DIGITAL)
    q = set_field(p, "mlp:adc.bits", 6)
    assert get_field(q, "mlp:adc.bits") == 6
    assert get_field(q, "attn:adc.bits") == 8
    assert q.signature() != p.signature()
    assert set_field(q, "mlp:adc.bits", 8).signature() == p.signature()
    u = Profile.uniform(SPEC8)
    assert get_field(set_field(u, "default:error.alpha", 0.1),
                     "default:error.alpha") == pytest.approx(0.1)
    with pytest.raises(ValueError, match="no profile rule answers"):
        p.with_field("ssm", "adc.bits", 6)
    with pytest.raises(ValueError, match="cannot set"):
        p.with_field("head", "adc.bits", 6)     # head rule is digital
    with pytest.raises(ValueError, match="selector"):
        set_field(p, "adc.bits", 6)             # missing selector


# ---------------------------------------------------------------------------
# uniform profile == global spec (the bit-identity contract)
# ---------------------------------------------------------------------------

def _pack_arrays(pack):
    out = {}
    for name, aw in pack.layer_weights.items():
        out[f"{name}.g_pos"] = np.asarray(aw.g_pos)
        if aw.g_neg is not None:
            out[f"{name}.g_neg"] = np.asarray(aw.g_neg)
        if aw.g_unit is not None:
            out[f"{name}.g_unit"] = np.asarray(aw.g_unit)
    if pack.head is not None:
        out["head.g_pos"] = np.asarray(pack.head.g_pos)
    return out


def _assert_packs_equal(pa, pb):
    a, b = _pack_arrays(pa), _pack_arrays(pb)
    assert set(a) == set(b)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=k)
    for name in pa.layer_lo:
        np.testing.assert_array_equal(np.asarray(pa.layer_lo[name]),
                                      np.asarray(pb.layer_lo[name]))
        np.testing.assert_array_equal(np.asarray(pa.layer_hi[name]),
                                      np.asarray(pb.layer_hi[name]))
    np.testing.assert_array_equal(np.asarray(pa.head_lo),
                                  np.asarray(pb.head_lo))
    np.testing.assert_array_equal(np.asarray(pa.head_hi),
                                  np.asarray(pb.head_hi))


def _full_chain(cfg, params, ds, spec_like):
    """codes -> pack -> calibrated pack -> greedy decode tokens."""
    codes = lm_program_codes(cfg, params, spec_like)
    pack = program_lm_from_codes(cfg, codes, spec_like, KEY)
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    toks = decode_lm(cfg, params, ds.batch(2)["tokens"][:2, :6], 3, pack=pack)
    return codes, pack, np.asarray(toks)


def test_uniform_profile_bit_identical_fixed_specs(lm):
    """Uniform Profile == global AnalogSpec across representative specs:
    identical codes, programming noise, calibration ranges, decode."""
    cfg, params, ds = lm
    specs = [
        SPEC8,
        A.design_e(error=E.state_independent(0.03)),
        AnalogSpec(mapping=MappingConfig(scheme="differential",
                                         bits_per_cell=2, on_off_ratio=100.0),
                   adc=ADCConfig(style="none"),
                   error=E.state_proportional(0.05), max_rows=40),
    ]
    for spec in specs:
        c1, p1, t1 = _full_chain(cfg, params, ds, spec)
        c2, p2, t2 = _full_chain(cfg, params, ds, Profile.uniform(spec))
        assert set(c1) == set(c2)
        for name in c1:
            np.testing.assert_array_equal(np.asarray(c1[name].codes.c_pos),
                                          np.asarray(c2[name].codes.c_pos))
        _assert_packs_equal(p1, p2)
        np.testing.assert_array_equal(t1, t2)


try:                                    # hypothesis is dev-only; keep the
    import hypothesis  # noqa: F401     # rest of this module collectable
    _HAVE_HYPOTHESIS = True
except ImportError:
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    from hypothesis import given, settings

    from test_properties import analog_specs

    @given(spec=analog_specs())
    @settings(max_examples=5, deadline=None)
    def test_uniform_profile_bit_identical_property(spec, lm):
        """The whole-design-space version of the contract: ANY valid
        spec, wrapped uniformly, reproduces the global-spec pack
        bit-exactly (codes, noise, calibration ranges, decode)."""
        cfg, params, ds = lm
        c1, p1, t1 = _full_chain(cfg, params, ds, spec)
        c2, p2, t2 = _full_chain(cfg, params, ds, Profile.uniform(spec))
        for name in c1:
            np.testing.assert_array_equal(np.asarray(c1[name].codes.c_pos),
                                          np.asarray(c2[name].codes.c_pos))
        _assert_packs_equal(p1, p2)
        np.testing.assert_array_equal(t1, t2)


# ---------------------------------------------------------------------------
# heterogeneous 2-class profile vs the per-site serial reference
# ---------------------------------------------------------------------------

def test_hetero_two_class_matches_per_site_reference(lm):
    """attn on 8-bit arrays, mlp on 6-bit arrays, head digital: every
    site's programmed stack must equal programming that site alone with
    its own spec and the same hook-keyed schedule."""
    cfg, params, ds = lm
    profile = Profile.by_class(attn=SPEC8, mlp=SPEC6, head=DIGITAL)
    pack = program_lm(cfg, params, profile, KEY)
    assert pack.head is None and pack.head_spec is None
    assert pack.bands == ((0, cfg.n_layers),)

    site_spec = {"wq": SPEC8, "wk": SPEC8, "wv": SPEC8, "wo": SPEC8,
                 "w_gate": SPEC6, "w_up": SPEC6, "w_down": SPEC6}
    assert set(pack.layer_weights) == set(site_spec)
    groups = {"wq": ("attn", "wq"), "wk": ("attn", "wk"),
              "wv": ("attn", "wv"), "wo": ("attn", "wo"),
              "w_gate": ("mlp", "w_gate"), "w_up": ("mlp", "w_up"),
              "w_down": ("mlp", "w_down")}
    for name, (parent, leaf) in groups.items():
        spec = site_spec[name]
        w_stack = params["layers"][parent][leaf].astype(jnp.float32)
        pms = jax.vmap(lambda w: program_codes(w, spec))(w_stack)
        hk = hook_key(KEY, name)
        keys = jnp.stack([jax.random.fold_in(hk, i)
                          for i in range(cfg.n_layers)])
        ref = jax.vmap(lambda c, k: program_from_codes(c, spec, k))(pms, keys)
        np.testing.assert_array_equal(
            np.asarray(pack.layer_weights[name].g_pos), np.asarray(ref.g_pos),
            err_msg=f"{name} differs from the per-site serial reference")
    # the serving context resolves per site
    assert pack.site_spec("wq").adc.bits == 8
    assert pack.site_spec("w_up").adc.bits == 6

    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    toks = decode_lm(cfg, params, ds.batch(2)["tokens"][:2, :6], 3, pack=pack)
    assert toks.shape == (2, 3)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))


# ---------------------------------------------------------------------------
# layer bands
# ---------------------------------------------------------------------------

def test_two_band_profile_of_one_spec_equals_single_band(lm):
    """Splitting the scan at an artificial band boundary must not change
    a single numeric: two bands of the SAME spec == the uniform path."""
    cfg, params, ds = lm
    l = cfg.n_layers
    assert l >= 2, "band test needs >= 2 layers"
    # band rules only see layer sites; the default serves the head
    two = Profile(rules=(Rule("*", SPEC8, layers=(0, 1)),
                         Rule("*", SPEC8, layers=(1, l))), default=SPEC8)
    _, p1, t1 = _full_chain(cfg, params, ds, Profile.uniform(SPEC8))
    _, p2, t2 = _full_chain(cfg, params, ds, two)
    assert p2.bands == ((0, 1), (1, l))
    _assert_packs_equal(p1, p2)
    np.testing.assert_array_equal(t1, t2)


def test_banded_mixed_precision_and_digital_band(lm):
    """A depth-banded profile (8-bit early layers, 6-bit late; MLP digital
    in the first band) programs, calibrates, and serves."""
    cfg, params, ds = lm
    l = cfg.n_layers
    profile = Profile(rules=(
        Rule("attn.*", SPEC8, layers=(0, 1)),
        Rule("attn.*", SPEC6, layers=(1, l)),
        Rule("mlp.*", SPEC6, layers=(1, l)),   # digital in band [0, 1)
        Rule("head", DIGITAL),
    ))
    pack = program_lm(cfg, params, profile, KEY)
    assert pack.bands == ((0, 1), (1, l))
    assert "w_up" not in pack.band_specs[0]
    assert pack.band_specs[1].spec_for("w_up").adc.bits == 6
    assert pack.band_specs[0].spec_for("wq").adc.bits == 8
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    toks = decode_lm(cfg, params, ds.batch(2)["tokens"][:2, :6], 3, pack=pack)
    assert bool(jnp.all((toks >= 0) & (toks < cfg.vocab)))


def test_band_geometry_mismatch_rejected(lm):
    """Bands may vary ADC/error fields but not array geometry (a site's
    conductance stack is ONE scanned array)."""
    cfg, params, _ = lm
    l = cfg.n_layers
    narrow = dataclasses.replace(SPEC8, max_rows=32)
    profile = Profile(rules=(
        Rule("attn.*", SPEC8, layers=(0, 1)),
        Rule("attn.*", narrow, layers=(1, l)),
        Rule("mlp.*", SPEC8),
    ))
    with pytest.raises(ValueError, match="array\\s+geometry"):
        program_lm(cfg, params, profile, KEY)


def test_all_digital_profile_rejected(lm):
    cfg, params, _ = lm
    with pytest.raises(ValueError, match="digital"):
        lm_program_codes(cfg, params, Profile(rules=(), default=DIGITAL))


# ---------------------------------------------------------------------------
# sweep composition: per-site-class axes, compile groups, codes cache
# ---------------------------------------------------------------------------

def test_hetero_grid_compile_groups(lm):
    """attn-bits x mlp-bits x alpha: compile groups == profile
    signatures (one per (attn, mlp) bits cell, <= one per signature),
    with the cell-error axis batched as a traced scalar inside each.
    Declared as a CompileContract (repro.analysis)."""
    from repro.analysis import CompileContract, check_contract
    from repro.sweep import Axis, ServeEvaluator, SweepSpec

    cfg, params, ds = lm
    ev = ServeEvaluator(cfg, params, ds.batch(998)["tokens"],
                        ds.batch(999)["tokens"], ds.batch(999)["targets"])
    sweep = SweepSpec(
        name="t",
        base=Profile.by_class(attn=SPEC8, mlp=SPEC8, head=DIGITAL),
        axes=(Axis("attn:adc.bits", (6, 8)),
              Axis("mlp:adc.bits", (6, 8)),
              Axis("attn:error.alpha", (0.02, 0.05))),
        trials=1,
    )
    pts = sweep.expand()
    assert len(pts) == 8
    sigs = {set_field(p.spec, "attn:error.alpha", 0.0).signature()
            for p in pts}
    assert len(sigs) == 4
    c = CompileContract(
        name="test/hetero-grid",
        sweep=sweep,
        evaluator=lambda: ev,
        max_groups=len(sigs), min_groups=len(sigs),
        expect_dynamic=(("attn:error.alpha",),),
        require_dynamic=("attn:error.alpha",),
    )
    assert check_contract(c, "static") == []
    # codes shared across ADC-bit cells (mapping-identical), per-site keyed
    k1 = ev._codes_key(pts[0].spec)
    assert all(ev._codes_key(p.spec) == k1 for p in pts)
    assert "head=digital" in k1 and "wq=differential" in k1


def test_benchmark_sweep_one_group_per_signature(lm):
    """The shipped hetero_precision grid: every point is its own profile
    signature and the whole grid compiles in exactly that many groups."""
    import os
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    if root not in sys.path:
        sys.path.insert(0, root)
    from benchmarks.hetero_precision import hetero_sweep

    from repro.analysis import CompileContract, check_contract
    from repro.sweep import ServeEvaluator

    cfg, params, ds = lm
    ev = ServeEvaluator(cfg, params, ds.batch(998)["tokens"],
                        ds.batch(999)["tokens"], ds.batch(999)["targets"])
    sweep = hetero_sweep()
    pts = sweep.expand()
    n = len({p.spec.signature() for p in pts})
    assert n == len(pts)
    c = CompileContract(
        name="test/benchmark-hetero",
        sweep=sweep,
        evaluator=lambda: ev,
        max_groups=n, min_groups=n,
    )
    assert check_contract(c, "static") == []


def test_codes_key_head_resolution_matches_program_path(lm):
    """The codes-cache key must classify the head exactly like
    lm_program_codes (resolve at layer=None): a banded-rules profile
    whose head falls to a digital default must not share a key with an
    analog-head profile (regression: cache poisoning)."""
    from repro.sweep import ServeEvaluator

    cfg, params, ds = lm
    ev = ServeEvaluator(cfg, params, ds.batch(998)["tokens"],
                        ds.batch(999)["tokens"], ds.batch(999)["targets"])
    l = cfg.n_layers
    banded = Profile(rules=(Rule("*", SPEC8, layers=(0, l)),),
                     default=DIGITAL)
    uniform = Profile.uniform(SPEC8)
    assert "head=digital" in ev._codes_key(banded)
    assert "head=digital" not in ev._codes_key(uniform)
    assert ev._codes_key(banded) != ev._codes_key(uniform)
    # the keys mirror what lm_program_codes actually builds
    assert HEAD not in lm_program_codes(cfg, params, banded)
    assert HEAD in lm_program_codes(cfg, params, uniform)


# ---------------------------------------------------------------------------
# serving runtime over a heterogeneous pack
# ---------------------------------------------------------------------------

def test_runtime_agreement_heterogeneous_pack(lm):
    """A running ServeRuntime serves a mixed-precision pack unchanged:
    greedy token agreement with per-request decode_lm is exactly 1.0."""
    from repro.sweep.serve_eval import runtime_agreement

    cfg, params, ds = lm
    profile = Profile.by_class(attn=SPEC8, mlp=SPEC6, head=DIGITAL)
    pack = program_lm(cfg, params, profile, KEY)
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    toks = np.asarray(ds.batch(3)["tokens"])
    reqs = [(toks[0, :5], 4), (toks[1, :3], 5), (toks[2, :7], 3)]
    assert runtime_agreement(cfg, params, reqs, pack=pack,
                             max_slots=2, seed=0) == 1.0


# ---------------------------------------------------------------------------
# satellites: ValueError validation + dispatch fallback
# ---------------------------------------------------------------------------

def test_core_validation_value_errors():
    with pytest.raises(ValueError, match="input_accum"):
        AnalogSpec(input_accum="wrong")
    with pytest.raises(ValueError, match="input_bits"):
        AnalogSpec(input_bits=0)
    with pytest.raises(ValueError, match="ErrorModel.kind"):
        ErrorModel(kind="gaussian")
    with pytest.raises(ValueError, match="MappingConfig.scheme"):
        MappingConfig(scheme="dual")
    with pytest.raises(ValueError, match="bits_per_cell"):
        MappingConfig(bits_per_cell=3)
    with pytest.raises(ValueError, match="unit_column"):
        MappingConfig(scheme="differential", unit_column=True)
    with pytest.raises(ValueError, match="ADCConfig.style"):
        ADCConfig(style="sar")


def test_analog_matmul_mismatch_value_error():
    spec = AnalogSpec(adc=ADCConfig(style="none"))
    aw = A.program(jnp.ones((8, 3)), spec)
    with pytest.raises(ValueError, match="depth 7 does not match"):
        A.analog_matmul(jnp.ones((2, 7)), aw, spec)
    with pytest.raises(ValueError, match="2-D"):
        A.program(jnp.ones((2, 3, 4)), spec)
    cal_spec = AnalogSpec(adc=ADCConfig(style="calibrated"))
    aw2 = A.program(jnp.ones((8, 3)), cal_spec)
    with pytest.raises(ValueError, match="calibrated"):
        A.analog_matmul(jnp.ones((2, 8)), aw2, cal_spec)


def test_shard_fallback_returns_inputs_unsharded():
    """When neither the point nor the trial axis divides the mesh, the
    batch is replicated explicitly — the exact input arrays come back."""
    from repro.sweep.dispatch import shard_point_trial_batch

    class _Mesh:
        shape = {"data": 3}

    dyn = jnp.ones((4, 2))
    keys = jnp.zeros((5, 2), jnp.uint32)
    d2, k2 = shard_point_trial_batch(dyn, keys, _Mesh())
    assert d2 is dyn and k2 is keys
