"""End-to-end behaviour tests: train a tiny LM until loss drops, checkpoint
/restore mid-run, then program it onto the analog substrate, calibrate, and
verify the analog model's quality tracks the digital one (the paper's
direct-weight-transfer story on an LM)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.data.synthetic import SyntheticLM
from repro.serve.analog_engine import analog_eval_loss, calibrate_lm, program_lm
from repro.train.step import loss_fn, make_train_state, train_step_fn


@pytest.fixture(scope="module")
def trained_lm():
    cfg = get_smoke_config("qwen1.5-4b")
    ds = SyntheticLM(cfg=cfg, seq_len=32, global_batch=8, seed=0)
    state = make_train_state(cfg, jax.random.PRNGKey(0), lr=3e-3)
    step = jax.jit(train_step_fn(cfg, microbatches=1, lr=3e-3))
    first = None
    for i in range(60):
        state, m = step(state, ds.batch(i))
        if first is None:
            first = float(m["loss"])
    return cfg, ds, state, first, float(m["loss"])


def test_training_reduces_loss(trained_lm):
    cfg, ds, state, first, last = trained_lm
    assert np.isfinite(last)
    assert last < first - 0.5, (first, last)


def test_checkpoint_restart_reproduces_trajectory(trained_lm, tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    cfg, ds, state, *_ = trained_lm
    step = jax.jit(train_step_fn(cfg, microbatches=1, lr=3e-3))
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(60, state)
    # continue 3 steps from live state
    s_live = state
    for i in range(60, 63):
        s_live, m_live = step(s_live, ds.batch(i))
    # restart from checkpoint + deterministic data replay
    s_rest, at_step, _ = mgr.restore(state)
    for i in range(at_step, 63):
        s_rest, m_rest = step(s_rest, ds.batch(i))
    assert abs(float(m_live["loss"]) - float(m_rest["loss"])) < 1e-5


def test_analog_direct_weight_transfer_tracks_digital(trained_lm):
    cfg, ds, state, *_ = trained_lm
    batch = ds.batch(999)
    dig = float(loss_fn(cfg, state.params, batch)[0])

    spec = A.design_a(error=E.sonos())
    pack = program_lm(cfg, state.params, spec, jax.random.PRNGKey(5))
    pack = calibrate_lm(cfg, state.params, pack, ds.batch(998)["tokens"])
    al = float(analog_eval_loss(cfg, state.params, pack,
                                batch["tokens"], batch["targets"]))
    assert np.isfinite(al)
    # direct weight transfer with the recommended design: small penalty
    assert al < dig * 1.35 + 0.2, (dig, al)


def test_analog_offset_design_is_worse(trained_lm):
    """Paper Table 4: the offset/near-FPG design E loses far more."""
    from repro.core.adc import ADCConfig
    from repro.core.mapping import MappingConfig

    cfg, ds, state, *_ = trained_lm
    batch = ds.batch(999)

    spec_a = A.design_a(error=E.state_independent(0.04))
    spec_e = A.AnalogSpec(
        mapping=MappingConfig(scheme="offset", bits_per_cell=2),
        adc=ADCConfig(style="calibrated", bits=8),
        error=E.state_independent(0.04), input_accum="digital", max_rows=72)

    def ppl(spec):
        pack = program_lm(cfg, state.params, spec, jax.random.PRNGKey(5))
        pack = calibrate_lm(cfg, state.params, pack, ds.batch(998)["tokens"])
        return float(analog_eval_loss(cfg, state.params, pack,
                                      batch["tokens"], batch["targets"]))

    la, le = ppl(spec_a), ppl(spec_e)
    dig = float(loss_fn(cfg, state.params, batch)[0])
    assert la - dig < le - dig, (la, le, dig)
