"""Tier-2 differential suite for the end-to-end analog LM serving sweep.

Acceptance contract of the serve path (ISSUE 2):

(a) the vectorized ``ServeEvaluator`` sweep matches a serial
    program → calibrate → eval reference on ≥ 3 design points
    (identical programming noise by the shared key schedule; losses
    equal up to vmap-vs-eager float reassociation, bounded here);
(b) analog loss at the paper's baseline design point (proportional
    mapping, 8-bit calibrated ADC) tracks the digital loss within the
    tolerance ``tests/test_system.py`` uses for direct weight transfer;
(c) serve-sweep results cache on disk and resume without recomputation.

Runs on the trained smoke LM cached by ``benchmarks/lm_accuracy`` (the
same vehicle the benchmark sweeps).  Marked ``tier2``: executed by the
nightly / manual CI job (``RUN_TIER2=1``), skipped in the tier-1 suite.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import analog as A
from repro.core import errors as E
from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import state_proportional
from repro.core.mapping import MappingConfig
from repro.sweep import (
    Axis,
    ServeEvaluator,
    SweepSpec,
    run_sweep,
    serve_serial_reference,
)
from repro.train.step import loss_fn

pytestmark = pytest.mark.tier2

#: loss tolerance between vectorized and serial execution: identical
#: programming noise, but vmapped calibration/eval reassociates float
#: reductions; observed deviations are ~2e-4 relative on the smoke LM.
LOSS_RTOL = 1e-2
#: top1 counts argmax decisions: traced-alpha batching may flip isolated
#: ADC rounding boundaries (same policy as tests/test_sweep.py's
#: calibrated-ADC bound) — allow a few flipped tokens, no more.
TOP1_FLIP_TOKENS = 4
DECODE_NEW = 8
N_DECODED = 4 * DECODE_NEW            # prompts × generated tokens
#: greedy decode can flip a near-tie argmax under such reassociation,
#: and one early flip cascades through the rest of that continuation —
#: allow up to one diverged continuation of the 4 prompts; observed
#: deviation on the smoke LM is 0.
MATCH_ATOL = DECODE_NEW / N_DECODED + 1e-9


@pytest.fixture(scope="module")
def vehicle():
    """(cfg, params, calib tokens, eval batch, prompts) — the trained
    smoke LM shared with ``benchmarks/lm_accuracy``."""
    from benchmarks.lm_accuracy import (
        CALIB_STEP, EVAL_STEP, N_PROMPTS, PROMPT_LEN, trained_lm)

    cfg, ds, params = trained_lm()
    calib = ds.batch(CALIB_STEP)["tokens"]
    ev_batch = ds.batch(EVAL_STEP)
    prompts = ev_batch["tokens"][:N_PROMPTS, :PROMPT_LEN]
    return cfg, params, calib, ev_batch, prompts


def _evaluator(vehicle, **kw):
    cfg, params, calib, ev_batch, prompts = vehicle
    return ServeEvaluator(cfg, params, calib, ev_batch["tokens"],
                          ev_batch["targets"], prompts=prompts,
                          decode_new=DECODE_NEW, **kw)


def _alpha_sweep(name="serve_eq", trials=2):
    return SweepSpec(
        name=name,
        base=AnalogSpec(
            mapping=MappingConfig(on_off_ratio=1e4),
            adc=ADCConfig(style="calibrated", bits=8),
            error=state_proportional(0.0),
            input_accum="analog",
            max_rows=1152,
        ),
        axes=(Axis("error.alpha", (0.02, 0.05, 0.1)),),
        trials=trials,
        seed=7,
    )


def test_vectorized_serve_sweep_matches_serial(vehicle):
    """(a): 3 design points, vectorized == serial, metric by metric."""
    cfg, params, calib, ev_batch, prompts = vehicle
    sweep = _alpha_sweep()
    res = run_sweep(sweep, _evaluator(vehicle))
    pts = sweep.expand()
    assert len(res) == 3
    for r in res:
        ref = serve_serial_reference(
            cfg, params, pts[r.index].spec, calib,
            ev_batch["tokens"], ev_batch["targets"],
            prompts=prompts, decode_new=DECODE_NEW,
            trials=sweep.trials, seed=sweep.seed)
        assert len(r.values) == len(ref)
        n_eval = ev_batch["targets"].size
        for vec, ser in zip(r.values, ref):
            np.testing.assert_allclose(
                vec["loss"], ser["loss"], rtol=LOSS_RTOL, atol=1e-3,
                err_msg=f"{r.tag}:loss")
            np.testing.assert_allclose(
                vec["top1"], ser["top1"],
                atol=TOP1_FLIP_TOKENS / n_eval + 1e-9,
                err_msg=f"{r.tag}:top1")
            np.testing.assert_allclose(
                vec["decode_match"], ser["decode_match"], atol=MATCH_ATOL,
                err_msg=f"{r.tag}:decode_match")


def test_baseline_design_tracks_digital(vehicle):
    """(b): proportional mapping + 8-bit calibrated ADC, the paper's
    recommended design, loses little vs digital (test_system tolerance)."""
    cfg, params, calib, ev_batch, prompts = vehicle
    dig = float(loss_fn(cfg, params, ev_batch)[0])
    sweep = SweepSpec.from_points(
        "serve_baseline",
        [("design_a_sonos", A.design_a(error=E.sonos()))],
        trials=2, seed=7,
    )
    res = run_sweep(sweep, _evaluator(vehicle))
    al = res.metric("design_a_sonos", "loss")
    assert np.isfinite(al)
    # same tolerance as tests/test_system.py's direct-weight-transfer check
    assert al < dig * 1.35 + 0.2, (dig, al)
    # serving-level sanity: greedy decode through the pack mostly agrees
    # with the digital model at the recommended design point
    assert res.metric("design_a_sonos", "decode_match") > 0.5


class _Counting:
    def __init__(self, inner):
        self.inner, self.calls = inner, 0

    def signature(self):
        return self.inner.signature()

    def dynamic_fields(self, spec):
        return self.inner.dynamic_fields(spec)

    def evaluate_group(self, *a, **kw):
        self.calls += 1
        return self.inner.evaluate_group(*a, **kw)


def test_serve_sweep_results_cache_and_resume(vehicle, tmp_path):
    """(c): on-disk cache round-trips dict-valued trials and resumes."""
    ev = _Counting(_evaluator(vehicle))
    sweep = dataclasses.replace(
        _alpha_sweep(name="serve_cache"),
        axes=(Axis("error.alpha", (0.02, 0.1)),), trials=1)
    res1 = run_sweep(sweep, ev, cache_dir=str(tmp_path))
    assert ev.calls == 1 and res1.n_cached == 0
    assert (tmp_path / "sweeps" / "serve_cache.json").exists()

    res2 = run_sweep(sweep, ev, cache_dir=str(tmp_path))
    assert ev.calls == 1, "resume must not recompute"
    assert res2.n_cached == 2
    for r1, r2 in zip(res1, res2):
        assert r1.values == r2.values
        assert r1.metric_mean("loss") == r2.metric_mean("loss")

    # widened grid: only the new point evaluates
    wider = dataclasses.replace(
        sweep, axes=(Axis("error.alpha", (0.02, 0.1, 0.2)),))
    res3 = run_sweep(wider, ev, cache_dir=str(tmp_path))
    assert ev.calls == 2
    assert res3.n_cached == 2 and len(res3) == 3
