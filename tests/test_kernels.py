"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle.

ADC-bearing kernels are quantizers: a float dot product landing within a
few ULPs of an ADC decision boundary may legally flip by one LSB between
two correct implementations (different fp32 accumulation orders).  The
tolerance policy is therefore: (a) the vast majority of outputs match to
float precision, and (b) every output matches within the worst-case
single-boundary-flip impact (one ADC LSB times the largest shift-and-add
weight times the gain).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core.parasitics import bitline_currents


def quantizer_allclose(y_k, y_r, *, flip_atol, tight_rtol=1e-4, frac=0.98):
    y_k, y_r = np.asarray(y_k), np.asarray(y_r)
    np.testing.assert_allclose(y_k, y_r, atol=flip_atol, rtol=0)
    tight = np.isclose(y_k, y_r, rtol=tight_rtol, atol=flip_atol * 1e-3)
    assert tight.mean() >= frac, f"only {tight.mean():.2%} bit-exact"


MVM_SHAPES = [
    (8, 1, 64, 16),
    (32, 2, 96, 40),
    (128, 1, 1152, 256),
    (64, 3, 200, 24),
    (16, 2, 8, 8),
]

# off-tile-boundary shapes: rows/N far from multiples of 128 (ops.py pads
# tiles), single-row partitions, and a tall-skinny output
MVM_EDGE_SHAPES = [
    (4, 1, 1, 8),
    (8, 2, 33, 7),
    (16, 1, 129, 130),
    (8, 4, 72, 3),
]


@pytest.mark.parametrize("m,p,rows,n", MVM_SHAPES)
@pytest.mark.parametrize("adc_bits", [6, 8])
def test_analog_mvm_diff_matches_ref(m, p, rows, n, adc_bits):
    ks = jax.random.split(jax.random.PRNGKey(m * 7 + p), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
    gain = 127.0
    args = dict(adc_lo=lo, adc_hi=hi, adc_bits=adc_bits, gain=gain)
    y_k = ops.analog_mvm(x, gp, gm, **args)
    y_r = ref.analog_mvm_diff(x, gp, gm, **args)
    lsb = 100.0 / (2 ** adc_bits - 1)
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p)


@pytest.mark.parametrize("m,p,rows,n", MVM_EDGE_SHAPES)
def test_analog_mvm_diff_edge_shapes(m, p, rows, n):
    ks = jax.random.split(jax.random.PRNGKey(m * 13 + rows), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
    gain = 127.0
    args = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, gain=gain)
    y_k = ops.analog_mvm(x, gp, gm, **args)
    y_r = ref.analog_mvm_diff(x, gp, gm, **args)
    lsb = 100.0 / 255.0
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p)


@pytest.mark.parametrize("m,p,rows,n", MVM_SHAPES[:4] + MVM_EDGE_SHAPES)
@pytest.mark.parametrize("n_bits", [4, 7])
def test_analog_mvm_bitserial_matches_ref(m, p, rows, n, n_bits):
    ks = jax.random.split(jax.random.PRNGKey(m + p + n_bits), 3)
    qmax = 2 ** n_bits - 1
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * qmax / 3)
    x = jnp.clip(x, -qmax, qmax).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-20.0), jnp.float32(20.0)
    gain = 127.0
    args = dict(n_bits=n_bits, adc_lo=lo, adc_hi=hi, adc_bits=8, gain=gain)
    y_k = ops.analog_mvm_bitserial(x, gp, gm, **args)
    y_r = ref.analog_mvm_bitserial(x, gp, gm, **args)
    lsb = 40.0 / 255.0
    # worst case: one flip at every bit of one partition chain
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p * 2 ** n_bits)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_analog_mvm_dtypes(dtype):
    m, p, rows, n = 16, 1, 64, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 30).astype(dtype)
    gp = (jax.random.uniform(ks[1], (p, rows, n)) * 0.1).astype(dtype)
    gm = (jax.random.uniform(ks[2], (p, rows, n)) * 0.1).astype(dtype)
    lo, hi = jnp.float32(-30.0), jnp.float32(30.0)
    y = ops.analog_mvm(x, gp, gm, adc_lo=lo, adc_hi=hi, adc_bits=8, gain=1.0)
    assert y.shape == (m, n)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("m,k,n,r", [
    (8, 17, 16, 1e-3),
    (32, 96, 24, 1e-4),
    (16, 200, 8, 1e-5),
    (128, 64, 128, 3e-4),
    # solve-shape edges: minimal chain (k=2), single output column,
    # full-depth 1152-row line, and the heaviest sag the sweeps use
    (4, 2, 3, 1e-3),
    (8, 33, 1, 5e-4),
    (4, 1152, 4, 1e-4),
    (16, 72, 8, 5e-3),
])
def test_bitline_kernel_matches_solver(m, k, n, r):
    kx, kg = jax.random.split(jax.random.PRNGKey(k), 2)
    x = jnp.sign(jax.random.normal(kx, (m, k))) * (
        jax.random.uniform(jax.random.PRNGKey(2), (m, k)) > 0.4
    )
    g = jax.random.uniform(kg, (k, n))
    y_k = ops.bitline_mvm(g, x, r)
    y_r = bitline_currents(g, x.astype(jnp.float32), r)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-5)


def test_bitline_kernel_zero_r_is_ideal():
    kx, kg = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jnp.sign(jax.random.normal(kx, (8, 32)))
    g = jax.random.uniform(kg, (32, 16))
    np.testing.assert_allclose(ops.bitline_mvm(g, x, 0.0), x @ g, rtol=1e-6)


def test_bitline_vs_dense_oracle():
    """Thomas-in-kernel vs dense jnp.linalg.solve, element by element."""
    from repro.core.parasitics import bitline_voltages_dense

    m, k, n, r = 4, 23, 6, 2e-3
    kx, kg = jax.random.split(jax.random.PRNGKey(7), 2)
    x = jnp.sign(jax.random.normal(kx, (m, k))) * (
        jax.random.uniform(jax.random.PRNGKey(8), (m, k)) > 0.3
    )
    g = jax.random.uniform(kg, (k, n))
    y_k = ops.bitline_mvm(g, x, r)
    for mm in range(m):
        for nn in range(n):
            v = bitline_voltages_dense(g[:, nn], x[mm], r)
            np.testing.assert_allclose(y_k[mm, nn], v[-1] / r, rtol=1e-4)
