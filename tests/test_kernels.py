"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle.

ADC-bearing kernels are quantizers: a float dot product landing within a
few ULPs of an ADC decision boundary may legally flip by one LSB between
two correct implementations (different fp32 accumulation orders).  The
tolerance policy is therefore: (a) the vast majority of outputs match to
float precision, and (b) every output matches within the worst-case
single-boundary-flip impact (one ADC LSB times the largest shift-and-add
weight times the gain).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.core.parasitics import bitline_currents


def quantizer_allclose(y_k, y_r, *, flip_atol, tight_rtol=1e-4, frac=0.98):
    y_k, y_r = np.asarray(y_k), np.asarray(y_r)
    np.testing.assert_allclose(y_k, y_r, atol=flip_atol, rtol=0)
    tight = np.isclose(y_k, y_r, rtol=tight_rtol, atol=flip_atol * 1e-3)
    assert tight.mean() >= frac, f"only {tight.mean():.2%} bit-exact"


MVM_SHAPES = [
    (8, 1, 64, 16),
    (32, 2, 96, 40),
    (128, 1, 1152, 256),
    (64, 3, 200, 24),
    (16, 2, 8, 8),
]

# off-tile-boundary shapes: rows/N far from multiples of 128 (ops.py pads
# tiles), single-row partitions, a tall-skinny output, and small-M decode
# rows (M = 1 and 2 live lanes, far under one sublane tile)
MVM_EDGE_SHAPES = [
    (4, 1, 1, 8),
    (8, 2, 33, 7),
    (16, 1, 129, 130),
    (8, 4, 72, 3),
    (1, 1, 64, 16),
    (2, 3, 40, 24),
]


@pytest.mark.parametrize("m,p,rows,n", MVM_SHAPES)
@pytest.mark.parametrize("adc_bits", [6, 8])
def test_analog_mvm_diff_matches_ref(m, p, rows, n, adc_bits):
    ks = jax.random.split(jax.random.PRNGKey(m * 7 + p), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
    gain = 127.0
    args = dict(adc_lo=lo, adc_hi=hi, adc_bits=adc_bits, gain=gain)
    y_k = ops.analog_mvm(x, gp, gm, **args)
    y_r = ref.analog_mvm_diff(x, gp, gm, **args)
    lsb = 100.0 / (2 ** adc_bits - 1)
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p)


@pytest.mark.parametrize("m,p,rows,n", MVM_EDGE_SHAPES)
def test_analog_mvm_diff_edge_shapes(m, p, rows, n):
    ks = jax.random.split(jax.random.PRNGKey(m * 13 + rows), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
    gain = 127.0
    args = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, gain=gain)
    y_k = ops.analog_mvm(x, gp, gm, **args)
    y_r = ref.analog_mvm_diff(x, gp, gm, **args)
    lsb = 100.0 / 255.0
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p)


@pytest.mark.parametrize("m,p,rows,n", MVM_SHAPES[:4] + MVM_EDGE_SHAPES)
@pytest.mark.parametrize("n_bits", [4, 7])
def test_analog_mvm_bitserial_matches_ref(m, p, rows, n, n_bits):
    ks = jax.random.split(jax.random.PRNGKey(m + p + n_bits), 3)
    qmax = 2 ** n_bits - 1
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * qmax / 3)
    x = jnp.clip(x, -qmax, qmax).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-20.0), jnp.float32(20.0)
    gain = 127.0
    args = dict(n_bits=n_bits, adc_lo=lo, adc_hi=hi, adc_bits=8, gain=gain)
    y_k = ops.analog_mvm_bitserial(x, gp, gm, **args)
    y_r = ref.analog_mvm_bitserial(x, gp, gm, **args)
    lsb = 40.0 / 255.0
    # worst case: one flip at every bit of one partition chain
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p * 2 ** n_bits)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_analog_mvm_dtypes(dtype):
    m, p, rows, n = 16, 1, 64, 24
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 30).astype(dtype)
    gp = (jax.random.uniform(ks[1], (p, rows, n)) * 0.1).astype(dtype)
    gm = (jax.random.uniform(ks[2], (p, rows, n)) * 0.1).astype(dtype)
    lo, hi = jnp.float32(-30.0), jnp.float32(30.0)
    y = ops.analog_mvm(x, gp, gm, adc_lo=lo, adc_hi=hi, adc_bits=8, gain=1.0)
    assert y.shape == (m, n)
    assert bool(jnp.all(jnp.isfinite(y)))


@pytest.mark.parametrize("m,k,n,r", [
    (8, 17, 16, 1e-3),
    (32, 96, 24, 1e-4),
    (16, 200, 8, 1e-5),
    (128, 64, 128, 3e-4),
    # solve-shape edges: minimal chain (k=2), single output column,
    # full-depth 1152-row line, and the heaviest sag the sweeps use
    (4, 2, 3, 1e-3),
    (8, 33, 1, 5e-4),
    (4, 1152, 4, 1e-4),
    (16, 72, 8, 5e-3),
    # padding edges: M and N off tile multiples, K not a multiple of 8
    (3, 13, 130, 1e-4),
    (130, 7, 5, 1e-3),
    (9, 129, 127, 1e-4),
])
def test_bitline_kernel_matches_solver(m, k, n, r):
    kx, kg = jax.random.split(jax.random.PRNGKey(k), 2)
    x = jnp.sign(jax.random.normal(kx, (m, k))) * (
        jax.random.uniform(jax.random.PRNGKey(2), (m, k)) > 0.4
    )
    g = jax.random.uniform(kg, (k, n))
    y_k = ops.bitline_mvm(g, x, r)
    y_r = bitline_currents(g, x.astype(jnp.float32), r)
    np.testing.assert_allclose(y_k, y_r, rtol=1e-4, atol=1e-5)


def test_bitline_kernel_zero_r_is_ideal():
    kx, kg = jax.random.split(jax.random.PRNGKey(5), 2)
    x = jnp.sign(jax.random.normal(kx, (8, 32)))
    g = jax.random.uniform(kg, (32, 16))
    # every *concrete* scalar form of zero must short-circuit to the ideal
    # matmul — running the Thomas sweep at r=0 divides into silent NaNs
    for zero in (0.0, 0, np.float32(0.0), jnp.float32(0.0),
                 jnp.zeros(())):
        np.testing.assert_allclose(ops.bitline_mvm(g, x, zero), x @ g,
                                   rtol=1e-6)
        np.testing.assert_allclose(bitline_currents(g, x, zero), x @ g,
                                   rtol=1e-6)
    from repro.core.analog import AnalogSpec

    assert not AnalogSpec(r_hat=np.float32(0.0)).parasitics_on
    assert AnalogSpec(r_hat=np.float32(1e-4)).parasitics_on


@pytest.mark.parametrize("m,k,n,r", [
    (4, 23, 6, 2e-3),
    # padded/edge shapes through the dense jnp.linalg.solve oracle too:
    # M/N off tile multiples, K not a multiple of 8
    (3, 13, 9, 1e-3),
    (5, 130, 2, 1e-4),
])
def test_bitline_vs_dense_oracle(m, k, n, r):
    """Thomas-in-kernel vs dense jnp.linalg.solve, element by element."""
    from repro.core.parasitics import bitline_voltages_dense

    kx, kg = jax.random.split(jax.random.PRNGKey(7), 2)
    x = jnp.sign(jax.random.normal(kx, (m, k))) * (
        jax.random.uniform(jax.random.PRNGKey(8), (m, k)) > 0.3
    )
    g = jax.random.uniform(kg, (k, n))
    y_k = ops.bitline_mvm(g, x, r)
    for mm in range(m):
        for nn in range(n):
            v = bitline_voltages_dense(g[:, nn], x[mm], r)
            np.testing.assert_allclose(y_k[mm, nn], v[-1] / r, rtol=1e-4)


def test_bitline_traced_r_hat_one_compilation():
    """``r_hat`` is a kernel *input*: one jitted function serves every
    parasitic level (the sweep engine's Fig. 19 batching contract)."""
    m, k, n = 8, 33, 7
    kx, kg = jax.random.split(jax.random.PRNGKey(11), 2)
    x = jnp.sign(jax.random.normal(kx, (m, k)))
    g = jax.random.uniform(kg, (k, n))
    traces = []

    @jax.jit
    def f(r):
        traces.append(1)
        return ops.bitline_mvm(g, x, r)

    for r in (1e-5, 1e-4, 1e-3):
        np.testing.assert_allclose(
            f(jnp.float32(r)), bitline_currents(g, x, r),
            rtol=1e-4, atol=1e-6)
    assert len(traces) == 1, "r_hat retraced the kernel"


def test_bitline_vmap_over_slices_partitions():
    """The core parasitic branch vmaps the kernel over (slice, partition)
    stacks; pin the batching rule."""
    p_, m, k, n = 3, 6, 24, 5
    gs = jax.random.uniform(jax.random.PRNGKey(3), (p_, k, n))
    xs = jnp.sign(jax.random.normal(jax.random.PRNGKey(4), (p_, m, k)))
    out = jax.vmap(lambda g, x: ops.bitline_mvm(g, x, 1e-4))(gs, xs)
    want = jnp.stack([bitline_currents(gs[i], xs[i], 1e-4)
                      for i in range(p_)])
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-6)


PARASITIC_SHAPES = [
    (8, 1, 16, 8),
    (16, 2, 33, 7),      # K not a multiple of 8, tiny N
    (8, 2, 8, 130),      # N just over one lane tile
    (130, 1, 72, 24),    # M off tile multiple
]


@pytest.mark.parametrize("m,p,rows,n", PARASITIC_SHAPES)
def test_analog_mvm_parasitic_matches_ref(m, p, rows, n):
    ks = jax.random.split(jax.random.PRNGKey(m * 3 + rows), 3)
    x = jnp.clip(jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40),
                 -127, 127).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
    gain = 127.0
    args = dict(r_hat=1e-3, n_bits=7, adc_lo=lo, adc_hi=hi, adc_bits=8,
                gain=gain)
    y_k = ops.analog_mvm_parasitic(x, gp, gm, **args)
    y_r = ref.analog_mvm_parasitic_diff(x, gp, gm, **args)
    lsb = 100.0 / 255.0
    quantizer_allclose(y_k, y_r, flip_atol=lsb * gain * p)


def test_analog_mvm_parasitic_traced_r_hat():
    """The fused Design-A parasitic kernel also takes r_hat as a traced
    scalar — one compiled program across the Fig. 19 axis."""
    m, p, rows, n = 8, 1, 24, 9
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jnp.clip(jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40),
                 -127, 127).astype(jnp.float32)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    kw = dict(n_bits=7, adc_lo=jnp.float32(-50.0), adc_hi=jnp.float32(50.0),
              adc_bits=8, gain=127.0)
    f = jax.jit(lambda r: ops.analog_mvm_parasitic(x, gp, gm, r_hat=r, **kw))
    for r in (1e-5, 1e-3):
        np.testing.assert_allclose(
            f(jnp.float32(r)),
            ref.analog_mvm_parasitic_diff(x, gp, gm, r_hat=r, **kw),
            rtol=1e-3, atol=100.0 / 255.0 * 127.0)


def test_pick_tile_lane_dim_is_full_tile():
    """Mosaic requires 128-lane tiles: the N (lane) tile must never shrink
    to a sublane-rounded size, however small N is (interpret mode hides
    the violation; TPU compilation does not)."""
    for n in (1, 3, 7, 64, 127):
        assert ops._pick_tile(n, 128, lane=True) == 128, n
    assert ops._pick_tile(200, 128, lane=True) == 128
    # sublane tiles snap up to the next power of two (Mosaic-legal
    # second-minor sizes: 8, 16, 32, 64, 128), capped at the block max
    assert ops._pick_tile(3, 128) == 8
    assert ops._pick_tile(33, 128) == 64
    assert ops._pick_tile(64, 128) == 64
    assert ops._pick_tile(65, 128) == 128
    assert ops._pick_tile(200, 128) == 128


@pytest.mark.parametrize("n", [1, 3, 5])
def test_analog_mvm_small_lane_shapes(n):
    """Tiny-N outputs exercise the lane-padded (bn=128) path in all three
    wrapper entry points."""
    m, p, rows = 8, 2, 40
    ks = jax.random.split(jax.random.PRNGKey(n), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40)
    gp = jax.random.uniform(ks[1], (p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (p, rows, n)) * 0.1
    lo, hi = jnp.float32(-50.0), jnp.float32(50.0)
    args = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, gain=127.0)
    lsb = 100.0 / 255.0

    # frac: with only m*n <= 40 outputs, one boundary-straddling sample row
    # is >10% of the output — the flip_atol bound is the real contract here
    y = ops.analog_mvm(x, gp, gm, **args)
    quantizer_allclose(y, ref.analog_mvm_diff(x, gp, gm, **args),
                       flip_atol=lsb * 127.0 * p, frac=0.8)
    y = ops.analog_mvm_bitserial(x, gp, gm, n_bits=7, **args)
    quantizer_allclose(
        y, ref.analog_mvm_bitserial(x, gp, gm, n_bits=7, **args),
        flip_atol=lsb * 127.0 * p * 2 ** 7, frac=0.8)
    xs = jnp.sign(jax.random.normal(ks[0], (m, rows)))
    g = jax.random.uniform(ks[1], (rows, n))
    np.testing.assert_allclose(
        ops.bitline_mvm(g, xs, 1e-4), bitline_currents(g, xs, 1e-4),
        rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# paged-attention decode: in-kernel block-table gather vs jnp gather oracle
# ---------------------------------------------------------------------------

# (B, H, KV, hd, page_size, NP) decode shapes: multi-page rows, ragged
# last pages, GQA grouping, single-page tables, page_size=1 degenerate
PAGED_SHAPES = [
    (1, 2, 1, 8, 4, 2),
    (3, 4, 2, 8, 4, 4),
    (2, 4, 4, 16, 8, 2),
    (4, 8, 2, 32, 8, 4),
    (2, 2, 2, 8, 4, 1),      # single page
    (3, 2, 1, 8, 1, 6),      # page_size = 1
    (2, 6, 3, 8, 2, 5),
]


def _paged_case(b, h, kv, hd, ps, np_pages, seed=0):
    """Random pool + per-row block tables with ragged fills (last page
    partially valid) and sink-padded table tails."""
    rng = np.random.default_rng(seed)
    num_pages = 1 + b * np_pages
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k1, (b, h, hd), jnp.float32)
    k_pages = jax.random.normal(k2, (num_pages, ps, kv, hd), jnp.float32)
    v_pages = jax.random.normal(k3, (num_pages, ps, kv, hd), jnp.float32)
    perm = rng.permutation(np.arange(1, num_pages))
    ptab = np.zeros((b, np_pages), np.int32)
    kv_len = np.zeros((b,), np.int32)
    for i in range(b):
        n = int(rng.integers(1, np_pages * ps + 1))   # ragged fill
        used = -(-n // ps)
        ptab[i, :used] = perm[i * np_pages:i * np_pages + used]
        kv_len[i] = n                                 # tail stays sink (0)
    return q, k_pages, v_pages, jnp.asarray(ptab), jnp.asarray(kv_len)


@pytest.mark.parametrize("b,h,kv,hd,ps,np_pages", PAGED_SHAPES)
def test_paged_attention_bit_exact_vs_oracle(b, h, kv, hd, ps, np_pages):
    """The two-phase kernel is BITWISE equal to the two-phase jnp
    oracle — the exactness anchor of the paged serving runtime (see
    kernels/paged.py on why one-pass online softmax cannot give this:
    FMA contraction of the rescale differs across compilation
    contexts)."""
    args = _paged_case(b, h, kv, hd, ps, np_pages)
    out = ops.paged_attention(*args)
    want = ref.paged_attention_decode(*args)
    assert out.dtype == want.dtype and out.shape == (b, h, hd)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_paged_attention_invariant_to_table_tail_padding():
    """Positions >= kv_len contribute exact zeros, so the result cannot
    depend on what page ids pad the tail of the block table."""
    q, kp, vp, ptab, kv_len = _paged_case(3, 4, 2, 8, 4, 4, seed=1)
    base = np.asarray(ops.paged_attention(q, kp, vp, ptab, kv_len))
    tab = np.asarray(ptab).copy()
    for i, n in enumerate(np.asarray(kv_len)):
        used = -(-int(n) // 4)
        tab[i, used:] = (i + 5) % tab.shape[1] + 1     # garbage, non-sink
    np.testing.assert_array_equal(
        base, np.asarray(ops.paged_attention(q, kp, vp,
                                             jnp.asarray(tab), kv_len)))


def test_paged_attention_matches_streaming_gather():
    """Numerical cross-check against the serving gather path: dense
    streaming attention over pool[ptab] (the runtime's bit-exact
    backend) agrees with the kernel to float tolerance."""
    from repro.models.layers import streaming_attention

    b, h, kv, hd, ps, npg = 3, 4, 2, 16, 4, 4
    q, kp, vp, ptab, kv_len = _paged_case(b, h, kv, hd, ps, npg, seed=2)
    out = np.asarray(ops.paged_attention(q, kp, vp, ptab, kv_len))
    gk = kp[ptab].reshape(b, npg * ps, kv, hd)
    gv = vp[ptab].reshape(b, npg * ps, kv, hd)
    want = streaming_attention(q[:, None], gk, gv,
                               q_offset=kv_len - 1, causal=True,
                               kv_len=kv_len)[:, 0]
    np.testing.assert_allclose(out, np.asarray(want), rtol=2e-5, atol=2e-5)


def test_paged_attention_rejects_unpadded_pallas_page_size():
    """Non-interpret mode requires sublane-aligned pages (positions
    cannot be padded; a padded page would shift k_pos)."""
    q, kp, vp, ptab, kv_len = _paged_case(2, 2, 1, 8, 4, 2)
    with pytest.raises(ValueError, match="page_size"):
        ops.paged_attention(q, kp, vp, ptab, kv_len, interpret=False)


# ---------------------------------------------------------------------------
# fused decode chain: single-launch matmul + ADC + dequant + slice/bit
# shift-and-add vs the jnp oracle (the composed form of the same chain)
# ---------------------------------------------------------------------------

def _fused_case(m, p, s, rows, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jnp.round(jax.random.normal(ks[0], (m, p, rows)) * 40)
    gp = jax.random.uniform(ks[1], (s, p, rows, n)) * 0.1
    gm = jax.random.uniform(ks[2], (s, p, rows, n)) * 0.1
    lo = jnp.linspace(-60.0, -40.0, s).astype(jnp.float32)
    hi = jnp.linspace(40.0, 60.0, s).astype(jnp.float32)
    return x, gp, gm, lo, hi


def _max_ulp(y_k, y_r):
    y_k, y_r = np.asarray(y_k), np.asarray(y_r)
    d = np.abs(y_k - y_r)
    mag = np.maximum(np.abs(y_k), np.abs(y_r))
    return float(np.max(np.where(d > 0,
                                 d / np.spacing(mag.astype(np.float32)),
                                 0.0)))


def _assert_close_codes(y_k, y_r, scale, *, ulp=2.0, codes=0.25):
    """Elementwise kernel-vs-oracle bound: within ``ulp`` float32 ulps,
    or — for near-zero outputs, where a sub-lsb absolute drift reads as
    millions of ulps — within ``codes`` dequant grid units (the output
    is ``scale`` times an integer-weighted sum of ADC codes, so its
    grid spacing is ``scale``; a drift under half a grid step can never
    flip which quantized value either side lands on, and slice/plane
    weights up to 2^12 amplify fp32 reassociation into that window)."""
    y_k, y_r = np.asarray(y_k), np.asarray(y_r)
    d = np.abs(y_k - y_r)
    mag = np.maximum(np.abs(y_k), np.abs(y_r))
    ok = ((d <= ulp * np.spacing(mag.astype(np.float32)))
          | (d <= codes * float(scale)))
    assert bool(ok.all()), (
        f"max ulp={_max_ulp(y_k, y_r):.1f}, "
        f"max code diff={float(d.max()) / float(scale):.2e}")


@pytest.mark.parametrize("m,p,rows,n", [
    (1, 1, 64, 16),     # single decode lane
    (2, 1, 33, 7),      # small-M, off-tile rows/N
    (8, 1, 256, 128),   # a full decode gang at the lane tile
    (8, 2, 96, 40),     # multi-partition
    (4, 3, 72, 24),
])
@pytest.mark.parametrize("n_bits", [None, 7])
def test_fused_mvm_single_slice_bitwise(m, p, rows, n, n_bits):
    """S == 1 — the decode MVMs the smoke LM actually serves: the fused
    kernel is BITWISE equal to its oracle under jit (the serving path —
    XLA contracts both sides' dot/epilogue chains identically).  Eager
    dispatch compiles each op separately and may reassociate the bit
    fold differently, so eagerly we pin agreement to a sliver of an ADC
    code unit instead — far below the half-code threshold where any
    quantized output could flip."""
    x, gp, gm, lo, hi = _fused_case(m, p, 1, rows, n, seed=m * 11 + rows)
    kw = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, cell_bits=7,
              n_bits=n_bits, scale=jnp.float32(3e-4))
    y_k = ops.fused_mvm(x, gp, gm, backend="kernel", **kw)
    y_r = ops.fused_mvm(x, gp, gm, backend="oracle", **kw)
    codes = np.abs(np.asarray(y_k) - np.asarray(y_r)) / kw["scale"]
    assert float(codes.max()) <= 1e-2
    yj_k = jax.jit(lambda *a: ops.fused_mvm(*a, backend="kernel", **kw))(
        x, gp, gm)
    yj_r = jax.jit(lambda *a: ops.fused_mvm(*a, backend="oracle", **kw))(
        x, gp, gm)
    np.testing.assert_array_equal(np.asarray(yj_k), np.asarray(yj_r))


@pytest.mark.parametrize("m,p,s,rows,n,n_bits", [
    (8, 1, 2, 40, 24, None),
    (4, 2, 4, 33, 7, 7),
    (8, 1, 3, 96, 130, None),   # N over one lane tile
    (2, 1, 4, 64, 16, 7),       # small-M sliced decode
])
def test_fused_mvm_multi_slice_ulp(m, p, s, rows, n, n_bits):
    """S >= 2 multi-tile slice accumulation: the per-slice lsb factor
    rides outside the bit fold behind an exact power-of-two slice
    weight, so kernel-vs-oracle drift is fp32 reassociation of the
    final sum — a couple of ULPs on full-size outputs, a sub-lsb
    absolute sliver where slices cancel to near zero, never an ADC
    code flip."""
    x, gp, gm, lo, hi = _fused_case(m, p, s, rows, n, seed=s * 17 + n)
    kw = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, cell_bits=2,
              n_bits=n_bits, scale=jnp.float32(3e-4))
    y_k = ops.fused_mvm(x, gp, gm, backend="kernel", **kw)
    y_r = ops.fused_mvm(x, gp, gm, backend="oracle", **kw)
    _assert_close_codes(y_k, y_r, kw["scale"])


@pytest.mark.parametrize("m,p,s,rows,n", [
    (4, 1, 1, 24, 9),
    (8, 2, 2, 33, 7),
    (2, 1, 1, 64, 16),          # small-M decode lane
])
def test_fused_mvm_parasitic_matches_oracle(m, p, s, rows, n):
    """The fused parasitic variant (per-bit Thomas solve inside the same
    launch) against its oracle, with r_hat traced."""
    x, gp, gm, lo, hi = _fused_case(m, p, s, rows, n, seed=rows + n)
    x = jnp.clip(x, -127, 127)
    kw = dict(adc_lo=lo, adc_hi=hi, adc_bits=8, cell_bits=2 if s > 1 else 7,
              n_bits=7, scale=jnp.float32(3e-4))
    f_k = jax.jit(lambda r: ops.fused_mvm_parasitic(
        x, gp, gm, r_hat=r, backend="kernel", **kw))
    f_r = jax.jit(lambda r: ops.fused_mvm_parasitic(
        x, gp, gm, r_hat=r, backend="oracle", **kw))
    traces = []
    for r in (1e-5, 1e-3):
        _assert_close_codes(f_k(jnp.float32(r)), f_r(jnp.float32(r)),
                            kw["scale"])
        traces.append(r)
    assert len(traces) == 2


# ---------------------------------------------------------------------------
# flash-decode attention over the dense per-slot KV cache
# ---------------------------------------------------------------------------

def _flash_case(b, s, kv, g, hd, seed=0):
    h = kv * g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, h, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    rng = np.random.default_rng(seed)
    fills = jnp.asarray(rng.integers(1, s + 1, size=b), jnp.int32)
    return q, ck, cv, fills


@pytest.mark.parametrize("b,s,kv,g,hd", [
    (1, 8, 2, 1, 8),     # single row, single group
    (2, 16, 2, 2, 8),
    (3, 40, 2, 1, 32),   # cache off the block multiple
    (4, 33, 4, 2, 16),
    (2, 9, 1, 4, 8),     # GQA onto one KV head
])
def test_flash_decode_bitwise_vs_oracle(b, s, kv, g, hd):
    """Same two-phase exactness anchor as the paged kernel: the flash
    decode kernel is BITWISE equal to its chunked-gather oracle on
    ragged fills — what the fused runtime's token agreement rests on."""
    q, ck, cv, fills = _flash_case(b, s, kv, g, hd, seed=b * 7 + s)
    out = ops.flash_attention_decode(q, ck, cv, fills, backend="kernel")
    want = ops.flash_attention_decode(q, ck, cv, fills, backend="oracle")
    assert out.shape == (b, kv * g, hd)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_flash_decode_invariant_to_cache_tail():
    """Positions >= kv_len[b] are exact zeros in both phases: garbage in
    the unwritten tail of the dense cache cannot leak into the output."""
    q, ck, cv, fills = _flash_case(3, 16, 2, 2, 8, seed=9)
    base = np.asarray(ops.flash_attention_decode(q, ck, cv, fills))
    ckg, cvg = np.asarray(ck).copy(), np.asarray(cv).copy()
    for i, n in enumerate(np.asarray(fills)):
        ckg[i, int(n):] = 1e9
        cvg[i, int(n):] = -1e9
    out = ops.flash_attention_decode(q, jnp.asarray(ckg), jnp.asarray(cvg),
                                     fills)
    np.testing.assert_array_equal(base, np.asarray(out))
