"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step on CPU, output shapes + finiteness, prefill/decode
consistency against the teacher-forced forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.registry import get_model

B, S = 2, 16

# published sizes (billions) the exact configs must reproduce within 10%
EXPECTED_PARAMS_B = {
    "gemma-2b": 2.5,
    "gemma3-1b": 1.0,
    "qwen1.5-4b": 4.0,
    "qwen3-14b": 14.8,
    "arctic-480b": 480.0,
    "qwen3-moe-235b-a22b": 235.0,
    "zamba2-7b": 7.3,
    "internvl2-26b": 20.0,   # text backbone only; ViT frontend is a stub
    "rwkv6-3b": 3.0,
    "whisper-large-v3": 1.5,
}


def _inputs(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    kw = {}
    if cfg.frontend:
        kw["prefix_embeds"] = (
            jax.random.normal(jax.random.PRNGKey(2),
                              (B, cfg.n_frontend_tokens, cfg.d_model)) * 0.02
        )
    return tokens, kw


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg, jax.random.PRNGKey(1))
    logits, _ = api.forward(cfg, params, tokens, **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss_and_is_finite(arch):
    from repro.train.step import make_train_state, train_step_fn

    cfg = get_smoke_config(arch)
    state = make_train_state(cfg, jax.random.PRNGKey(0), lr=1e-2)
    tokens, kw = _inputs(cfg, jax.random.PRNGKey(1))
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1)}
    if "prefix_embeds" in kw:
        batch["prefix_embeds"] = kw["prefix_embeds"]
    step = train_step_fn(cfg, microbatches=1)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(m1["loss"]) and np.isfinite(m2["loss"])
    assert m2["loss"] < m1["loss"] + 1e-3  # same batch: loss must not blow up


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg, jax.random.PRNGKey(1))
    logits, _ = api.forward(cfg, params, tokens, **kw)
    lp, cache = api.prefill(cfg, params, tokens, max_len=S + 4, **kw)
    np.testing.assert_allclose(
        np.asarray(lp[:, 0]), np.asarray(logits[:, -1]), rtol=2e-2, atol=2e-3)
    nt = jnp.argmax(lp, -1).astype(jnp.int32)
    ld, cache = api.decode_step(cfg, params, nt, cache)
    lf, _ = api.forward(cfg, params, jnp.concatenate([tokens, nt], 1), **kw)
    np.testing.assert_allclose(
        np.asarray(ld[:, 0]), np.asarray(lf[:, -1]), rtol=2e-2, atol=3e-3)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    got = cfg.param_count() / 1e9
    want = EXPECTED_PARAMS_B[arch]
    assert abs(got - want) / want < 0.12, f"{arch}: {got:.2f}B vs {want}B"


def test_subquadratic_flags_match_design_doc():
    long_runners = {a for a in ARCH_IDS if get_config(a).subquadratic}
    assert long_runners == {"gemma3-1b", "zamba2-7b", "rwkv6-3b"}
