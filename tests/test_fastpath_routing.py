"""Fastpath routing (tier-1): ``analog_matmul`` through the fused
single-launch serving kernels vs the composed multi-op chain vs the
fused jnp oracle, across input-accumulation x parasitics x slicing x
partitions; the must-refuse-to-fuse fallbacks; and the
``fuse_signature`` compile identity the per-site-class serving contract
keys on (``repro.hw.fused_site_classes``).

Exactness policy: the serving decode path is jitted, and under jit the
fused kernel is BITWISE equal to its jnp oracle — that equality is what
the runtime's token-agreement contract rests on, so it is pinned with
``array_equal`` here.  Eagerly, XLA dispatches the chain as separate
ops and may contract the final dequant multiply differently (a 1-2 ULP
artifact, never an ADC code flip), so eager checks use float tolerance.
Fused-vs-composed compares two *different* op orders over the same ADC
codes: float-level agreement, not bitwise.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analog as A
from repro.core.analog import AnalogSpec, fuse_signature
from repro.core.calibrate import calibrate_adc_for_matmul
from repro.core.errors import ErrorModel
from repro.core.mapping import MappingConfig
from repro.hw import Profile, Rule, fused_site_classes

BASE = A.design_a(error=ErrorModel())
SLICED = dataclasses.replace(
    BASE, mapping=MappingConfig(scheme="differential", weight_bits=8,
                                bits_per_cell=2, on_off_ratio=1e4))

#: every fuse-eligible corner of input_accum x parasitics x slicing x
#: partitions, with the compile signature each must lower to
ROUTED = [
    ("designA", BASE, ("linear", 1, 7, 8, None, None)),
    ("designA_parasitic", dataclasses.replace(BASE, r_hat=1e-4),
     ("parasitic", 1, 7, 8, None, 7)),
    ("digital_accum", dataclasses.replace(BASE, input_accum="digital"),
     ("linear", 1, 7, 8, 7, None)),
    ("sliced", SLICED, ("linear", 4, 2, 8, None, None)),
    ("sliced_digital", dataclasses.replace(SLICED, input_accum="digital"),
     ("linear", 4, 2, 8, 7, None)),
    ("multi_partition", dataclasses.replace(BASE, max_rows=96),
     ("linear", 1, 7, 8, None, None)),
]

#: specs that must refuse to fuse and fall back to the composed chain
REFUSED = [
    ("parasitic_digital", dataclasses.replace(
        BASE, input_accum="digital", r_hat=1e-4)),
    ("offset_scheme", dataclasses.replace(
        BASE, mapping=MappingConfig(scheme="offset", weight_bits=8,
                                    on_off_ratio=1e4),
        input_accum="digital")),
    ("uncalibrated_adc", dataclasses.replace(
        BASE, adc=dataclasses.replace(BASE.adc, style="fpg"))),
]


def _case(spec, m=4, k=200, n=48, seed=0):
    kw_, kx = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(kw_, (k, n)) * 0.1
    x = jax.random.normal(kx, (m, k))
    aw = A.program(w, spec, key=jax.random.PRNGKey(1))
    lo, hi = calibrate_adc_for_matmul(x, aw, spec)
    return x, aw, lo, hi


@pytest.mark.parametrize("tag,spec,sig", ROUTED, ids=[t for t, _, _ in ROUTED])
def test_fused_routes_and_agrees(tag, spec, sig):
    fspec = dataclasses.replace(spec, fused="kernel")
    assert A._maybe_pallas_fastpath(fspec, False)
    assert not A._maybe_pallas_fastpath(fspec, True)   # collection composes
    assert fuse_signature(fspec) == sig
    assert fuse_signature(spec) is None                # fused="off"

    x, aw, lo, hi = _case(spec)
    if tag == "multi_partition":
        assert aw.g_pos.shape[1] > 1                   # P really is > 1
    y_c = A.analog_matmul(x, aw, spec, adc_lo=lo, adc_hi=hi)
    arms = {
        mode: jax.jit(lambda x, s=dataclasses.replace(spec, fused=mode):
                      A.analog_matmul(x, aw, s, adc_lo=lo, adc_hi=hi))
        for mode in ("kernel", "oracle")
    }
    y_k, y_o = arms["kernel"](x), arms["oracle"](x)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_o))
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_c),
                               rtol=2e-5, atol=1e-5)


@pytest.mark.parametrize("tag,spec", REFUSED, ids=[t for t, _ in REFUSED])
def test_refuses_to_fuse_and_falls_back(tag, spec):
    fspec = dataclasses.replace(spec, fused="kernel")
    assert fuse_signature(fspec) is None
    x, aw, lo, hi = _case(spec)
    if spec.adc.style != "calibrated":
        lo = hi = None
    y_c = A.analog_matmul(x, aw, spec, adc_lo=lo, adc_hi=hi)
    y_f = A.analog_matmul(x, aw, fspec, adc_lo=lo, adc_hi=hi)
    # the fallback IS the composed chain: bitwise, not merely close
    np.testing.assert_array_equal(np.asarray(y_f), np.asarray(y_c))


def test_fused_field_validated():
    with pytest.raises(ValueError, match="fused"):
        AnalogSpec(fused="mosaic")
    for mode in ("off", "kernel", "oracle"):
        assert AnalogSpec(fused=mode).fused == mode


def test_fused_eager_matches_jit_to_float_tolerance():
    """Eager dispatch may re-associate the dequant multiply (separate-op
    XLA fusion) — bounded to ULP-scale, never an ADC code flip."""
    spec = dataclasses.replace(BASE, fused="kernel")
    x, aw, lo, hi = _case(BASE)
    y_e = A.analog_matmul(x, aw, spec, adc_lo=lo, adc_hi=hi)
    y_j = jax.jit(lambda x: A.analog_matmul(x, aw, spec,
                                            adc_lo=lo, adc_hi=hi))(x)
    np.testing.assert_allclose(np.asarray(y_e), np.asarray(y_j),
                               rtol=1e-5, atol=1e-6)


def test_fuse_signature_groups_sites_not_layers():
    """A profile with two ADC widths and a parasitic MLP band lowers to
    exactly three fused programs, whatever the site and layer count —
    the one-compile-per-site-class identity the serving contract pins."""
    spec6 = dataclasses.replace(
        BASE, adc=dataclasses.replace(BASE.adc, bits=6))
    par = dataclasses.replace(BASE, r_hat=1e-4)
    prof = Profile(rules=(
        Rule("attn.*", dataclasses.replace(BASE, fused="kernel")),
        Rule("w_up", dataclasses.replace(spec6, fused="kernel")),
        Rule("w_down", dataclasses.replace(par, fused="kernel"),
             layers=(0, 2)),
        Rule("w_down", dataclasses.replace(BASE, fused="kernel"),
             layers=(2, 4)),
    ), default=dataclasses.replace(BASE, fused="kernel"))
    sites = ["wq", "wk", "wv", "wo", "w_up", "w_down", "w_gate"]
    groups = fused_site_classes(prof, sites, n_layers=4)
    assert set(groups) == {
        ("linear", 1, 7, 8, None, None),
        ("linear", 1, 7, 6, None, None),
        ("parasitic", 1, 7, 8, None, 7),
    }
    assert groups[("linear", 1, 7, 6, None, None)] == ["w_up"]
    assert groups[("parasitic", 1, 7, 8, None, 7)] == ["w_down"]
    # w_down fuses differently across its two layer bands: it appears in
    # BOTH the parasitic and the plain linear class
    assert "w_down" in groups[("linear", 1, 7, 8, None, None)]
    # a refusing profile contributes no classes
    off = Profile(rules=(Rule("attn.*", BASE),), default=BASE)
    assert fused_site_classes(off, sites, n_layers=4) == {}
