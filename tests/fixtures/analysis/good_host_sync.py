"""Host access outside any jitted path — clean."""
import jax
import numpy as np


@jax.jit
def decode_step(logits):
    return logits.argmax()


def collect(logits):
    # host code calling INTO jit, then syncing — the legal direction
    return int(np.asarray(decode_step(logits)))
