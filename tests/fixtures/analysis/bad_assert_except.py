"""Bare assert + silent except-pass in library code."""


def tile(m, bm):
    assert m % bm == 0
    return m // bm


def read_attr(obj):
    out = {}
    try:
        out["size"] = int(obj.size)
    except Exception:
        pass
    return out
