"""The verbatim pre-PR-3 rope: concat-of-slices along head_dim.

This exact function miscompiled in the XLA SPMD partitioner when
head_dim was model-sharded on a multi-axis mesh (PR 3), silently
corrupting k.  The analyzer must flag the concatenate on line 17.
"""
import jax
import jax.numpy as jnp


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)
