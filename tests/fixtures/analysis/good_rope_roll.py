"""The PR 3 fix: roll-based rotate-half, no slice reassembly — clean."""
import jax
import jax.numpy as jnp


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    hd = x.shape[-1]
    half = hd // 2
    idx = jnp.arange(hd)
    freqs = theta ** (-(idx % half).astype(jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    sign = jnp.where(idx < half, -1.0, 1.0)
    rot = jnp.roll(x, half, axis=-1) * sign
    return (x * cos + rot * sin).astype(x.dtype)
