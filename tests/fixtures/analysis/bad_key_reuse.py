"""A PRNG key consumed twice without an interleaving split/fold_in:
the two draws are correlated, silently breaking trial independence."""
import jax


def two_draws(key, shape):
    a = jax.random.normal(key, shape)
    b = jax.random.uniform(key, shape)     # correlated with `a`
    return a + b
