"""The `_pick_tile` bug class: a 64-wide lane (N) tile in a BlockSpec.

Mosaic requires the last (lane) block dim to be a multiple of 128;
64 works under interpret=True on CPU and fails on real hardware —
exactly how the PR 3 latent bug shipped.
"""
import jax
from jax.experimental import pallas as pl


def call_kernel(kernel, x, *, bm: int = 8):
    m, n = x.shape
    bn = 64
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x)
