"""Host syncs reachable from a jitted body: `.item()` inside the jit
root's same-module call graph — trace-time crash or silent device sync."""
import jax
import numpy as np


def _postprocess(logits):
    top = logits.argmax()
    return top.item()                      # host sync


@jax.jit
def decode_step(logits):
    return _postprocess(logits)


def make_step():
    def inner(x):
        return float(np.asarray(x).sum())  # two syncs in a jitted factory

    return inner


step = jax.jit(make_step())
