"""Mosaic-legal tiles: 128-multiple lane, 8-multiple sublane — clean."""
import jax
from jax.experimental import pallas as pl


def call_kernel(kernel, x, *, bm: int = 8):
    m, n = x.shape
    bn = 128
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j: (0, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
    )(x)
