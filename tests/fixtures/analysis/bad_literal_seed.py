"""A literal integer seed in library code — determinism the caller
cannot control."""
import jax


def init_params(shape):
    key = jax.random.PRNGKey(42)
    return jax.random.normal(key, shape)
