"""Key hygiene done right: split before each consumer — clean."""
import jax


def two_draws(key, shape):
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, shape)
    b = jax.random.uniform(k2, shape)
    return a + b


def rebound(key, shape):
    a = jax.random.normal(key, shape)
    key = jax.random.fold_in(key, 1)       # refreshes `key`
    b = jax.random.uniform(key, shape)
    return a + b
