"""Substrate tests: data determinism, optimizer vs numpy reference,
checkpoint round-trip + elastic restore, fault tolerance, recurrences,
MoE dispatch vs dense reference, gradient compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, ShapeConfig


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_step_addressable():
    from repro.data.synthetic import SyntheticLM

    cfg = ModelConfig(name="t", family="dense", n_layers=1, d_model=8,
                      n_heads=1, n_kv_heads=1, d_ff=8, vocab=101)
    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=3)
    b1 = ds.batch(7)
    b2 = ds.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    np.testing.assert_array_equal(
        b1["targets"][:, :-1], b1["tokens"][:, 1:])
    assert ds.state(7) == {"seed": 3, "step": 7, "mode": "lm"}


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_matches_numpy_reference():
    from repro.optim import adamw

    rng = np.random.RandomState(0)
    p = {"w": jnp.asarray(rng.randn(5, 3), jnp.float32)}
    g = {"w": jnp.asarray(rng.randn(5, 3), jnp.float32)}
    st = adamw.init(p)
    lr, b1, b2, eps, wd = 1e-2, 0.9, 0.95, 1e-8, 0.1
    new_p, st = adamw.update(g, st, p, lr=lr, b1=b1, b2=b2, eps=eps,
                             weight_decay=wd)
    # numpy reference, step 1
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mh = m / (1 - b1)
    vh = v / (1 - b2)
    upd = mh / (np.sqrt(vh) + eps) + wd * np.asarray(p["w"])
    ref = np.asarray(p["w"]) - lr * upd
    np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)


def test_clip_by_global_norm():
    from repro.optim.adamw import clip_by_global_norm, global_norm

    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((5,), -4.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


def test_cosine_schedule():
    from repro.optim.adamw import cosine_schedule

    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < 1e-5
    assert float(lr(5)) == pytest.approx(5e-4)


def test_ef_compression_residual_bounds_error():
    from repro.optim import compress

    rng = np.random.RandomState(1)
    g = {"w": jnp.asarray(rng.randn(64), jnp.float32)}
    res = compress.init_residual(g)
    total_true = np.zeros(64)
    total_sent = np.zeros(64)
    for step in range(20):
        gi = {"w": jnp.asarray(rng.randn(64), jnp.float32)}
        total_true += np.asarray(gi["w"])
        q, s, res = compress.ef_compress(gi, res)
        total_sent += np.asarray(q["w"], np.float32) * np.asarray(s["w"])
    # error feedback: cumulative sent tracks cumulative true gradients
    # within the residual's bound (single-step quant error)
    err = np.abs(total_true - total_sent).max()
    assert err < 0.2, err


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_gc(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for step in (1, 2, 3):
        mgr.save(step, jax.tree.map(lambda x: x * step, tree),
                 extra={"step": step})
    assert mgr.all_steps() == [2, 3]  # gc kept last 2
    out, step, extra = mgr.restore(tree)
    assert step == 3 and extra == {"step": 3}
    np.testing.assert_array_equal(out["a"], np.arange(6).reshape(2, 3) * 3)


def test_checkpoint_async_and_elastic_sharding_hook(tmp_path):
    from repro.checkpoint.manager import CheckpointManager

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((8, 4))}
    mgr.save_async(5, tree)
    mgr.wait()
    calls = []

    def sharding_fn(name, shape):
        calls.append((name, shape))
        return None

    out, step, _ = mgr.restore(tree, sharding_fn=sharding_fn)
    assert step == 5
    assert calls == [("w", (8, 4))]


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


def test_resilient_step_retries_then_succeeds():
    from repro.runtime.fault import resilient_step

    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise ConnectionError("transient")
        return "ok"

    assert resilient_step(flaky, backoff_s=0.001) == "ok"
    assert calls["n"] == 3


def test_resilient_step_gives_up():
    from repro.runtime.fault import StepFailed, resilient_step

    def always_fails():
        raise TimeoutError("dead node")

    with pytest.raises(StepFailed):
        resilient_step(always_fails, max_retries=2, backoff_s=0.001)


def test_resilient_step_deterministic_errors_reraise_immediately():
    """A bare RuntimeError (XLA shape error, assertion, NaN guard) is
    NOT transient: one attempt, no retries, original exception type."""
    from repro.runtime.fault import resilient_step

    calls = {"n": 0}

    def deterministic():
        calls["n"] += 1
        raise RuntimeError("rank mismatch: expected 2, got 3")

    with pytest.raises(RuntimeError, match="rank mismatch"):
        resilient_step(deterministic, max_retries=5, backoff_s=0.001)
    assert calls["n"] == 1

    def missing():
        calls["n"] += 1
        raise FileNotFoundError("no such checkpoint")

    with pytest.raises(FileNotFoundError):
        resilient_step(missing, max_retries=5, backoff_s=0.001)
    assert calls["n"] == 2


def test_resilient_step_transient_xla_messages():
    """jaxlib's XlaRuntimeError has no subtype taxonomy — transience is
    decided by an RPC-status message allowlist (``is_transient``)."""
    from repro.runtime.fault import is_transient

    class XlaRuntimeError(RuntimeError):     # stand-in, matched by name
        pass

    assert is_transient(XlaRuntimeError("UNAVAILABLE: socket closed"))
    assert is_transient(XlaRuntimeError("DEADLINE_EXCEEDED: heartbeat"))
    assert not is_transient(XlaRuntimeError("INVALID_ARGUMENT: rank"))
    assert not is_transient(RuntimeError("UNAVAILABLE"))  # name-gated
    assert is_transient(ConnectionResetError("peer reset"))
    assert not is_transient(ValueError("bad field"))


def test_straggler_monitor_flags_outliers():
    from repro.runtime.fault import StragglerMonitor

    events = []
    mon = StragglerMonitor(k_sigma=3.0, min_samples=10,
                           on_straggler=lambda s, t: events.append((s, t)))
    for _ in range(20):
        mon.record(0.1 + np.random.RandomState(1).rand() * 0.001)
    assert mon.record(1.5) is True       # injected straggler
    assert len(events) == 1


# ---------------------------------------------------------------------------
# recurrences / moe
# ---------------------------------------------------------------------------


def test_chunked_recurrence_matches_naive():
    from repro.models.recurrent import (
        chunked_decay_recurrence, decay_recurrence_naive)

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, dk, dv = 2, 37, 3, 8, 5
    r = jax.random.normal(ks[0], (B, S, H, dk)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, dk)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, dv)) * 0.5
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, dk)) * 0.5)
    u = jax.random.normal(ks[4], (H, dk)) * 0.3
    for uu in (None, u):
        for chunk in (4, 16, 64):
            y1, s1 = chunked_decay_recurrence(r, k, v, lw, u=uu, chunk=chunk)
            y2, s2 = decay_recurrence_naive(r, k, v, lw, u=uu)
            np.testing.assert_allclose(y1, y2, rtol=3e-4, atol=3e-5)
            np.testing.assert_allclose(s1, s2, rtol=3e-4, atol=5e-5)


def test_moe_dispatch_matches_dense_reference():
    from repro.models.mlp import init_moe, moe_block, moe_block_dense_ref

    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=4, top_k=2, moe_d_ff=32,
                      capacity_factor=4.0)  # = n_experts: no drops
    p = jax.tree.map(lambda a: a[0], init_moe(jax.random.PRNGKey(0), cfg, 1,
                                              jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, lb = moe_block(p, x, cfg)
    y_ref = moe_block_dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-5)
    assert float(lb) > 0.0


def test_moe_drops_when_capacity_exceeded():
    from repro.models.mlp import init_moe, moe_block

    cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16,
                      n_heads=2, n_kv_heads=2, d_ff=32, vocab=64,
                      n_experts=4, top_k=2, moe_d_ff=32,
                      capacity_factor=0.25)
    p = jax.tree.map(lambda a: a[0], init_moe(jax.random.PRNGKey(0), cfg, 1,
                                              jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 16))
    aux = {}
    y, _ = moe_block(p, x, cfg, aux=aux)
    assert float(aux["moe/drop_frac"]) > 0.0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_streaming_attention_matches_dense():
    from repro.models.layers import streaming_attention

    B, S, H, KV, hd = 2, 33, 4, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    out = streaming_attention(q, k, v, q_offset=0, causal=True, chunk=8)
    # dense reference
    qg = q.reshape(B, S, KV, H // KV, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k) * hd ** -0.5
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    ref = jnp.einsum("bkgqs,bskd->bqkgd", p, v).reshape(B, S, H, hd)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_streaming_attention_sliding_window():
    from repro.models.layers import streaming_attention

    B, S, H, hd, W = 1, 24, 2, 4, 5
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    out = streaming_attention(q, k, v, q_offset=0, causal=True, window=W,
                              chunk=8)
    s = jnp.einsum("bqhd,bshd->bhqs", q, k) * hd ** -0.5
    i = jnp.arange(S)
    mask = (i[None, :] <= i[:, None]) & (i[None, :] > i[:, None] - W)
    s = jnp.where(mask[None, None], s, -1e30)
    ref = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)
