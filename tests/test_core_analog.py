"""Core analog engine: exactness in the error-free limit for every mapping
scheme, FPG exactness, unit-column behaviour, and the paper's sensitivity
orderings at dot-product level."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import analog as A
from repro.core import errors as E
from repro.core.adc import ADCConfig
from repro.core.mapping import MappingConfig
from repro.core.quant import quantize_acts, quantize_weights

K, N, M = 96, 24, 7
W = jax.random.normal(jax.random.PRNGKey(0), (K, N)) * 0.05
X = jax.random.normal(jax.random.PRNGKey(1), (M, K))
NONE_ADC = ADCConfig(style="none")


def _quant_ref(spec):
    m = spec.mapping
    mag = None if m.scheme == "offset" else m.magnitude_bits
    qw = quantize_weights(W, m.weight_bits, magnitude_bits=mag)
    qx = quantize_acts(X, spec.input_bits, signed=True)
    return (qx.values @ qw.values) * qw.scale * qx.scale


@pytest.mark.parametrize("scheme", ["differential", "offset"])
@pytest.mark.parametrize("bpc", [None, 1, 2, 4])
@pytest.mark.parametrize("accum", ["analog", "digital"])
@pytest.mark.parametrize("onoff", [float("inf"), 100.0])
def test_error_free_exactness(scheme, bpc, accum, onoff):
    mc = MappingConfig(scheme=scheme, bits_per_cell=bpc, on_off_ratio=onoff)
    spec = A.AnalogSpec(mapping=mc, adc=NONE_ADC, input_accum=accum,
                        max_rows=40)
    aw = A.program(W, spec)
    y = A.analog_matmul(X, aw, spec)
    ref = _quant_ref(spec)
    rel = float(jnp.max(jnp.abs(y - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-5


@pytest.mark.parametrize("scheme,accum", [
    ("differential", "analog"), ("offset", "digital"),
    ("differential", "digital"), ("offset", "analog"),
])
@pytest.mark.parametrize("bpc", [None, 2])
def test_fpg_is_exact(scheme, accum, bpc):
    mc = MappingConfig(scheme=scheme, bits_per_cell=bpc)
    spec = A.AnalogSpec(mapping=mc, adc=ADCConfig(style="fpg"),
                        input_accum=accum, max_rows=40)
    aw = A.program(W, spec)
    y = A.analog_matmul(X, aw, spec)
    spec0 = A.AnalogSpec(mapping=mc, adc=NONE_ADC, input_accum=accum,
                         max_rows=40)
    y0 = A.analog_matmul(X, aw, spec0)
    rel = float(jnp.max(jnp.abs(y - y0)) / jnp.max(jnp.abs(y0)))
    assert rel < 1e-5, "FPG must provide a level per possible output"


def test_unit_column_exact_without_errors():
    mc = MappingConfig(scheme="offset", bits_per_cell=2, unit_column=True)
    spec = A.AnalogSpec(mapping=mc, adc=NONE_ADC, input_accum="digital",
                        max_rows=40)
    aw = A.program(W, spec)
    y = A.analog_matmul(X, aw, spec)
    ref = _quant_ref(spec)
    np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-5)


def test_unit_column_correlates_errors():
    """Sec 5.2: the unit column increases error vs digital offset."""
    mc_u = MappingConfig(scheme="offset", bits_per_cell=2, unit_column=True)
    mc_d = MappingConfig(scheme="offset", bits_per_cell=2)
    errs = {}
    for name, mc in (("unit", mc_u), ("digital", mc_d)):
        spec = A.AnalogSpec(mapping=mc, adc=NONE_ADC, input_accum="digital",
                            max_rows=1152,
                            error=E.state_independent(0.02))
        spec0 = A.AnalogSpec(mapping=mc, adc=NONE_ADC, input_accum="digital",
                             max_rows=1152)
        y0 = A.analog_matmul(X, A.program(W, spec0), spec0)
        es = []
        for t in range(5):
            aw = A.program(W, spec, jax.random.PRNGKey(t))
            y = A.analog_matmul(X, aw, spec)
            es.append(float(jnp.sqrt(jnp.mean((y - y0) ** 2))))
        errs[name] = np.mean(es)
    assert errs["unit"] > errs["digital"]


def _dot_err(scheme, err, accum):
    mc = MappingConfig(scheme=scheme)
    spec = A.AnalogSpec(mapping=mc, adc=NONE_ADC, error=err,
                        input_accum=accum, max_rows=1152)
    spec0 = A.AnalogSpec(mapping=mc, adc=NONE_ADC, input_accum=accum,
                         max_rows=1152)
    y0 = A.analog_matmul(X, A.program(W, spec0), spec0)
    es = []
    for t in range(4):
        aw = A.program(W, spec, jax.random.PRNGKey(100 + t))
        y = A.analog_matmul(X, aw, spec)
        es.append(float(jnp.sqrt(jnp.mean((y - y0) ** 2)) / jnp.std(y0)))
    return np.mean(es)


def test_paper_orderings():
    e_off_ind = _dot_err("offset", E.state_independent(0.02), "digital")
    e_dif_ind = _dot_err("differential", E.state_independent(0.02), "analog")
    e_off_prp = _dot_err("offset", E.state_proportional(0.04), "digital")
    e_dif_prp = _dot_err("differential", E.state_proportional(0.04), "analog")
    assert e_dif_ind < e_off_ind          # Fig. 8: differential beats offset
    assert e_dif_prp < 0.3 * e_off_prp    # Fig. 9: >>x with proportionality
    assert e_dif_prp < e_dif_ind          # Sec. 5.3
    # offset cannot tell the two error types apart (Sec. 5.3):
    assert 0.5 < e_off_ind / (e_off_prp / 2.0) < 2.0


def test_adc_conversion_counts():
    a = A.design_a()
    e = A.design_e()
    assert a.adc_conversions_per_mvm(1152, 256) == 256
    assert e.adc_conversions_per_mvm(1152, 256) == 256 * 4 * 16 * 7
    # Table 3 B_out values
    assert a.fpg_adc_bits(1152) == 27   # 26.2 rounded up
    assert e.fpg_adc_bits(1152) in (9, 10)  # 8.2 + signed-input bit


def test_sonos_error_model_shape():
    em = E.sonos()
    g = jnp.linspace(0.0, 1.0, 11)
    s = em.sigma(g)
    # proportional at low g with slope ~6%
    assert abs(float(s[1] / g[1]) - 0.06) < 0.01
    # saturating near 0.031 at the top
    assert float(s[-1]) < 0.033
    assert bool(jnp.all(jnp.diff(s) >= -1e-9))


# ---------------------------------------------------------------------------
# use_pallas integration: the kernel-routed paths must match the dense
# oracle paths through the full analog_matmul pipeline, parasitics included
# ---------------------------------------------------------------------------


def test_use_pallas_parasitic_fastpath_matches_dense():
    """Design A + r_hat > 0 + calibrated ADC: the fused parasitic kernel
    (analog_mvm_parasitic) vs the dense scan oracle, end to end."""
    import dataclasses

    spec_d = A.design_a(r_hat=1e-4, use_pallas=False)
    spec_p = dataclasses.replace(spec_d, use_pallas=True)
    aw = A.program(W, spec_d, jax.random.PRNGKey(5))
    _, stats = A.analog_matmul(X, aw, spec_d, collect=True)
    lo, hi = stats[:, 0], stats[:, 1]
    y_d = A.analog_matmul(X, aw, spec_d, adc_lo=lo, adc_hi=hi)
    y_p = A.analog_matmul(X, aw, spec_p, adc_lo=lo, adc_hi=hi)
    # quantizer tolerance: isolated ADC-boundary flips only
    lsb = float((hi[0] - lo[0]) / 255.0)
    gain = 127.0 / (1.0 - spec_d.mapping.g_min)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_d),
                               atol=lsb * gain * float(aw.w_scale) * 1.01)
    tight = np.isclose(np.asarray(y_p), np.asarray(y_d), rtol=1e-4).mean()
    assert tight >= 0.95, f"only {tight:.2%} bit-close"


@pytest.mark.parametrize("scheme,accum,bpc,rows", [
    ("offset", "digital", 2, 72),        # sliced: _apply_line branch
    ("differential", "digital", None, 96),
])
def test_use_pallas_apply_line_matches_dense(scheme, accum, bpc, rows):
    """Non-fastpath parasitic configs route _apply_line through the Pallas
    Thomas kernel when use_pallas is set; the dense lax.scan path is the
    parity oracle."""
    import dataclasses

    spec = A.AnalogSpec(
        mapping=MappingConfig(scheme=scheme, weight_bits=8,
                              bits_per_cell=bpc),
        adc=NONE_ADC, input_accum=accum, max_rows=rows, r_hat=1e-4,
    )
    aw = A.program(W, spec, jax.random.PRNGKey(6))
    y_d = A.analog_matmul(X, aw, spec)
    y_p = A.analog_matmul(X, aw, dataclasses.replace(spec, use_pallas=True))
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_d),
                               rtol=1e-3, atol=1e-4)
