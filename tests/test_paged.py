"""Paged-KV differential tier: the paged runtime is pinned bit-exact
against the dense-slot runtime (tier-1), with the long mixed-trace grid
in tier-2.

The contract under test (DESIGN.md §Paged-KV-and-prefix-sharing): the
KV *layout* — paged pool, block tables, shared prefix pages, page
eviction/readmission — must never change a single emitted token.  The
dense :class:`ServeRuntime` stays in the tree as the differential
oracle; every test here serves the same request set through both
runtimes and asserts token-for-token equality, greedy and seeded,
digital and analog, uniform and heterogeneous packs, with and without
mid-stream healing."""

import numpy as np
import jax
import pytest

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.data.synthetic import SyntheticLM
from repro.hw import DIGITAL, Profile
from repro.models.registry import get_model
from repro.serve import (
    HealPolicy,
    PagedServeRuntime,
    SamplerConfig,
    ServeRuntime,
    calibrate_lm,
    program_lm,
)
from repro.sweep.serve_eval import paged_runtime_agreement


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _mixed_trace(cfg, n, seed=0, lens=(3, 14), new=(2, 6), prefix_len=9):
    """Requests with heavy prefix sharing: every other prompt opens with
    the same ``prefix_len`` tokens (the system-prompt pattern)."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab, size=prefix_len).astype(np.int32)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(*lens))
        p = rng.integers(0, cfg.vocab, size=plen).astype(np.int32)
        if i % 2 == 0:
            k = min(prefix_len, plen - 1)
            p[:k] = prefix[:k]
        reqs.append((p, int(rng.integers(*new))))
    return reqs


def _serve(rt, reqs):
    for i, (p, n) in enumerate(reqs):
        rt.submit(p, max_new_tokens=n, uid=f"r{i}")
    return rt.run()


# ---------------------------------------------------------------------------
# paged == dense, token for token
# ---------------------------------------------------------------------------


def test_paged_matches_dense_digital_greedy(lm):
    cfg, params = lm
    agree = paged_runtime_agreement(
        cfg, params, _mixed_trace(cfg, 8), max_slots=4, max_len=24,
        page_size=4)
    assert agree == 1.0


def test_paged_matches_dense_seeded_sampling(lm):
    """Bit-identity must survive stochastic sampling: per-request keys
    fold from uids in both runtimes, so the streams coincide exactly."""
    cfg, params = lm
    agree = paged_runtime_agreement(
        cfg, params, _mixed_trace(cfg, 6, seed=1), max_slots=4,
        max_len=24, page_size=4,
        sampler=SamplerConfig(kind="temperature", temperature=0.8), seed=11)
    assert agree == 1.0


def test_paged_matches_dense_analog_pack(lm):
    cfg, params = lm
    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    pack = program_lm(cfg, params, A.design_a(error=E.state_independent(0.05)),
                      jax.random.PRNGKey(5))
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    # few distinct shapes to bound compile cost
    reqs = _mixed_trace(cfg, 5, seed=2, lens=(5, 7), new=(4, 6))
    agree = paged_runtime_agreement(cfg, params, reqs, pack=pack,
                                    max_slots=2, max_len=16, page_size=4)
    assert agree == 1.0


def test_paged_matches_dense_hetero_profile(lm):
    """Heterogeneous per-site hardware resolves identically through the
    paged runtime — the pack carries its own site resolution."""
    cfg, params = lm
    spec8 = A.design_a(error=E.state_proportional(0.05))
    profile = Profile.by_class(attn=spec8, mlp=spec8, head=DIGITAL)
    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    pack = program_lm(cfg, params, profile, jax.random.PRNGKey(5))
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    reqs = _mixed_trace(cfg, 4, seed=3, lens=(5, 7), new=(4, 6))
    agree = paged_runtime_agreement(cfg, params, reqs, pack=pack,
                                    max_slots=2, max_len=16, page_size=4)
    assert agree == 1.0


def test_paged_heal_preserves_tokens(lm):
    """Mid-stream reprogramming (PR 6's self-healing) composes with the
    paged layout: a healed paged runtime with numerically inert aging
    serves exactly what the unhealed dense runtime serves."""
    from repro.serve import PackManager

    cfg, params = lm
    calib = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4,
                        seed=0).batch(1)["tokens"]
    mk = lambda: PackManager(
        cfg, params, A.design_a(error=E.none(), drift=E.power_law_drift(0.0)),
        jax.random.PRNGKey(5), calib_tokens=calib)
    reqs = _mixed_trace(cfg, 4, seed=4, lens=(5, 7), new=(4, 6))
    m0 = mk()
    dense = ServeRuntime(cfg, params, pack=m0.aged(1.0), max_slots=2,
                         max_len=16)
    paged = PagedServeRuntime(
        cfg, params, manager=mk(), max_slots=2, max_len=16, page_size=4,
        heal=HealPolicy(check_every=1, loss_mult=0.0, loss_add=-1.0,
                        bands_per_step=1))
    ref, got = _serve(dense, reqs), _serve(paged, reqs)
    paged.check()
    assert paged.stats["heal_events"] > 0
    assert paged.stats["bands_reprogrammed"] > 0
    for uid in ref:
        np.testing.assert_array_equal(ref[uid], got[uid])


# ---------------------------------------------------------------------------
# prefix cache: hits bit-identical to cold, replay identity
# ---------------------------------------------------------------------------


def test_prefix_hit_bit_identical_to_cold(lm):
    """The same trace with the radix cache on and off emits identical
    tokens — a hit replays cached K/V that is bitwise what the cold
    path would recompute."""
    cfg, params = lm
    reqs = _mixed_trace(cfg, 8, seed=5)
    outs = {}
    for cached in (False, True):
        rt = PagedServeRuntime(cfg, params, max_slots=4, max_len=24,
                               page_size=4, prefix_cache=cached)
        outs[cached] = _serve(rt, reqs)
        rt.check()
        hits = rt.stats["prefix_hits"]
        assert hits > 0 if cached else hits == 0
    for uid in outs[False]:
        np.testing.assert_array_equal(outs[False][uid], outs[True][uid])


def test_eviction_readmission_replay_identity(lm):
    """A pool too small to keep everything forces cache eviction; a
    re-submitted prompt must replay identically whether its pages
    survived in the radix cache or were evicted and recomputed."""
    cfg, params = lm
    rng = np.random.default_rng(6)
    prompts = [rng.integers(0, cfg.vocab, size=9).astype(np.int32)
               for _ in range(4)]
    # pool: sink + 8 pages; each request needs 4 -> constant pressure
    rt = PagedServeRuntime(cfg, params, max_slots=2, max_len=16,
                           page_size=4, num_pages=9)
    first = {}
    for i, p in enumerate(prompts):
        first[i] = _serve(rt, [(p, 4)])[f"r0"]
        rt.check()
    assert rt.stats["cache_evictions"] > 0
    for i, p in enumerate(prompts):       # round 2: replay identity
        uid = rt.submit(p, max_new_tokens=4, uid=f"again{i}")
        np.testing.assert_array_equal(rt.run()[uid], first[i])
        rt.check()


# ---------------------------------------------------------------------------
# scheduler capacity: prefill-retired lanes, backpressure
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("paged", [False, True])
def test_retired_at_prefill_frees_capacity_same_step(lm, paged):
    """A burst of 1-token-budget requests retires at prefill; the
    admission loop must recycle those slots (and pages) immediately —
    the whole burst drains in ONE scheduler step with zero decode
    steps, instead of leaking occupancy until the next decode."""
    cfg, params = lm
    rng = np.random.default_rng(7)
    kw = dict(max_slots=4, max_len=16)
    rt = (PagedServeRuntime(cfg, params, page_size=4, **kw) if paged
          else ServeRuntime(cfg, params, **kw))
    for i in range(12):
        rt.submit(rng.integers(0, cfg.vocab, size=5).astype(np.int32),
                  max_new_tokens=1, uid=f"b{i}")
    done = rt.step()
    assert len(done) == 12 and rt.idle
    assert rt.stats["decode_steps"] == 0
    if paged:
        rt.check()
        assert rt.page_stats["resident_pages"] == 0


def test_pool_backpressure_preserves_fifo(lm):
    """When the pool cannot hold the queue head, admission stalls (the
    request is NOT skipped over) and resumes as capacity frees."""
    cfg, params = lm
    rng = np.random.default_rng(8)
    rt = PagedServeRuntime(cfg, params, max_slots=4, max_len=16,
                           page_size=4, num_pages=9, prefix_cache=False)
    reqs = [(rng.integers(0, cfg.vocab, size=10).astype(np.int32), 4)
            for _ in range(5)]
    out = _serve(rt, reqs)
    rt.check()
    assert sorted(out) == sorted(f"r{i}" for i in range(5))
    assert all(v.size == 4 for v in out.values())
    assert rt.stats["admission_stalls"] > 0
    assert rt.page_stats["free_pages"] == rt.num_pages - 1


# ---------------------------------------------------------------------------
# validation + pallas backend
# ---------------------------------------------------------------------------


def test_paged_validation_errors(lm):
    cfg, params = lm
    with pytest.raises(ValueError, match="multiple of"):
        PagedServeRuntime(cfg, params, max_len=30, page_size=4)
    with pytest.raises(ValueError, match="gang"):
        PagedServeRuntime(cfg, params, max_len=16, page_size=4, gang=True)
    with pytest.raises(ValueError, match="backend"):
        PagedServeRuntime(cfg, params, max_len=16, page_size=4,
                          backend="dense")
    with pytest.raises(ValueError, match="attn_backend"):
        # flash decode reads the dense per-slot cache; the paged decode
        # path must refuse it rather than silently stream
        PagedServeRuntime(cfg, params, max_len=16, page_size=4,
                          attn_backend="flash")
    with pytest.raises(ValueError, match="num_pages"):
        PagedServeRuntime(cfg, params, max_len=16, page_size=4, num_pages=3)
    with pytest.raises(ValueError, match="page_size"):
        PagedServeRuntime(cfg, params, max_len=16, page_size=0)
    # num_pages >= 1 + max_len/page_size (checked above) guarantees any
    # request admissible by the base validation also fits the pool, so
    # submit needs no extra paged check — base errors still fire:
    rt = PagedServeRuntime(cfg, params, max_len=16, page_size=4)
    with pytest.raises(ValueError, match="max_new_tokens"):
        rt.submit(np.arange(4, dtype=np.int32) % cfg.vocab,
                  max_new_tokens=0)


def test_pallas_backend_serves_end_to_end(lm):
    """The in-kernel block-table gather backend drains a mixed trace
    (numerical-equivalence path; bit-exactness vs the jnp oracle is
    pinned per-kernel in test_kernels.py)."""
    cfg, params = lm
    reqs = _mixed_trace(cfg, 4, seed=9, lens=(5, 7), new=(3, 5))
    rt = PagedServeRuntime(cfg, params, max_slots=2, max_len=16,
                           page_size=8, backend="pallas")
    out = _serve(rt, reqs)
    rt.check()
    assert all(out[f"r{i}"].size == n for i, (_, n) in enumerate(reqs))


# ---------------------------------------------------------------------------
# tier-2: the long mixed-trace grid
# ---------------------------------------------------------------------------


@pytest.mark.tier2
@pytest.mark.parametrize("page_size", [2, 4, 8])
def test_paged_matches_dense_long_trace(lm, page_size):
    cfg, params = lm
    for sampler, seed in ((SamplerConfig(), 0),
                          (SamplerConfig(kind="top_k", top_k=16), 3)):
        agree = paged_runtime_agreement(
            cfg, params, _mixed_trace(cfg, 24, seed=10, lens=(3, 20),
                                      new=(2, 10), prefix_len=12),
            max_slots=4, max_len=32, page_size=page_size,
            sampler=sampler, seed=seed)
        assert agree == 1.0


@pytest.mark.tier2
def test_paged_matches_dense_long_trace_analog(lm):
    cfg, params = lm
    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    pack = program_lm(cfg, params, A.design_a(error=E.state_independent(0.05)),
                      jax.random.PRNGKey(5))
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    agree = paged_runtime_agreement(
        cfg, params, _mixed_trace(cfg, 12, seed=11, lens=(4, 12), new=(3, 8)),
        pack=pack, max_slots=4, max_len=24, page_size=4)
    assert agree == 1.0
