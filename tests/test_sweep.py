"""Sweep-engine tests: grid expansion, compile-group batching, the
vectorized-vs-serial equivalence contract, and cache resume.

The equivalence tests are the acceptance gate for the engine: the same
seeds must produce the same accuracies as the legacy per-point serial
loop.  ADC-free paths are bit-exact; calibrated-ADC paths with traced
dynamic scalars are allowed isolated ADC-rounding-boundary flips
(DESIGN.md §Sweep-engine), bounded here to a few test samples."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec, program, program_codes, program_from_codes
from repro.core.errors import state_independent, state_proportional
from repro.core.mapping import MappingConfig
from repro.analysis import CompileContract, check_contract
from repro.sweep import (
    Axis,
    ClassifierEvaluator,
    FunctionEvaluator,
    SweepSpec,
    compile_groups,
    point_key,
    run_sweep,
    serial_accuracy,
)


@pytest.fixture(scope="module")
def vehicle():
    """Tiny random classifier + splits (the pipeline, not the training)."""
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    dims = (16, 32, 8)
    layers = [
        (jax.random.normal(ks[i], (dims[i], dims[i + 1])) * dims[i] ** -0.5,
         jnp.zeros((dims[i + 1],)))
        for i in range(2)
    ]
    xca = jax.random.normal(ks[3], (64, 16))
    xte = jax.random.normal(ks[4], (128, 16))
    yte = jax.random.randint(ks[5], (128,), 0, 8)
    return layers, xca, xte, yte


def _evaluator(vehicle):
    return ClassifierEvaluator(*vehicle)


# ---------------------------------------------------------------------------
# spec expansion
# ---------------------------------------------------------------------------

def test_expand_cartesian_and_zipped():
    sweep = SweepSpec(
        name="t",
        base=AnalogSpec(adc=ADCConfig(style="none")),
        axes=(
            Axis(("mapping.scheme", "input_accum"),
                 (("differential", "analog"), ("offset", "digital")),
                 labels=("diff", "off")),
            Axis("adc.bits", (6, 8)),
        ),
    )
    pts = sweep.expand()
    assert len(pts) == 4
    assert [p.tag for p in pts] == ["diff_bits6", "diff_bits8",
                                    "off_bits6", "off_bits8"]
    assert pts[0].spec.mapping.scheme == "differential"
    assert pts[0].spec.input_accum == "analog"
    assert pts[2].spec.input_accum == "digital"
    assert pts[3].spec.adc.bits == 8
    assert pts[1].coord("adc.bits") == 8


def test_expand_explicit_points():
    sweep = SweepSpec.from_points(
        "t", [("A", AnalogSpec()), ("B", AnalogSpec(max_rows=72))])
    pts = sweep.expand()
    assert [p.tag for p in pts] == ["A", "B"]
    assert pts[1].spec.max_rows == 72


# ---------------------------------------------------------------------------
# compile-group batching
# ---------------------------------------------------------------------------

def _alpha_sweep():
    return SweepSpec(
        name="t",
        base=AnalogSpec(adc=ADCConfig(style="none"),
                        error=state_proportional(0.0)),
        axes=(Axis("error.alpha", (0.01, 0.02, 0.05, 0.1)),),
        trials=2,
    )


def test_alpha_grid_is_one_compile_group(vehicle):
    """Declared as a CompileContract (repro.analysis): 4 alpha values,
    one compiled program, alpha traced."""
    c = CompileContract(
        name="test/alpha-axis",
        sweep=_alpha_sweep(),
        evaluator=lambda: _evaluator(vehicle),
        max_groups=1,
        expect_dynamic=(("error.alpha",),),
        require_dynamic=("error.alpha",),
    )
    assert check_contract(c, "static") == []


def test_constant_dynamic_field_stays_static(vehicle):
    """A field that does not vary must not be traced (bit-exactness)."""
    c = CompileContract(
        name="test/constant-field-static",
        sweep=SweepSpec(
            name="t",
            base=AnalogSpec(adc=ADCConfig(style="none"),
                            error=state_proportional(0.05)),
            axes=(Axis("max_rows", (72, 1152)),),
            trials=1,
        ),
        evaluator=lambda: _evaluator(vehicle),
        # max_rows is static: separate shapes; alpha/on_off constant ->
        # not dynamic in either group
        max_groups=2, min_groups=2,
        expect_dynamic=((),),
    )
    assert check_contract(c, "static") == []


def test_r_hat_axis_is_one_compile_group(vehicle):
    """The Fig. 19 parasitic axis batches as a traced scalar: every
    ``r_hat > 0`` level shares one compiled program (the tridiagonal solve
    is structurally identical), instead of one compile group per level."""
    c = CompileContract(
        name="test/r-hat-axis",
        sweep=SweepSpec(
            name="t",
            base=AnalogSpec(adc=ADCConfig(style="none"), max_rows=64),
            axes=(Axis("r_hat", (1e-5, 1e-4, 1e-3)),),
            trials=1,
        ),
        evaluator=lambda: _evaluator(vehicle),
        max_groups=1,
        require_dynamic=("r_hat",),
    )
    assert check_contract(c, "static") == []


def test_r_hat_on_off_split_is_static(vehicle):
    """``r_hat == 0`` is a different compiled program (no solve in the
    graph): it must land in its own group, never be traced to zero."""
    c = CompileContract(
        name="test/r-hat-on-off-split",
        sweep=SweepSpec(
            name="t",
            base=AnalogSpec(adc=ADCConfig(style="none"), max_rows=64),
            axes=(Axis("r_hat", (0.0, 1e-4, 1e-3)),),
            trials=1,
        ),
        evaluator=lambda: _evaluator(vehicle),
        max_groups=2, min_groups=2,
        expect_dynamic=((), ("r_hat",)),
        require_dynamic=("r_hat",),
    )
    assert check_contract(c, "static") == []


def test_compile_contract_canary(vehicle):
    """The checker validated against the old method: the original
    hand-written compile_groups assertions for the alpha grid, side by
    side with the CompileContract declaration of the same pin — and a
    falsified declaration must fail."""
    import dataclasses

    ev = _evaluator(vehicle)
    sweep = _alpha_sweep()
    pts = sweep.expand()
    groups = compile_groups(
        [(point_key(ev.signature(), p, sweep.point_protocol()), p)
         for p in pts], ev)
    # the original PR 3 pin, verbatim
    assert len(groups) == 1
    _, dyn_names, members = groups[0]
    assert dyn_names == ("error.alpha",)
    assert len(members) == 4
    # the declaration agrees with the raw partition
    c = CompileContract(
        name="test/canary", sweep=sweep,
        evaluator=lambda: _evaluator(vehicle),
        max_groups=1, expect_dynamic=(("error.alpha",),),
        require_dynamic=("error.alpha",))
    assert check_contract(c, "static") == []
    # and the checker actually discriminates: tighten the budget past
    # what the raw partition shows and it must report
    wrong = dataclasses.replace(c, max_groups=0)
    assert len(check_contract(wrong, "static")) == 1


# ---------------------------------------------------------------------------
# vectorized == serial
# ---------------------------------------------------------------------------

def test_vectorized_matches_serial_bitexact_no_adc(vehicle):
    layers, xca, xte, yte = vehicle
    sweep = SweepSpec(
        name="t",
        base=AnalogSpec(
            mapping=MappingConfig(scheme="differential"),
            adc=ADCConfig(style="none"),
            error=state_proportional(0.0),
            input_accum="analog",
        ),
        axes=(
            Axis("error.alpha", (0.02, 0.1)),
            Axis("mapping.on_off_ratio", (100.0, float("inf"))),
        ),
        trials=3,
        seed=7,
    )
    res = run_sweep(sweep, _evaluator(vehicle))
    pts = sweep.expand()
    assert len(res) == 4
    for r in res:
        _, _, accs = serial_accuracy(
            layers, pts[r.index].spec, xca, xte, yte, trials=3, seed=7)
        np.testing.assert_array_equal(np.asarray(r.values), np.asarray(accs))


def test_vectorized_matches_serial_fig19_r_hat_axis(vehicle):
    """Fig. 19 at engine scale: the whole parasitic axis runs as one
    compile group with ``r_hat`` traced, and still reproduces the serial
    per-point loop bit-exactly (ADC-free path)."""
    layers, xca, xte, yte = vehicle
    sweep = SweepSpec(
        name="t",
        base=AnalogSpec(
            mapping=MappingConfig(scheme="differential", on_off_ratio=1e4),
            adc=ADCConfig(style="none"),
            error=state_proportional(0.02),
            input_accum="analog",
            max_rows=64,
        ),
        axes=(Axis("r_hat", (1e-5, 1e-4, 1e-3)),),
        trials=2,
        seed=7,
    )
    res = run_sweep(sweep, _evaluator(vehicle))
    pts = sweep.expand()
    assert len(res) == 3
    for r in res:
        _, _, accs = serial_accuracy(
            layers, pts[r.index].spec, xca, xte, yte, trials=2, seed=7)
        np.testing.assert_array_equal(np.asarray(r.values), np.asarray(accs))


def test_vectorized_matches_serial_calibrated_adc(vehicle):
    layers, xca, xte, yte = vehicle
    sweep = SweepSpec(
        name="t",
        base=AnalogSpec(
            mapping=MappingConfig(scheme="offset", bits_per_cell=2,
                                  on_off_ratio=1e4),
            adc=ADCConfig(style="calibrated", bits=8),
            error=state_independent(0.0),
            input_accum="digital",
            max_rows=72,
        ),
        axes=(Axis("error.alpha", (0.01, 0.05)),),
        trials=2,
        seed=7,
    )
    res = run_sweep(sweep, _evaluator(vehicle))
    pts = sweep.expand()
    # traced-alpha batching may flip isolated ADC rounding boundaries:
    # allow up to 2 of 128 test samples per trial, no more.
    tol = 2.0 / xte.shape[0] + 1e-9
    for r in res:
        _, _, accs = serial_accuracy(
            layers, pts[r.index].spec, xca, xte, yte, trials=2, seed=7)
        np.testing.assert_allclose(np.asarray(r.values), np.asarray(accs),
                                   atol=tol)


def test_program_split_is_identity(vehicle):
    layers, _, _, _ = vehicle
    w = layers[0][0]
    spec = AnalogSpec(
        mapping=MappingConfig(scheme="differential", bits_per_cell=2,
                              on_off_ratio=1e3),
        error=state_proportional(0.05),
    )
    key = jax.random.PRNGKey(3)
    direct = program(w, spec, key)
    split = program_from_codes(program_codes(w, spec), spec, key)
    np.testing.assert_array_equal(np.asarray(direct.g_pos),
                                  np.asarray(split.g_pos))
    np.testing.assert_array_equal(np.asarray(direct.g_neg),
                                  np.asarray(split.g_neg))


# ---------------------------------------------------------------------------
# cache resume
# ---------------------------------------------------------------------------

class _CountingEvaluator:
    """Delegates to a real evaluator, counting group evaluations."""

    def __init__(self, inner):
        self.inner = inner
        self.calls = 0

    def signature(self):
        return self.inner.signature()

    def dynamic_fields(self, spec):
        return self.inner.dynamic_fields(spec)

    def evaluate_group(self, *a, **kw):
        self.calls += 1
        return self.inner.evaluate_group(*a, **kw)


def _cache_sweep():
    return SweepSpec(
        name="cache_t",
        base=AnalogSpec(adc=ADCConfig(style="none"),
                        error=state_proportional(0.0)),
        axes=(Axis("error.alpha", (0.02, 0.1)),),
        trials=2,
    )


def test_resume_from_cache(vehicle, tmp_path):
    ev = _CountingEvaluator(_evaluator(vehicle))
    res1 = run_sweep(_cache_sweep(), ev, cache_dir=str(tmp_path))
    assert ev.calls == 1
    assert res1.n_cached == 0
    assert (tmp_path / "sweeps" / "cache_t.json").exists()

    # same sweep, fresh run: everything served from disk
    res2 = run_sweep(_cache_sweep(), ev, cache_dir=str(tmp_path))
    assert ev.calls == 1              # no new group evaluations
    assert res2.n_cached == 2
    for r1, r2 in zip(res1, res2):
        assert r1.values == r2.values
        assert r1.tag == r2.tag

    # widened grid: only the new point runs
    wider = dataclasses.replace(
        _cache_sweep(), axes=(Axis("error.alpha", (0.02, 0.1, 0.2)),))
    res3 = run_sweep(wider, ev, cache_dir=str(tmp_path))
    assert ev.calls == 2
    assert res3.n_cached == 2
    assert len(res3) == 3

    # force recomputes everything and agrees with the cached values
    res4 = run_sweep(_cache_sweep(), ev, cache_dir=str(tmp_path), force=True)
    assert ev.calls == 3
    for r1, r4 in zip(res1, res4):
        assert r1.values == r4.values


def test_cache_misses_on_evaluator_signature_change(vehicle, tmp_path):
    """A version bump in the evaluator signature must invalidate points."""
    ev1 = _CountingEvaluator(ClassifierEvaluator(*vehicle, version="v1"))
    run_sweep(_cache_sweep(), ev1, cache_dir=str(tmp_path))
    assert ev1.calls == 1

    ev2 = _CountingEvaluator(ClassifierEvaluator(*vehicle, version="v2"))
    res = run_sweep(_cache_sweep(), ev2, cache_dir=str(tmp_path))
    assert ev2.calls == 1, "changed signature must miss the cache"
    assert res.n_cached == 0


def test_cache_misses_on_spec_change(vehicle, tmp_path):
    """Any spec field outside the axes must be part of the cache key."""
    ev = _CountingEvaluator(_evaluator(vehicle))
    run_sweep(_cache_sweep(), ev, cache_dir=str(tmp_path))
    assert ev.calls == 1

    changed = dataclasses.replace(
        _cache_sweep(),
        base=dataclasses.replace(_cache_sweep().base, input_bits=7))
    res = run_sweep(changed, ev, cache_dir=str(tmp_path))
    assert ev.calls == 2, "changed base spec must miss the cache"
    assert res.n_cached == 0


def test_cache_misses_on_trial_protocol_change(vehicle, tmp_path):
    """trials / seed / test_n are part of a point's cache identity."""
    ev = _CountingEvaluator(_evaluator(vehicle))
    run_sweep(_cache_sweep(), ev, cache_dir=str(tmp_path))
    calls = ev.calls
    for change in (dict(trials=3), dict(seed=99), dict(test_n=32)):
        res = run_sweep(
            dataclasses.replace(_cache_sweep(), **change), ev,
            cache_dir=str(tmp_path))
        calls += 1
        assert ev.calls == calls, f"{change} must miss the cache"
        assert res.n_cached == 0


def test_cache_hits_on_axis_reordering(vehicle, tmp_path):
    """Reordering unrelated grid factors yields the same spec set and
    must be served fully from cache (identity is the spec, not the tag
    or expansion order)."""
    ab = SweepSpec(
        name="reorder_t",
        base=AnalogSpec(adc=ADCConfig(style="none"),
                        error=state_proportional(0.0)),
        axes=(Axis("error.alpha", (0.02, 0.1)),
              Axis("max_rows", (72, 1152))),
        trials=1,
    )
    ba = dataclasses.replace(ab, axes=tuple(reversed(ab.axes)))
    ev = _CountingEvaluator(_evaluator(vehicle))
    res1 = run_sweep(ab, ev, cache_dir=str(tmp_path))
    calls = ev.calls
    res2 = run_sweep(ba, ev, cache_dir=str(tmp_path))
    assert ev.calls == calls, "reordered axes must hit the cache"
    assert res2.n_cached == len(res2) == 4
    by_spec1 = {repr(ab.expand()[r.index].spec): r.values for r in res1}
    by_spec2 = {repr(ba.expand()[r.index].spec): r.values for r in res2}
    assert by_spec1 == by_spec2


def test_function_evaluator_vmapped_trials(tmp_path):
    def probe(spec, key):
        return jax.random.normal(key, ()) * 0.0 + spec.mapping.g_min

    sweep = SweepSpec(
        name="fn_t",
        base=AnalogSpec(),
        axes=(Axis("mapping.on_off_ratio", (10.0, 100.0)),),
        trials=3,
    )
    ev = FunctionEvaluator(probe, name="probe", takes_key=True)
    res = run_sweep(sweep, ev, cache_dir=str(tmp_path))
    assert len(res) == 2
    assert res["on_off_ratio10"].values == pytest.approx([0.1] * 3)
    assert res["on_off_ratio100"].values == pytest.approx([0.01] * 3)
    # resume: no recomputation, same values
    res2 = run_sweep(sweep, ev, cache_dir=str(tmp_path))
    assert res2.n_cached == 2
    assert res2["on_off_ratio10"].values == res["on_off_ratio10"].values
