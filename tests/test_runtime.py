"""Continuous-batching runtime tests (tier-1, no training).

The serving contract: scheduling must never change what the model says.
Variable-length prompts drained through the slot-scheduled runtime must
match per-request ``decode_lm`` token-for-token under greedy decoding —
digital and through an analog pack — and sampled decoding must be a
pure function of the per-request key (stable uid hash, never admission
order), mirroring how programming keys fold from stable hook-name
hashes (``tests/test_serve_engine.py``)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.models import transformer
from repro.models.registry import get_model
from repro.serve import (
    SamplerConfig,
    ServeRuntime,
    calibrate_lm,
    decode_lm,
    program_lm,
)
from repro.sweep.serve_eval import runtime_agreement


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _trace(cfg, n, seed=0, lens=(3, 15), new=(2, 9)):
    rng = np.random.default_rng(seed)
    return [
        (rng.integers(0, cfg.vocab, size=int(rng.integers(*lens)))
         .astype(np.int32),
         int(rng.integers(*new)))
        for _ in range(n)
    ]


# ---------------------------------------------------------------------------
# ragged serving: runtime == per-request decode_lm, token for token
# ---------------------------------------------------------------------------


def test_ragged_greedy_matches_decode_lm(lm):
    cfg, params = lm
    agree = runtime_agreement(cfg, params, _trace(cfg, 9),
                              max_slots=4, max_len=32, seed=0)
    assert agree == 1.0


def test_ragged_greedy_matches_decode_lm_through_analog_pack(lm):
    cfg, params = lm
    from repro.data.synthetic import SyntheticLM

    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    pack = program_lm(cfg, params, A.design_a(error=E.state_independent(0.05)),
                      jax.random.PRNGKey(5))
    pack = calibrate_lm(cfg, params, pack, ds.batch(1)["tokens"])
    # few distinct (prompt_len, n_new) shapes to bound eager reference cost
    reqs = _trace(cfg, 5, lens=(4, 6), new=(4, 6))
    assert runtime_agreement(cfg, params, reqs, pack=pack,
                             max_slots=2, max_len=24) == 1.0


def test_gang_mode_serves_identically(lm):
    """Static (gang) scheduling is a policy change, not a model change."""
    cfg, params = lm
    reqs = _trace(cfg, 6, seed=3)
    outs = {}
    for gang in (False, True):
        rt = ServeRuntime(cfg, params, max_slots=3, max_len=32, gang=gang)
        uids = [rt.submit(p, max_new_tokens=n, uid=i)
                for i, (p, n) in enumerate(reqs)]
        outs[gang] = rt.run()
        assert sorted(outs[gang]) == sorted(uids)
    for uid in outs[False]:
        np.testing.assert_array_equal(outs[False][uid], outs[True][uid])


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


def test_sampled_streams_invariant_to_admission_order(lm):
    """Per-slot keys fold from the request uid, so a request's sampled
    continuation must not depend on queue position or slot assignment."""
    cfg, params = lm
    reqs = _trace(cfg, 6, seed=1)
    sampler = SamplerConfig(kind="temperature", temperature=0.8)
    outs = []
    for order in (lambda x: x, reversed):
        rt = ServeRuntime(cfg, params, max_slots=3, max_len=32,
                          sampler=sampler, seed=11)
        for i, (p, n) in order(list(enumerate(reqs))):
            rt.submit(p, max_new_tokens=n, uid=i)
        outs.append(rt.run())
    for uid in outs[0]:
        np.testing.assert_array_equal(outs[0][uid], outs[1][uid])


def test_sampled_streams_depend_on_seed(lm):
    cfg, params = lm
    reqs = _trace(cfg, 3, seed=2, new=(8, 9))
    runs = []
    for seed in (0, 1):
        rt = ServeRuntime(cfg, params, max_slots=2, max_len=32,
                          sampler=SamplerConfig(kind="top_k", top_k=16),
                          seed=seed)
        for i, (p, n) in enumerate(reqs):
            rt.submit(p, max_new_tokens=n, uid=i)
        runs.append(rt.run())
    assert any(not np.array_equal(runs[0][u], runs[1][u]) for u in runs[0])


def test_greedy_ignores_sampling_seed(lm):
    cfg, params = lm
    reqs = _trace(cfg, 3, seed=4)
    runs = []
    for seed in (0, 123):
        rt = ServeRuntime(cfg, params, max_slots=2, max_len=32, seed=seed)
        for i, (p, n) in enumerate(reqs):
            rt.submit(p, max_new_tokens=n, uid=i)
        runs.append(rt.run())
    for uid in runs[0]:
        np.testing.assert_array_equal(runs[0][uid], runs[1][uid])


def test_eos_stops_early(lm):
    cfg, params = lm
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab
    ref = np.asarray(decode_lm(cfg, params, jnp.asarray(prompt)[None], 6))[0]
    eos = int(ref[2])                   # greedy emits this 3rd
    rt = ServeRuntime(cfg, params, max_slots=2, max_len=16, eos_id=eos)
    uid = rt.submit(prompt, max_new_tokens=6)
    out = rt.run()[uid]
    np.testing.assert_array_equal(out, ref[:3])   # EOS emitted, then stop


# ---------------------------------------------------------------------------
# self-healing: mid-stream reprogramming must not touch in-flight requests
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def noop_aging_manager(lm):
    """PackManager whose aging is *enabled but numerically inert*:
    ``nu = 0`` makes the drift factor exactly 1.0 while ``aging_on``
    stays True, so heal events really reprogram bands — and with
    ``error=none`` programming is deterministic, so every rewrite is
    bit-identical.  The healed runtime must therefore serve exactly
    what the unhealed one serves."""
    from repro.data.synthetic import SyntheticLM
    from repro.serve import PackManager

    cfg, params = lm
    calib = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4,
                        seed=0).batch(1)["tokens"]
    return lambda: PackManager(
        cfg, params, A.design_a(error=E.none(),
                                drift=E.power_law_drift(0.0)),
        jax.random.PRNGKey(5), calib_tokens=calib)


#: forces a heal on every health probe (threshold below any real loss)
FORCE_HEAL = dict(check_every=1, loss_mult=0.0, loss_add=-1.0)


def test_mid_stream_reprogram_preserves_tokens(lm, noop_aging_manager):
    """Requests admitted before, during, and after heal events complete
    with tokens identical to an unhealed same-seed run when drift is a
    no-op: the background reprogram path swaps packs between decode
    steps without perturbing any in-flight slot."""
    from repro.serve import HealPolicy

    cfg, params = lm
    reqs = _trace(cfg, 6, seed=5, lens=(4, 6), new=(4, 8))
    outs = []
    for heal in (None, HealPolicy(**FORCE_HEAL, bands_per_step=1)):
        rt = ServeRuntime(cfg, params, manager=noop_aging_manager(),
                          max_slots=2, max_len=24, heal=heal)
        for i, (p, n) in enumerate(reqs):
            rt.submit(p, max_new_tokens=n, uid=i)
        outs.append(rt.run())
        if heal is not None:
            s = rt.stats
            assert s["heal_events"] >= 1        # healing really happened
            assert s["bands_reprogrammed"] >= 2
            assert s["recalibrations"] >= 1
    for uid in outs[0]:
        np.testing.assert_array_equal(outs[0][uid], outs[1][uid])


def test_eos_during_reprogram_race(lm, noop_aging_manager):
    """A request whose EOS fires while the heal queue is mid-drain
    (``bands_per_step=1`` spreads one heal event over several scheduler
    steps) must retire exactly at the EOS token, and a request submitted
    during the drain must serve correctly afterwards."""
    from repro.serve import HealPolicy, decode_lm

    cfg, params = lm
    m = noop_aging_manager()
    prompt = np.arange(5, dtype=np.int32) % cfg.vocab
    ref = np.asarray(decode_lm(cfg, params, jnp.asarray(prompt)[None], 8,
                               pack=m.fresh_pack))[0]
    # stop on a token greedy emits at position >= 3 and nowhere earlier:
    # the first probe fires after decode step 1 and drains one band per
    # step after, so retirement at decode step j >= 3 lands mid-drain
    j = next(i for i in range(3, 8) if ref[i] not in ref[:i])
    eos = int(ref[j])
    rt = ServeRuntime(cfg, params, manager=m, max_slots=2, max_len=16,
                      eos_id=eos, heal=HealPolicy(**FORCE_HEAL,
                                                  bands_per_step=1))
    uid = rt.submit(prompt, max_new_tokens=8)
    # step until the EOS request retires; every step is also draining /
    # re-queueing heal targets, so the retirement races a reprogram
    done = {}
    for _ in range(64):
        for c in rt.step():
            done[c.uid] = c.tokens
        if uid in done:
            break
    np.testing.assert_array_equal(done[uid], ref[:j + 1])
    assert rt.stats["bands_reprogrammed"] >= 1   # reprogram raced the EOS
    # a late request admitted into the still-healing server serves fine
    uid2 = rt.submit(prompt, max_new_tokens=2)
    out2 = rt.run()
    np.testing.assert_array_equal(out2[uid2], ref[:2])
    assert not rt._heal_queue            # run() drains leftover healing


# ---------------------------------------------------------------------------
# slot cache insert / evict
# ---------------------------------------------------------------------------


def test_cache_slot_insert_and_evict(lm):
    cfg, params = lm
    max_slots, max_len = 3, 24
    cache0 = transformer.init_cache(cfg, max_slots, max_len)
    slot = {"layers": cache0["layers"],
            "len": jnp.zeros((max_slots,), jnp.int32)}
    prompts = jnp.arange(2 * 8, dtype=jnp.int32).reshape(2, 8) % cfg.vocab
    lens = jnp.asarray([8, 5], jnp.int32)
    _, pcache = transformer.prefill_ragged(cfg, params, prompts,
                                           true_lens=lens)
    # row 0 -> slot 2, row 1 -> dummy (dropped)
    ins = transformer.cache_slot_insert(slot, pcache,
                                        jnp.asarray([2, max_slots]))
    assert ins["len"].tolist() == [0, 0, 8]
    k_ins = np.asarray(ins["layers"]["attn"]["k"])
    k_new = np.asarray(pcache["layers"]["attn"]["k"])
    np.testing.assert_array_equal(k_ins[:, 2, :8], k_new[:, 0])
    assert not k_ins[:, :2].any()                 # other slots untouched
    ev = transformer.cache_slot_evict(ins, jnp.asarray([2]))
    assert ev["len"].tolist() == [0, 0, 0]
    assert not np.asarray(ev["layers"]["attn"]["k"]).any()


def test_prefill_ragged_matches_exact_prefill(lm):
    cfg, params = lm
    tokens = (jnp.arange(6, dtype=jnp.int32) % cfg.vocab)[None, :]
    ref, _ = transformer.prefill(cfg, params, tokens, 8)
    padded = jnp.pad(tokens, ((0, 0), (0, 4)))
    got, cache = transformer.prefill_ragged(cfg, params, padded,
                                            true_lens=jnp.asarray([6]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    assert cache["len"].tolist() == [6]


# ---------------------------------------------------------------------------
# user-facing errors
# ---------------------------------------------------------------------------


def test_decode_lm_family_error():
    cfg = get_smoke_config("whisper-large-v3")
    with pytest.raises(ValueError, match="audio.*no batched decode"):
        decode_lm(cfg, {}, jnp.zeros((1, 4), jnp.int32), 2)


def test_runtime_rejects_rwkv():
    cfg = get_smoke_config("rwkv6-3b")
    with pytest.raises(ValueError, match="rwkv"):
        ServeRuntime(cfg, {}, max_slots=2, max_len=16)


def test_runtime_rejects_moe():
    """Capacity routing couples co-batched rows — the scheduling-
    never-changes-outputs contract cannot hold for MoE configs."""
    cfg = get_smoke_config("arctic-480b")
    with pytest.raises(ValueError, match="MoE"):
        ServeRuntime(cfg, {}, max_slots=2, max_len=16)


def test_greedy_decode_rejects_bad_n_new(lm):
    cfg, params = lm
    with pytest.raises(ValueError, match="n_new >= 1"):
        decode_lm(cfg, params, jnp.zeros((1, 4), jnp.int32), 0)


def test_submit_validation(lm):
    cfg, params = lm
    rt = ServeRuntime(cfg, params, max_slots=2, max_len=16, buckets=(8,))
    with pytest.raises(ValueError, match="largest bucket"):
        rt.submit(np.zeros(9, np.int32), max_new_tokens=2)
    with pytest.raises(ValueError, match="max_new_tokens"):
        rt.submit(np.zeros(4, np.int32), max_new_tokens=0)
    with pytest.raises(ValueError, match="KV capacity"):
        rt.submit(np.zeros(8, np.int32), max_new_tokens=12)
    with pytest.raises(ValueError, match="empty prompt"):
        rt.submit(np.zeros(0, np.int32), max_new_tokens=2)
    rt.submit(np.zeros(4, np.int32), max_new_tokens=2, uid=7)
    with pytest.raises(ValueError, match="already in flight"):
        rt.submit(np.zeros(4, np.int32), max_new_tokens=2, uid="7")


def test_sampler_config_validation():
    with pytest.raises(ValueError, match="unknown sampler"):
        SamplerConfig(kind="nucleus")
    with pytest.raises(ValueError, match="temperature"):
        SamplerConfig(kind="temperature", temperature=0.0)
    with pytest.raises(ValueError, match="top_k"):
        SamplerConfig(kind="top_k", top_k=0)
