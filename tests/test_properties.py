"""Hypothesis property tests on the system's invariants.

``hypothesis`` is a dev-only dependency (requirements-dev.txt); skip the
module instead of aborting collection when it is absent."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import adc as adc_lib
from repro.core.mapping import (
    MappingConfig,
    codes_to_conductance,
    conductance_to_codes,
    program_weights,
    reconstruct_weights,
    slice_codes,
    unslice_codes,
)
from repro.core.quant import bit_planes, quantize_weights

SETTINGS = dict(max_examples=30, deadline=None)


@given(
    codes=st.lists(st.integers(0, 255), min_size=1, max_size=32),
    bpc=st.sampled_from([1, 2, 4]),
)
@settings(**SETTINGS)
def test_slice_unslice_roundtrip(codes, bpc):
    c = jnp.asarray(codes, jnp.int32)
    n_slices = -(-8 // bpc)
    s = slice_codes(c, bpc, n_slices)
    assert bool(jnp.all(s >= 0)) and bool(jnp.all(s < 2 ** bpc))
    np.testing.assert_array_equal(np.asarray(unslice_codes(s, bpc)), codes)


@given(
    vals=st.lists(st.integers(-127, 127), min_size=2, max_size=64),
    scheme=st.sampled_from(["offset", "differential"]),
    bpc=st.sampled_from([None, 1, 2, 4]),
    onoff=st.sampled_from([float("inf"), 100.0, 10.0]),
)
@settings(**SETTINGS)
def test_program_reconstruct_roundtrip(vals, scheme, bpc, onoff):
    w = jnp.asarray(vals, jnp.int32).reshape(-1, 1)
    mc = MappingConfig(scheme=scheme, bits_per_cell=bpc, on_off_ratio=onoff)
    pw = program_weights(w, mc)
    back = reconstruct_weights(pw, mc)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w), atol=1e-3)
    # conductances physical: in [g_min - eps, 1]
    for g in (pw.g_pos, pw.g_neg):
        if g is not None:
            assert bool(jnp.all(g >= mc.g_min - 1e-6))
            assert bool(jnp.all(g <= 1.0 + 1e-6))


@given(
    x=st.lists(st.integers(-127, 127), min_size=1, max_size=32),
)
@settings(**SETTINGS)
def test_bit_planes_reconstruct(x):
    xi = jnp.asarray(x, jnp.float32)
    planes = bit_planes(xi, 7, signed=True)
    recon = sum(2.0 ** b * planes[b] for b in range(7))
    np.testing.assert_array_equal(np.asarray(recon), np.asarray(xi))
    assert bool(jnp.all(jnp.abs(planes) <= 1))


@given(
    data=st.lists(st.floats(-100, 100, allow_nan=False), min_size=8,
                  max_size=64),
    bits=st.sampled_from([4, 6, 8]),
    lo=st.floats(-50, -1),
    hi=st.floats(1, 50),
)
@settings(**SETTINGS)
def test_adc_monotone_and_bounded(data, bits, lo, hi):
    v = jnp.asarray(sorted(data), jnp.float32)
    q = adc_lib.adc_quantize(v, lo, hi, bits)
    dq = np.diff(np.asarray(q))
    assert (dq >= -1e-5).all(), "quantizer must be monotone"
    assert float(jnp.min(q)) >= lo - 1e-5
    assert float(jnp.max(q)) <= hi + 1e-5
    lsb = (hi - lo) / (2 ** bits - 1)
    inside = (v >= lo) & (v <= hi)
    err = jnp.abs(q - v) * inside
    assert float(jnp.max(err)) <= lsb / 2 + 1e-5


@given(
    needs=st.lists(st.floats(0.01, 100.0), min_size=2, max_size=8),
)
@settings(**SETTINGS)
def test_power_of_two_ranges(needs):
    n = jnp.asarray(needs, jnp.float32)
    granted = adc_lib.power_of_two_ranges(n)
    assert bool(jnp.all(granted >= n - 1e-5)), "granted must cover need"
    ratios = granted / jnp.min(granted)
    logr = np.log2(np.asarray(ratios))
    assert np.allclose(logr, np.round(logr), atol=1e-4)


@given(
    w=st.lists(st.floats(-1, 1, allow_nan=False, width=32), min_size=4,
               max_size=64),
    bits=st.sampled_from([4, 8]),
)
@settings(**SETTINGS)
def test_weight_quant_error_bound(w, bits):
    arr = jnp.asarray(w, jnp.float32).reshape(-1, 1)
    q = quantize_weights(arr, bits)
    err = jnp.max(jnp.abs(q.dequant() - arr))
    bound = jnp.max(jnp.abs(arr)) / (2 ** (bits - 1) - 1) / 2 + 1e-7
    assert float(err) <= float(bound) * 1.01


@given(
    k=st.integers(2, 24),
    r=st.floats(1e-6, 1e-2),
    seed=st.integers(0, 100),
)
@settings(**SETTINGS)
def test_parasitic_solver_vs_dense(k, r, seed):
    from repro.core.parasitics import (
        bitline_currents, bitline_voltages_dense, injected_current)

    kg, kx = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.uniform(kg, (k, 3))
    x = jnp.sign(jax.random.normal(kx, (2, k)))
    out = bitline_currents(g, x, r)
    for m in range(2):
        for n in range(3):
            v = bitline_voltages_dense(g[:, n], x[m], r)
            np.testing.assert_allclose(out[m, n], v[-1] / r, rtol=1e-3,
                                       atol=1e-5)
            # Kirchhoff: bottom-segment current == injected current
            np.testing.assert_allclose(
                v[-1] / r, injected_current(g[:, n], x[m], v),
                rtol=1e-3, atol=1e-5)


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_parasitics_only_reduce_current_magnitude(seed):
    """Voltage sag can only pull outputs toward zero (Sec. 8: 'downward')."""
    from repro.core.parasitics import bitline_currents

    kg, kx = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.uniform(kg, (16, 4))
    x = (jax.random.uniform(kx, (3, 16)) > 0.5).astype(jnp.float32)  # unipolar
    ideal = x @ g
    sag = bitline_currents(g, x, 1e-3)
    assert bool(jnp.all(sag <= ideal + 1e-6))
    assert bool(jnp.all(sag >= 0))


# ---------------------------------------------------------------------------
# whole-spec strategy: arbitrary valid AnalogSpecs
# ---------------------------------------------------------------------------

from repro.core import analog as A
from repro.core.adc import ADCConfig
from repro.core.analog import AnalogSpec
from repro.core.errors import ErrorModel, state_independent, state_proportional


@st.composite
def analog_specs(draw):
    """Generate valid :class:`AnalogSpec` design points.

    Covers both mapping schemes, sliced and unsliced precision, finite and
    infinite On/Off ratios, the offset unit column, every ADC style, and
    both input-accumulation modes — the constraints mirror the dataclass
    ``__post_init__`` validators (unit_column requires offset, etc.).
    """
    scheme = draw(st.sampled_from(["differential", "offset"]))
    bpc = draw(st.sampled_from([None, 1, 2, 4]))
    onoff = draw(st.sampled_from([float("inf"), 1e4, 100.0, 10.0]))
    unit_column = scheme == "offset" and draw(st.booleans())
    mapping = MappingConfig(scheme=scheme, weight_bits=8, bits_per_cell=bpc,
                            on_off_ratio=onoff, unit_column=unit_column)
    style = draw(st.sampled_from(["none", "fpg", "calibrated"]))
    adc = ADCConfig(style=style, bits=draw(st.sampled_from([6, 8])))
    error = draw(st.sampled_from([
        ErrorModel(), state_independent(0.02), state_proportional(0.05)]))
    return AnalogSpec(
        mapping=mapping,
        adc=adc,
        error=error,
        input_bits=draw(st.sampled_from([4, 8])),
        input_accum=draw(st.sampled_from(["analog", "digital"])),
        max_rows=draw(st.sampled_from([16, 40, 1152])),
    )


_PW = jax.random.normal(jax.random.PRNGKey(10), (48, 6)) * 0.05
_PX = jax.random.normal(jax.random.PRNGKey(11), (5, 48))


@given(spec=analog_specs())
@settings(max_examples=25, deadline=None)
def test_any_valid_spec_error_free_exactness(spec):
    """The core invariant over the whole design space: with errors and the
    ADC disabled, every valid spec reproduces the integer matmul."""
    import dataclasses as dc

    from repro.core.quant import quantize_acts as qa, quantize_weights as qw

    spec = dc.replace(spec, error=ErrorModel(), adc=ADCConfig(style="none"))
    aw = A.program(_PW, spec)
    y = A.analog_matmul(_PX, aw, spec)
    m = spec.mapping
    mag = None if m.scheme == "offset" else m.magnitude_bits
    w_q = qw(_PW, m.weight_bits, magnitude_bits=mag)
    x_q = qa(_PX, spec.input_bits, signed=True)
    ref = (x_q.values @ w_q.values) * w_q.scale * x_q.scale
    rel = float(jnp.max(jnp.abs(y - ref)) / jnp.max(jnp.abs(ref)))
    assert rel < 1e-5, (spec, rel)


@given(spec=analog_specs())
@settings(max_examples=25, deadline=None)
def test_any_valid_spec_full_pipeline_well_formed(spec):
    """Program (with errors) → calibrate → matmul stays finite and shaped
    for every valid spec, calibrated ranges ordered lo < hi."""
    from repro.core.calibrate import calibrate_adc_for_matmul

    aw = A.program(_PW, spec, jax.random.PRNGKey(3))
    kw = {}
    if spec.adc.style == "calibrated":
        lo, hi = calibrate_adc_for_matmul(_PX, aw, spec)
        assert bool(jnp.all(hi > lo))
        kw = dict(adc_lo=lo, adc_hi=hi)
    y = A.analog_matmul(_PX, aw, spec, **kw)
    assert y.shape == (_PX.shape[0], _PW.shape[1])
    assert bool(jnp.all(jnp.isfinite(y)))


# ---------------------------------------------------------------------------
# drift + fault models (DESIGN.md §Drift-and-healing)
# ---------------------------------------------------------------------------

from repro.core.errors import DriftModel, FaultModel


@st.composite
def drift_models(draw):
    """Valid power-law drift models: nu ∈ [0, 0.5], lognormal per-cell
    spread sigma_nu ∈ [0, 1]."""
    return DriftModel(kind="power_law",
                      nu=draw(st.floats(0.0, 0.5)),
                      sigma_nu=draw(st.floats(0.0, 1.0)))


@st.composite
def fault_models(draw):
    """Valid stuck-cell models: arrival rate ∈ [0, 0.1] per t0 of age,
    any G_max/G_min polarity split."""
    return FaultModel(kind="stuck",
                      rate=draw(st.floats(0.0, 0.1)),
                      p_hi=draw(st.floats(0.0, 1.0)))


_G = jax.random.uniform(jax.random.PRNGKey(12), (32, 8),
                        minval=1e-4, maxval=1.0)


@given(drift=drift_models(), fault=fault_models(), seed=st.integers(0, 100))
@settings(**SETTINGS)
def test_aging_at_t0_is_bitwise_identity(drift, fault, seed):
    """t = 1 is the fresh-age anchor for *every* valid model: decay
    factor exactly ``1.0 ** -nu_cell == 1.0`` and stuck probability
    exactly 0 — aging enabled must be a bit-identical no-op."""
    key = jax.random.PRNGKey(seed)
    np.testing.assert_array_equal(np.asarray(drift.apply(_G, 1.0, key)),
                                  np.asarray(_G))
    np.testing.assert_array_equal(np.asarray(fault.apply(_G, 1.0, key)),
                                  np.asarray(_G))


@given(drift=drift_models(), seed=st.integers(0, 100),
       t1=st.floats(1.0, 100.0), t2=st.floats(1.0, 100.0))
@settings(**SETTINGS)
def test_drift_monotone_decay(drift, seed, t1, t2):
    """Retention decay only shrinks conductance, elementwise monotone in
    age: 0 < g(t2) <= g(t1) <= g0 for t2 >= t1 (per cell — the exponents
    are a fixed device property of the key)."""
    key = jax.random.PRNGKey(seed)
    lo, hi = sorted((t1, t2))
    g1, g2 = np.asarray(drift.apply(_G, lo, key)), np.asarray(
        drift.apply(_G, hi, key))
    assert (g1 <= np.asarray(_G) + 1e-7).all()
    assert (g2 <= g1 + 1e-7).all()
    assert (g2 > 0).all()


@given(fault=fault_models(), seed=st.integers(0, 100),
       t1=st.floats(1.0, 100.0), t2=st.floats(1.0, 100.0))
@settings(**SETTINGS)
def test_fault_masks_replayable_nested_idempotent(fault, seed, t1, t2):
    """Fault masks under one key: re-aging replays bit-identically
    (idempotent), and arrivals are monotone — the stuck set at t1 is a
    subset of the stuck set at t2 >= t1, with per-cell values fixed
    (a cell's G_min/G_max polarity never flips)."""
    key = jax.random.PRNGKey(seed)
    lo, hi = sorted((t1, t2))
    a1 = np.asarray(fault.apply(_G, lo, key))
    np.testing.assert_array_equal(a1, np.asarray(fault.apply(_G, lo, key)))
    a2 = np.asarray(fault.apply(_G, hi, key))
    stuck1 = a1 != np.asarray(_G)
    stuck2 = a2 != np.asarray(_G)
    assert (stuck2 | ~stuck1).all(), "stuck sets must be nested in t"
    np.testing.assert_array_equal(a2[stuck1], a1[stuck1])
    # re-applying the mask to already-faulted conductances changes
    # nothing: stuck cells are pinned at exactly g_lo/g_hi
    np.testing.assert_array_equal(np.asarray(fault.apply(
        jnp.asarray(a1), lo, key)), a1)


def test_energy_model_monotonicity():
    from repro.core import energy as en
    from repro.core.adc import ADCConfig
    from repro.core.analog import AnalogSpec
    from repro.core.mapping import MappingConfig

    base = AnalogSpec(mapping=MappingConfig(scheme="differential"),
                      adc=ADCConfig(bits=8), input_accum="analog",
                      max_rows=1152)
    e_base = en.core_energy(base, g_avg=0.02)
    # more slices cost more
    sliced = AnalogSpec(mapping=MappingConfig(scheme="differential",
                                              bits_per_cell=1),
                        adc=ADCConfig(bits=8), input_accum="analog",
                        max_rows=1152)
    assert en.core_energy(sliced, g_avg=0.02) > e_base
    # smaller arrays cost more (less ADC amortization)
    small = AnalogSpec(mapping=MappingConfig(scheme="differential"),
                       adc=ADCConfig(bits=8), input_accum="analog",
                       max_rows=144)
    assert en.core_energy(small, g_avg=0.02) > e_base
    # digital input accumulation costs more
    dig = AnalogSpec(mapping=MappingConfig(scheme="differential"),
                     adc=ADCConfig(bits=8), input_accum="digital",
                     max_rows=1152)
    assert en.core_energy(dig, g_avg=0.02) > e_base
    # higher conductance costs more
    assert en.core_energy(base, g_avg=0.5) > e_base


# ---------------------------------------------------------------------------
# paged-KV bookkeeping: allocator + radix prefix cache (repro.serve.kvpool)
# ---------------------------------------------------------------------------


@st.composite
def _alloc_ops(draw):
    """A random alloc/retain/release program over a small pool."""
    n_ops = draw(st.integers(1, 40))
    return [
        (draw(st.sampled_from(["alloc", "retain", "release"])),
         draw(st.integers(0, 4)))
        for _ in range(n_ops)
    ]


@given(num_pages=st.integers(2, 24), ops=_alloc_ops(),
       seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_page_allocator_invariants(num_pages, ops, seed):
    """Conservation, refcount correctness, no sink circulation, no page
    handed out twice concurrently — against a shadow-model allocator."""
    from repro.serve.kvpool import PageAllocator, PagePoolExhausted

    rng = np.random.default_rng(seed)
    a = PageAllocator(num_pages)
    model = {}                              # page -> refcount
    for op, n in ops:
        live = sorted(model)
        if op == "alloc":
            try:
                got = a.alloc(n)
            except PagePoolExhausted:
                assert n > (num_pages - 1) - len(model)
            else:
                assert len(got) == n == len(set(got))
                assert not set(got) & set(model), "page aliased while live"
                assert 0 not in got
                for p in got:
                    model[p] = 1
        elif op == "retain" and live:
            pick = [live[int(i)] for i in
                    rng.integers(0, len(live), size=min(n, len(live)))]
            a.retain(pick)
            for p in pick:
                model[p] += 1
        elif op == "release" and live:
            pick = [live[int(i)] for i in
                    rng.integers(0, len(live), size=min(n, len(live)))]
            # releasing the same page twice in one call is legal only
            # while its refcount covers it; build a safe multiset
            safe, budget = [], dict(model)
            for p in pick:
                if budget[p] > 0:
                    safe.append(p)
                    budget[p] -= 1
            a.release(safe)
            for p in safe:
                model[p] -= 1
                if not model[p]:
                    del model[p]
        a.check()
        assert a.used_pages == len(model)
        assert a.free_pages == (num_pages - 1) - len(model)
        for p, r in model.items():
            assert a.refcount(p) == r
    # double free / foreign free always raises
    dead = next((p for p in range(1, num_pages) if p not in model), None)
    if dead is not None:
        with pytest.raises(ValueError):
            a.release([dead])


@st.composite
def _prompts(draw):
    """Small-alphabet prompts so prefixes actually collide."""
    n = draw(st.integers(1, 8))
    return [draw(st.lists(st.integers(0, 3), min_size=1, max_size=12))
            for _ in range(n)]


@given(prompts=_prompts(), page_size=st.integers(1, 4),
       queries=_prompts())
@settings(**SETTINGS)
def test_radix_match_equals_brute_force(prompts, page_size, queries):
    """``RadixCache.match`` == the longest common whole-page-chunk
    prefix over everything inserted, computed by brute force — and the
    first inserter of a chunk owns its page forever (the bit-identical
    content invariant)."""
    from repro.serve.kvpool import PageAllocator, RadixCache, full_pages

    a = PageAllocator(512)
    r = RadixCache(a, page_size)
    model = {}                              # chunk-path tuple -> page
    for toks in prompts:
        nfull = full_pages(len(toks), page_size)
        pages = a.alloc(nfull)
        r.insert(toks, pages)
        for i in range(nfull):
            path = tuple(toks[:(i + 1) * page_size])
            model.setdefault(path, pages[i])
        r.check()
        a.check()
    for q in prompts + queries:
        expect = []
        for i in range(len(q) // page_size):
            page = model.get(tuple(q[:(i + 1) * page_size]))
            if page is None:
                break
            expect.append(page)
        assert r.match(q) == expect
    # cached pages each hold exactly the cache's reference (+1 from the
    # allocating caller, which never released here)
    assert r.pages_cached == len(model)


@given(prompts=_prompts(), page_size=st.integers(1, 3),
       pool=st.integers(4, 16), seed=st.integers(0, 2 ** 16))
@settings(**SETTINGS)
def test_radix_evict_frees_without_breaking_holders(prompts, page_size,
                                                    pool, seed):
    """Eviction releases only the cache's own references: pages still
    held by a 'slot' survive eviction, and the allocator never loses or
    duplicates a page through any insert/evict/release interleaving."""
    from repro.serve.kvpool import (PageAllocator, PagePoolExhausted,
                                    RadixCache, full_pages)

    rng = np.random.default_rng(seed)
    a = PageAllocator(pool)
    r = RadixCache(a, page_size)
    held = []                                # our simulated slot's pages
    for toks in prompts:
        nfull = full_pages(len(toks), page_size)
        shared = r.match(toks)[:nfull]
        if shared:
            a.retain(shared)
        want = nfull - len(shared)
        if want > a.free_pages:
            r.evict(want)
        try:
            fresh = a.alloc(want)
        except PagePoolExhausted:
            if shared:
                a.release(shared)
            continue
        pages = shared + fresh
        r.insert(toks, pages)
        if rng.integers(2):
            held.extend(pages)               # slot keeps its references
        else:
            a.release(pages)                 # slot retires immediately
        r.check()
        a.check()
    for p in held:                           # survivors are still live
        assert a.refcount(p) >= 1
    r.evict(pool)                            # unsatisfiable -> full drain
    assert r.pages_cached == 0
    r.check()
    a.check()
    a.release(held)                          # one reference per held entry
    assert a.used_pages == 0 and a.free_pages == pool - 1
