"""Tier gating: ``tier2``-marked tests (expensive end-to-end differential
suites) are skipped unless ``RUN_TIER2`` is set — the nightly / manual
CI job runs them (see ``.github/workflows/ci.yml``)."""

import os

import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "tier2: expensive end-to-end differential tests "
        "(nightly CI; set RUN_TIER2=1 to run locally)",
    )


def pytest_collection_modifyitems(config, items):
    if os.environ.get("RUN_TIER2"):
        return
    skip = pytest.mark.skip(
        reason="tier-2: set RUN_TIER2=1 (runs in the nightly CI job)")
    for item in items:
        if "tier2" in item.keywords:
            item.add_marker(skip)
