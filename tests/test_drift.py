"""Drift + fault + healing determinism pins (tier-1, no training).

The aging contract (DESIGN.md §Drift-and-healing):

* a ``PackManager``'s fresh pack is bit-identical to ``program_lm`` +
  ``calibrate_lm`` under the same key — owning device state costs
  nothing when aging is off;
* ``aged(t=1)`` / ``AnalogPack.age(1, key)`` are bitwise no-ops even
  with drift and fault models *enabled* (the fresh-age anchor that keeps
  every pre-drift golden valid);
* aging replays: same key + same age = bit-identical conductances;
* reprogramming band ``b`` at epoch 0 reproduces the fresh program of
  exactly that band (the splice is surgical), and a reprogram at age
  ``t`` resets that band's drift clock (relative age 1 ⇒ no decay);
* stuck cells are *permanent*: fault masks key off the age key, not the
  reprogram epoch, so a reprogrammed band carries the same broken cells;
* the served answer is unchanged by the whole machinery: runtime-vs-
  ``decode_lm`` greedy agreement is exactly 1.0 on an aged-then-healed
  pack (the ISSUE acceptance bar).
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import analog as A
from repro.core import errors as E
from repro.data.synthetic import SyntheticLM
from repro.models.registry import get_model
from repro.serve import PackManager, calibrate_lm, program_lm
from repro.sweep.serve_eval import runtime_agreement

KEY = jax.random.PRNGKey(5)

#: drift + faults enabled — every site of the pack ages
AGING_SPEC = A.design_a(
    error=E.state_independent(0.05),
    drift=E.power_law_drift(0.2, sigma_nu=0.3),
    fault=E.stuck_faults(1e-3),
)


@pytest.fixture(scope="module")
def lm():
    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    calib = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4,
                        seed=0).batch(1)["tokens"]
    return cfg, params, calib


@pytest.fixture(scope="module")
def manager(lm):
    cfg, params, calib = lm
    return PackManager(cfg, params, AGING_SPEC, KEY, calib_tokens=calib)


def _leaves_equal(a, b) -> bool:
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    return all((np.asarray(x) == np.asarray(y)).all() for x, y in zip(la, lb))


def test_manager_fresh_pack_matches_program_calibrate(lm, manager):
    """Owning device state is free: the manager's as-built pack is
    bit-identical to the plain program + calibrate path."""
    cfg, params, calib = lm
    ref = calibrate_lm(cfg, params,
                       program_lm(cfg, params, AGING_SPEC, KEY), calib)
    assert _leaves_equal(manager.fresh_pack, ref)


def test_aged_at_t0_is_bitwise_noop(manager):
    """t = 1 is the fresh-age anchor: decay factor exactly 1.0, stuck
    probability exactly 0 — enabled models change nothing at t0."""
    assert _leaves_equal(manager.aged(1.0), manager.fresh_pack)


def test_aging_replays_and_responds_to_key(manager):
    a1, a2 = manager.aged(64.0), manager.aged(64.0)
    assert _leaves_equal(a1, a2)
    assert not _leaves_equal(a1, manager.fresh_pack)


def test_pack_age_method_deterministic(manager):
    """``AnalogPack.age``: replayable per key, no-op at t=1, and keyed —
    a different key draws different per-cell exponents."""
    pack = manager.fresh_pack
    k1, k2 = jax.random.PRNGKey(1), jax.random.PRNGKey(2)
    assert _leaves_equal(pack.age(64.0, k1), pack.age(64.0, k1))
    assert _leaves_equal(pack.age(1.0, k1), pack)
    assert not _leaves_equal(pack.age(64.0, k1), pack.age(64.0, k2))


def test_band_reprogram_bit_identity_vs_fresh_program(manager):
    """Reprogramming a band under the epoch-0 key reproduces the fresh
    program of exactly those rows — the splice path and the full
    ``program_lm_from_codes`` path share one key schedule."""
    fresh = manager.fresh_pack
    for b in range(len(fresh.bands)):
        lo, hi = fresh.bands[b]
        weights = manager.program_band(b, manager.epoch_key(0))
        for name, aw in weights.items():
            ref = jax.tree.map(lambda a: a[lo:hi], fresh.layer_weights[name])
            assert _leaves_equal(aw, ref), (b, name)


def test_reprogram_resets_drift_clock(lm):
    """With deterministic programming (error none, faults off), a band
    reprogrammed at age t serves *bit-identical to fresh* at age t:
    relative drift age is exactly 1 again."""
    cfg, params, calib = lm
    spec = A.design_a(error=E.none(),
                      drift=E.power_law_drift(0.2, sigma_nu=0.3))
    m = PackManager(cfg, params, spec, KEY, calib_tokens=calib)
    t = 64.0
    assert not _leaves_equal(m.aged(t), m.fresh_pack)   # drift bites...
    for target in m.heal_targets():
        if target == "head":
            m.reprogram_head(t_now=t)
        else:
            m.reprogram_band(target, t_now=t)
    assert _leaves_equal(m.aged(t), m.fresh_pack)       # ...and heals
    assert not _leaves_equal(m.aged(4 * t), m.fresh_pack)  # then re-drifts


def test_faults_survive_reprogramming(lm):
    """Stuck cells key off the age key, not the reprogram epoch: the
    same cells are broken, with the same polarity, after a rewrite."""
    cfg, params, calib = lm
    spec = A.design_a(error=E.none(), fault=E.stuck_faults(1e-2))
    m = PackManager(cfg, params, spec, KEY, calib_tokens=calib)
    t = 64.0
    before = m.aged(t)
    assert not _leaves_equal(before, m.fresh_pack)      # faults present
    for target in m.heal_targets():
        if target == "head":
            m.reprogram_head(t_now=t)
        else:
            m.reprogram_band(target, t_now=t)
    assert _leaves_equal(m.aged(t), before)


def test_manager_rejects_pre_aged_specs(lm):
    cfg, params, calib = lm
    spec = dataclasses.replace(
        AGING_SPEC, drift=dataclasses.replace(AGING_SPEC.drift, t=64.0))
    with pytest.raises(ValueError, match="fresh age"):
        PackManager(cfg, params, spec, KEY, calib_tokens=calib)


def test_runtime_agreement_on_aged_then_healed_pack(lm):
    """Acceptance bar: the continuous-batching runtime and per-request
    ``decode_lm`` agree token-for-token (exactly 1.0) on a pack that
    aged, was band-by-band reprogrammed mid-life, aged again, and was
    recalibrated — scheduling never changes what the model says, even
    through spliced band stacks."""
    cfg, params, calib = lm
    m = PackManager(cfg, params, AGING_SPEC, KEY, calib_tokens=calib)
    for target in m.heal_targets():
        if target == "head":
            m.reprogram_head(t_now=16.0)
        else:
            m.reprogram_band(target, t_now=16.0)
    healed = m.recalibrate(m.aged(64.0))
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab, size=int(rng.integers(4, 6)))
             .astype(np.int32), int(rng.integers(4, 6))) for _ in range(5)]
    assert runtime_agreement(cfg, params, reqs, pack=healed,
                             max_slots=2, max_len=24) == 1.0


def test_drift_grid_is_one_compile_group():
    """The drift nu x t grid (Fig. 21 horizons) batches through one
    compiled program — both the exponent and the horizon trace.  The
    pin previously lived only in ``dynamic_fields_for``'s docstring;
    declared here as a CompileContract (repro.analysis)."""
    import jax.numpy as jnp

    from repro.analysis import CompileContract, check_contract
    from repro.core.adc import ADCConfig
    from repro.sweep import Axis, ClassifierEvaluator, SweepSpec

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    layers = [(jax.random.normal(ks[0], (16, 8)) * 0.25,
               jnp.zeros((8,)))]
    ev = ClassifierEvaluator(
        layers, jax.random.normal(ks[1], (32, 16)),
        jax.random.normal(ks[2], (64, 16)),
        jax.random.randint(ks[3], (64,), 0, 8))
    c = CompileContract(
        name="test/drift-grid",
        sweep=SweepSpec(
            name="t",
            base=A.AnalogSpec(adc=ADCConfig(style="none"), max_rows=64,
                              drift=E.power_law_drift(0.2)),
            axes=(Axis("drift.nu", (0.1, 0.2)),
                  Axis("drift.t", (1.0, 16.0, 256.0))),
            trials=1,
        ),
        evaluator=lambda: ev,
        max_groups=1,
        expect_dynamic=(("drift.nu", "drift.t"),),
        require_dynamic=("drift.nu", "drift.t"),
    )
    assert check_contract(c, "static") == []
