"""Execute the documentation's code snippets so the docs cannot rot.

Extracts every fenced ```python block from the given markdown files and
runs it against the *smoke config*: a namespace pre-seeded with a tiny
trained-shape LM and the objects the docs talk about (``cfg``,
``params``, ``calib_tokens`` / ``eval_tokens`` / ``eval_targets``,
``prompts``, ``prompt``, ``spec0``, a programmed + calibrated ``pack``).
Blocks in one file share the namespace, so later snippets may build on
earlier ones.  A block fenced as ```python notest`` is skipped (use for
illustrative fragments that reference unavailable state).

Usage::

    PYTHONPATH=src python tools/check_docs.py README.md docs/PAPER_MAP.md

Every block is attempted; each failure prints the file, block index,
source line, and traceback, and the process exits nonzero if any block
failed — the `docs-check` CI job runs exactly this.
"""

from __future__ import annotations

import re
import sys
import traceback

FENCE = re.compile(
    r"^```python[ \t]*(?P<info>[^\n]*)\n(?P<body>.*?)^```[ \t]*$",
    re.MULTILINE | re.DOTALL,
)


def smoke_env() -> dict:
    """The execution namespace: smoke LM + the objects the docs name."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.core import analog as A
    from repro.core import errors as E
    from repro.data.synthetic import SyntheticLM
    from repro.models.registry import get_model
    from repro.serve import calibrate_lm, program_lm

    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(cfg, jax.random.PRNGKey(0))
    ds = SyntheticLM(cfg=cfg, seq_len=16, global_batch=4, seed=0)
    calib = ds.batch(998)
    batch = ds.batch(999)
    spec0 = A.design_a(error=E.state_proportional(0.05))
    pack = program_lm(cfg, params, spec0, jax.random.PRNGKey(7))
    pack = calibrate_lm(cfg, params, pack, calib["tokens"])
    return {
        "jax": jax, "jnp": jnp, "np": np,
        "cfg": cfg, "params": params, "ds": ds,
        "calib_tokens": calib["tokens"],
        "eval_tokens": batch["tokens"],
        "eval_targets": batch["targets"],
        "prompts": batch["tokens"][:2, :8],
        "prompt": np.asarray(batch["tokens"][0, :8]),
        "spec0": spec0, "pack": pack,
    }


def blocks(path: str):
    with open(path) as f:
        text = f.read()
    for i, m in enumerate(FENCE.finditer(text)):
        line = text[: m.start()].count("\n") + 1
        yield i, line, m.group("info").strip(), m.group("body")


def main(paths) -> int:
    if not paths:
        print("usage: check_docs.py DOC.md [DOC.md ...]", file=sys.stderr)
        return 2
    failures = 0
    for path in paths:
        env = smoke_env()               # fresh per file, shared per block
        n_run = n_skip = 0
        for i, line, info, body in blocks(path):
            if "notest" in info.split():
                n_skip += 1
                continue
            try:
                exec(compile(body, f"{path}:block{i}(line {line})", "exec"),
                     env)
                n_run += 1
            except Exception:
                print(f"FAIL {path} block {i} (line {line}):\n{body}",
                      file=sys.stderr)
                traceback.print_exc()
                failures += 1
        print(f"{path}: {n_run} block(s) executed, {n_skip} skipped")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
