"""The analyzer CLI: hazard lint + compile contracts, CI-gateable.

Runs the ``repro.analysis`` lint rules over source trees and checks the
repo's declared :class:`CompileContract` suite.  Findings that are
neither inline-suppressed (``# repro: ignore[rule]``) nor present in the
committed baseline (``tools/analyze_baseline.json``) fail ``--ci`` mode
with a nonzero exit — the ``analyze`` CI job runs exactly::

    PYTHONPATH=src python tools/analyze.py --ci

which lints ``src/repro`` and verifies the static (structural) contract
level.  The nightly tier-2 job adds ``--contracts trace`` to execute the
real jitted entry points under compilation counting.

Other entry points::

    python tools/analyze.py src/repro benchmarks     # lint, human output
    python tools/analyze.py --rules bare-assert ...  # one rule only
    python tools/analyze.py --list-rules
    python tools/analyze.py --write-baseline         # grandfather current
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "src"))

from repro.analysis import (          # noqa: E402
    Baseline,
    analyze_paths,
    check_contracts,
    render,
    rule_ids,
)

DEFAULT_PATHS = ["src/repro"]
DEFAULT_BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "analyze_baseline.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="JAX/Pallas hazard lint + compile-contract checker")
    ap.add_argument("paths", nargs="*", default=None,
                    help=f"files or trees to lint (default: {DEFAULT_PATHS})")
    ap.add_argument("--ci", action="store_true",
                    help="gate mode: lint + static contracts, exit nonzero "
                         "on any non-baselined finding")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help="baseline JSON path (default: %(default)s)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write current lint findings to the baseline and "
                         "exit (grandfathering — prefer fixing)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset to run")
    ap.add_argument("--contracts", choices=("none", "static", "trace"),
                    default=None,
                    help="contract level to check (default: static under "
                         "--ci, none otherwise; trace executes real jitted "
                         "entry points)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        print("\n".join(rule_ids()))
        return 0

    paths = args.paths or DEFAULT_PATHS
    only = args.rules.split(",") if args.rules else None
    findings = analyze_paths(paths, only=only)

    if args.write_baseline:
        Baseline.write(args.baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.baseline}")
        return 0

    level = args.contracts
    if level is None:
        level = "static" if args.ci else "none"
    if level != "none":
        from repro.analysis.repo_contracts import all_contracts

        findings.extend(check_contracts(all_contracts(level), level))

    gated = Baseline.load(args.baseline).filter(findings)
    baselined = len(findings) - len(gated)

    print(render(gated))
    if baselined:
        print(f"({baselined} baselined finding(s) not shown)")
    if args.ci and gated:
        print("analyze: FAIL — fix the findings above, suppress a reviewed "
              "exception inline with '# repro: ignore[rule]', or (last "
              "resort) --write-baseline", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
