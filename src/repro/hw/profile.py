"""Heterogeneous per-site hardware profiles: resolve each analog matmul
site of a network to its own :class:`~repro.core.analog.AnalogSpec`.

The paper's closing argument is that proportionality lets designers
"match the precision of the hardware to the needs of the algorithm" —
which is only expressible if the spec plumbing stops being a single
global.  A :class:`Profile` is an ordered rule list mapping *sites* (the
stable hook names already used for programming keys — ``wq``/``wk``/
``wv``/``wo``, ``w_gate``/``w_up``/``w_down``, ``rwkv_*``, ``head``) to
specs:

* patterns match the site name (``"wq"``, ``"rwkv_*"``), its class
  (``"attn"``, ``"mlp"``), or the class-qualified name (``"attn.*"``,
  ``"mlp.w_down"``) — :data:`SITE_CLASS` defines the classes;
* a rule may be restricted to a *layer band* ``layers=(lo, hi)``
  (half-open, absolute layer indices), giving per-depth heterogeneity;
* the spec :data:`DIGITAL` keeps a site off-array (served by the exact
  digital matmul), and unmatched sites fall through to ``default``.

First matching rule wins.  Resolution is by *rule identity*
(:meth:`Profile.rule_index`), never by spec equality — spec fields may
be traced scalars inside a sweep compilation, and comparing them would
concretize tracers.  :meth:`Profile.layer_bands` groups layers into
maximal contiguous runs with a constant site→rule map; the model layer
scans each band separately and a single-band (uniform) profile lowers to
exactly the pre-profile program (bit-identical, pinned by
``tests/test_profile.py``).

``Profile.signature()`` is the canonical identity used for cache keys
and compile-group keys: profiles are frozen dataclasses of frozen
dataclasses, so ``repr`` is deterministic and two profiles agree on it
iff they resolve identically.
"""

from __future__ import annotations

import dataclasses
import fnmatch
import hashlib
from typing import Iterator, List, Optional, Sequence, Tuple, Union

from repro.core.analog import AnalogSpec

#: sentinel spec: keep this site off-array (exact digital matmul)
DIGITAL = "digital"

#: hook/site name -> site class (the pattern-matching namespace)
SITE_CLASS = {
    "wq": "attn", "wk": "attn", "wv": "attn", "wo": "attn",
    "xattn_wq": "attn", "xattn_wo": "attn",
    "w_gate": "mlp", "w_up": "mlp", "w_down": "mlp",
    "rwkv_wr": "rwkv", "rwkv_wk": "rwkv", "rwkv_wv": "rwkv",
    "rwkv_wg": "rwkv", "rwkv_wo": "rwkv", "rwkv_ck": "rwkv",
    "rwkv_cv": "rwkv", "rwkv_cr": "rwkv",
    "ssm_in": "ssm", "ssm_out": "ssm",
    "head": "head",
}

#: the lm_head site name (shared with ``repro.serve.analog_engine.HEAD``)
HEAD = "head"

SpecOrDigital = Union[AnalogSpec, str]


def site_class(site: str) -> str:
    """Class of a site; unknown sites are their own class."""
    return SITE_CLASS.get(site, site)


def _check_spec(spec: SpecOrDigital, where: str) -> None:
    if not (isinstance(spec, AnalogSpec) or spec == DIGITAL):
        raise ValueError(
            f"{where} must be an AnalogSpec or the string {DIGITAL!r}, "
            f"got {spec!r}")


@dataclasses.dataclass(frozen=True)
class Rule:
    """One resolver rule: ``pattern`` (+ optional layer band) → spec.

    ``name`` labels the rule for sweep-axis selectors
    (``Axis("attn:adc.bits", ...)``); it defaults to the pattern with a
    trailing ``.*`` stripped, so ``Rule("attn.*", spec)`` answers to the
    selector ``"attn"``.
    """

    pattern: str
    spec: SpecOrDigital
    layers: Optional[Tuple[int, int]] = None      # half-open [lo, hi)
    name: Optional[str] = None

    def __post_init__(self):
        _check_spec(self.spec, f"Rule({self.pattern!r}).spec")
        if self.layers is not None:
            lo, hi = self.layers
            if not (0 <= lo < hi):
                raise ValueError(
                    f"Rule({self.pattern!r}).layers must be a half-open "
                    f"band (lo, hi) with 0 <= lo < hi, got {self.layers}")
            object.__setattr__(self, "layers", (int(lo), int(hi)))

    @property
    def key(self) -> str:
        """The selector this rule answers to (sweep axes, ``with_field``)."""
        if self.name is not None:
            return self.name
        p = self.pattern
        return p[:-2] if p.endswith(".*") else p

    def matches(self, site: str, layer: Optional[int]) -> bool:
        if self.layers is not None:
            if layer is None:
                return False
            lo, hi = self.layers
            if not (lo <= layer < hi):
                return False
        cls = site_class(site)
        return any(
            fnmatch.fnmatchcase(cand, self.pattern)
            for cand in (site, cls, f"{cls}.{site}")
        )


@dataclasses.dataclass(frozen=True)
class Profile:
    """Site-resolved hardware description: ordered rules + default spec.

    >>> Profile.by_class(attn=spec8, mlp=spec6, head=DIGITAL,
    ...                  default=spec8)

    ``default`` applies to sites no rule matches; it defaults to
    :data:`DIGITAL` ("everything not explicitly placed stays digital").
    """

    rules: Tuple[Rule, ...] = ()
    default: SpecOrDigital = DIGITAL

    def __post_init__(self):
        object.__setattr__(self, "rules", tuple(self.rules))
        _check_spec(self.default, "Profile.default")

    # ---- constructors ----------------------------------------------------
    @classmethod
    def uniform(cls, spec: AnalogSpec) -> "Profile":
        """Every site on identical hardware — the pre-profile global spec."""
        if not isinstance(spec, AnalogSpec):
            raise ValueError(
                f"Profile.uniform expects an AnalogSpec, got {spec!r}")
        return cls(rules=(), default=spec)

    @classmethod
    def by_class(cls, *, default: SpecOrDigital = DIGITAL,
                 **class_specs: SpecOrDigital) -> "Profile":
        """One rule per site class: ``by_class(attn=a, mlp=b, head=DIGITAL)``."""
        rules = tuple(
            Rule(pattern=f"{c}.*" if c not in (HEAD,) else c, spec=s, name=c)
            for c, s in class_specs.items()
        )
        return cls(rules=rules, default=default)

    # ---- resolution ------------------------------------------------------
    def rule_index(self, site: str, layer: Optional[int] = None) -> int:
        """Index of the first matching rule, or -1 for the default.

        This is the tracer-safe resolution primitive: it inspects only
        patterns and integer bands, never spec values (which may be
        traced scalars inside a sweep compilation).
        """
        for i, rule in enumerate(self.rules):
            if rule.matches(site, layer):
                return i
        return -1

    def resolve(self, site: str, layer: Optional[int] = None) -> SpecOrDigital:
        """The spec serving ``site`` (at ``layer``), or :data:`DIGITAL`."""
        i = self.rule_index(site, layer)
        return self.default if i < 0 else self.rules[i].spec

    def is_digital(self, site: str, layer: Optional[int] = None) -> bool:
        return not isinstance(self.resolve(site, layer), AnalogSpec)

    def first_analog(self, site: str, n_layers: int) -> Optional[AnalogSpec]:
        """The site's first analog resolution over ``n_layers``, if any.

        Array geometry is band-uniform per site (enforced at pack build),
        so this spec answers geometry questions — mapping scheme, slice
        count — for the whole stack.
        """
        for layer in range(n_layers):
            sp = self.resolve(site, layer)
            if isinstance(sp, AnalogSpec):
                return sp
        return None

    def layer_bands(self, sites: Sequence[str], n_layers: int,
                    ) -> Tuple[Tuple[int, int], ...]:
        """Maximal contiguous layer bands with a constant site→rule map.

        A profile without layer-band rules always yields the single band
        ``((0, n_layers),)`` — the uniform fast path the model layer
        lowers through one scan, exactly as before profiles existed.
        """
        if n_layers < 1:
            raise ValueError(f"n_layers must be >= 1, got {n_layers}")
        bands: List[Tuple[int, int]] = []
        start = 0
        prev = tuple(self.rule_index(s, 0) for s in sites)
        for layer in range(1, n_layers):
            cur = tuple(self.rule_index(s, layer) for s in sites)
            if cur != prev:
                bands.append((start, layer))
                start, prev = layer, cur
        bands.append((start, n_layers))
        return tuple(bands)

    # ---- sweep-axis plumbing --------------------------------------------
    def selectors(self) -> Iterator[Tuple[str, AnalogSpec]]:
        """(selector, spec) for every analog rule, then ``("default", ...)``.

        The iteration order is rule order — deterministic, so prefixed
        dynamic-field names enumerate identically across processes.
        """
        for rule in self.rules:
            if isinstance(rule.spec, AnalogSpec):
                yield rule.key, rule.spec
        if isinstance(self.default, AnalogSpec):
            yield "default", self.default

    def _targets(self, selector: str) -> List[int]:
        return [i for i, r in enumerate(self.rules) if r.key == selector]

    def with_field(self, selector: str, path: str, value) -> "Profile":
        """Functionally set ``path`` on every spec the selector targets.

        ``selector`` is a rule key (``Rule.key``) or ``"default"``; the
        sweep layer spells this ``"<selector>:<field.path>"`` in axis
        paths (see ``repro.sweep.spec.set_field``).
        """
        from repro.sweep.spec import set_field as _set

        if selector == "default":
            if not isinstance(self.default, AnalogSpec):
                raise ValueError(
                    f"profile default is {DIGITAL!r}; cannot set "
                    f"{path!r} on it")
            return dataclasses.replace(
                self, default=_set(self.default, path, value))
        idx = self._targets(selector)
        if not idx:
            raise ValueError(
                f"no profile rule answers to selector {selector!r}; "
                f"known selectors: {[r.key for r in self.rules] + ['default']}")
        rules = list(self.rules)
        for i in idx:
            if not isinstance(rules[i].spec, AnalogSpec):
                raise ValueError(
                    f"rule {rules[i].pattern!r} (selector {selector!r}) is "
                    f"{DIGITAL!r}; cannot set {path!r} on it")
            rules[i] = dataclasses.replace(
                rules[i], spec=_set(rules[i].spec, path, value))
        return dataclasses.replace(self, rules=tuple(rules))

    def field(self, selector: str, path: str):
        """Read ``path`` from the selector's spec (first target wins)."""
        from repro.sweep.spec import get_field as _get

        if selector == "default":
            spec = self.default
        else:
            idx = self._targets(selector)
            if not idx:
                raise ValueError(
                    f"no profile rule answers to selector {selector!r}")
            spec = self.rules[idx[0]].spec
        if not isinstance(spec, AnalogSpec):
            raise ValueError(
                f"selector {selector!r} resolves to {DIGITAL!r}; it has "
                f"no field {path!r}")
        return _get(spec, path)

    # ---- identity --------------------------------------------------------
    def signature(self) -> str:
        """Canonical identity for cache keys and compile-group keys."""
        blob = repr(self)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]


def as_profile(spec: Union[AnalogSpec, Profile]) -> Profile:
    """Accept the legacy global-spec API: wrap an AnalogSpec uniformly."""
    if isinstance(spec, Profile):
        return spec
    if isinstance(spec, AnalogSpec):
        return Profile.uniform(spec)
    raise ValueError(
        f"expected an AnalogSpec or hw.Profile, got {type(spec).__name__}: "
        f"{spec!r}")


# ---------------------------------------------------------------------------
# per-band site specs (the static payload the model layer threads)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SiteSpecs:
    """Frozen site→spec mapping for one layer band (hashable, ordered)."""

    items: Tuple[Tuple[str, AnalogSpec], ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "items", tuple(self.items))

    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(n for n, _ in self.items)

    def __contains__(self, name: str) -> bool:
        return any(n == name for n, _ in self.items)

    def get(self, name: str) -> Optional[AnalogSpec]:
        for n, s in self.items:
            if n == name:
                return s
        return None

    def spec_for(self, name: str) -> AnalogSpec:
        s = self.get(name)
        if s is None:
            raise KeyError(
                f"site {name!r} has no analog spec in this band; "
                f"analog sites: {list(self.names)}")
        return s


#: AnalogSpec fields that shape the programmed conductance stacks.  Sites
#: are stacked over *all* layers (one scanned array per site), so a site's
#: resolved specs may differ across layer bands only in fields that leave
#: the stack's shape/dtype/pytree-structure unchanged (ADC style/bits,
#: error model, r_hat, on_off_ratio, input bits, ...).  These fields must
#: agree:
GEOMETRY_FIELDS = (
    "mapping.scheme", "mapping.weight_bits", "mapping.bits_per_cell",
    "mapping.unit_column", "max_rows", "compute_dtype",
)


def geometry_key(spec: AnalogSpec) -> Tuple:
    """The concrete (never-traced) array-geometry identity of a spec."""
    m = spec.mapping
    return (m.scheme, m.weight_bits, m.bits_per_cell, m.unit_column,
            spec.max_rows, str(spec.compute_dtype))


def fused_site_classes(
    profile: Profile,
    sites: Sequence[str],
    n_layers: int,
) -> "dict[Tuple, List[str]]":
    """Group a profile's analog sites by fused-kernel compile identity.

    Keys are :func:`repro.core.analog.fuse_signature` tuples — the static
    program identity of the fused serving kernel — and values the sorted
    site names that share it.  Sites resolving digital everywhere, or to
    specs that refuse to fuse (``fused == "off"``, digital-accum
    parasitics, uncalibrated ADC, ...), never appear: they take the
    digital or composed path and own no fused compile group.  The
    ``serve/fused-one-compile-per-site-class`` contract pins the served
    model's fused-kernel compile count to ``len()`` of this mapping.
    """
    from repro.core.analog import fuse_signature

    groups: "dict[Tuple, List[str]]" = {}
    for site in sites:
        sigs = set()
        for lo, _hi in profile.layer_bands((site,), n_layers):
            spec = profile.resolve(site, lo)
            if isinstance(spec, AnalogSpec):
                sig = fuse_signature(spec)
                if sig is not None:
                    sigs.add(sig)
        for sig in sigs:
            groups.setdefault(sig, []).append(site)
    return {sig: sorted(names) for sig, names in sorted(groups.items())}


def check_band_geometry(site: str, specs: Sequence[AnalogSpec]) -> None:
    """Raise if a site's per-band specs disagree on array geometry."""
    keys = {geometry_key(s) for s in specs}
    if len(keys) > 1:
        raise ValueError(
            f"site {site!r} resolves to specs with different array "
            f"geometry across layer bands; the fields {GEOMETRY_FIELDS} "
            f"must agree for a site (its conductance stack is one scanned "
            f"array), got geometries {sorted(keys)}")
