"""``repro.hw`` — heterogeneous per-site hardware profiles.

A :class:`Profile` maps each analog matmul site of a network (the stable
hook names — ``wq``/``wk``/``wv``/``wo``, ``w_gate``/``w_up``/``w_down``,
``rwkv_*``, ``head``) to its own :class:`~repro.core.analog.AnalogSpec`,
via ordered pattern rules with optional layer bands and a ``digital``
fallback for sites kept off-array.  See DESIGN.md §Heterogeneous
profiles.

>>> from repro import hw
>>> profile = hw.Profile.by_class(
...     attn=design_a(),                        # 8-bit calibrated ADC
...     mlp=set_field(design_a(), "adc.bits", 6),
...     head=hw.DIGITAL,                        # lm_head stays digital
... )
>>> pack = program_lm(cfg, params, profile, key)
"""

from repro.hw.profile import (
    DIGITAL,
    GEOMETRY_FIELDS,
    HEAD,
    Profile,
    Rule,
    SITE_CLASS,
    SiteSpecs,
    as_profile,
    check_band_geometry,
    fused_site_classes,
    geometry_key,
    site_class,
)

__all__ = [
    "DIGITAL",
    "GEOMETRY_FIELDS",
    "HEAD",
    "Profile",
    "Rule",
    "SITE_CLASS",
    "SiteSpecs",
    "as_profile",
    "check_band_geometry",
    "fused_site_classes",
    "geometry_key",
    "site_class",
]
