"""Mamba2 and RWKV6 blocks on the shared chunked decay recurrence.

Faithfulness notes (see DESIGN.md §Arch-applicability):

* Mamba2: in/out projections, depthwise causal conv, per-head scalar decay
  ``exp(-softplus(dt) * A_h)``, SSD recurrence with state ``ssm_state``,
  D skip, gated (SiLU) output, RMS norm before out-projection.
* RWKV6 "Finch": token-shift with learned static mix, r/k/v/g projections,
  **data-dependent decay** via a low-rank MLP on the shifted stream (the
  Finch hallmark, kept faithful), current-token bonus ``u``, per-head group
  norm, SiLU gate.  The data-dependent token-shift interpolation (ddlerp)
  is simplified to a static mix — it does not interact with the paper's
  technique (projections are standard MVMs either way).

The analog hook applies to the *weight-stationary projections* only; the
state recurrences are dynamic and stay digital (the paper's technique
targets in-memory MVMs against stored weights).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import AnalogCtx, dense, rms_norm
from repro.models.recurrent import chunked_decay_recurrence, decay_step

CONV_W = 4  # depthwise conv window


# ---------------------------------------------------------------------------
# Mamba2
# ---------------------------------------------------------------------------


def mamba_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    h = cfg.ssm_heads
    hd = cfg.ssm_head_dim
    return h, hd, cfg.ssm_state


def init_mamba(key: jax.Array, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d = cfg.d_model
    h, hd, st = mamba_dims(cfg)
    din = h * hd
    proj_out = 2 * din + 2 * st + h          # z, x, B, C, dt
    ks = jax.random.split(key, 5)
    return {
        "in_proj": jax.random.normal(ks[0], (n_layers, d, proj_out), dtype)
        * d ** -0.5,
        "conv_w": jax.random.normal(ks[1], (n_layers, CONV_W, din + 2 * st),
                                    dtype) * 0.3,
        "a_log": jnp.zeros((n_layers, h), jnp.float32),
        "dt_bias": jnp.zeros((n_layers, h), jnp.float32),
        "d_skip": jnp.ones((n_layers, h), jnp.float32),
        "out_norm": jnp.zeros((n_layers, din), dtype),
        "out_proj": jax.random.normal(ks[2], (n_layers, din, d), dtype)
        * din ** -0.5,
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 carry: Optional[jax.Array] = None):
    """Depthwise causal conv.  x: (B,S,C); w: (W,C); carry: (B,W-1,C)."""
    b, s, c = x.shape
    if carry is None:
        carry = jnp.zeros((b, CONV_W - 1, c), x.dtype)
    xp = jnp.concatenate([carry, x], axis=1)
    out = sum(
        xp[:, i : i + s, :] * w[i][None, None, :] for i in range(CONV_W)
    )
    new_carry = xp[:, -(CONV_W - 1) :, :]
    return jax.nn.silu(out), new_carry


def mamba_block(
    p: dict,
    x: jax.Array,                   # (B, S, d)
    cfg: ModelConfig,
    *,
    state: Optional[dict] = None,   # {"ssm": (B,H,st,hd), "conv": (B,W-1,C)}
    decode: bool = False,
    ctx: Optional[AnalogCtx] = None,
    aux: Optional[dict] = None,
) -> Tuple[jax.Array, dict]:
    b, s, d = x.shape
    h, hd, st = mamba_dims(cfg)
    din = h * hd

    zxbcdt = dense(x, p["in_proj"], "ssm_in", ctx, aux)
    z, xs, bc, dt = jnp.split(zxbcdt, [din, 2 * din, 2 * din + 2 * st], axis=-1)
    conv_in = jnp.concatenate([xs, bc], axis=-1)
    conv_out, conv_carry = _causal_conv(
        conv_in, p["conv_w"], None if state is None else state["conv"]
    )
    xs = conv_out[..., :din].reshape(b, s, h, hd)
    bmat = conv_out[..., din : din + st]                     # (B,S,st)
    cmat = conv_out[..., din + st :]                         # (B,S,st)

    a = -jnp.exp(p["a_log"].astype(jnp.float32))             # (H,) negative
    dt_sp = jax.nn.softplus(dt.astype(jnp.float32)
                            + p["dt_bias"].astype(jnp.float32))  # (B,S,H)
    log_w = (dt_sp * a[None, None, :])[..., None]            # (B,S,H,1) <= 0
    log_w = jnp.broadcast_to(log_w, (b, s, h, st))

    # k = dt-scaled B (shared across heads), v = x, r = C
    k = jnp.broadcast_to(bmat[:, :, None, :], (b, s, h, st)) * dt_sp[..., None]
    r = jnp.broadcast_to(cmat[:, :, None, :], (b, s, h, st))
    v = xs

    s0 = None if state is None else state["ssm"]
    if decode:
        y1, new_ssm = decay_step(
            r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
            s0 if s0 is not None else jnp.zeros((b, h, st, hd), jnp.float32),
        )
        y = y1[:, None]
    else:
        y, new_ssm = chunked_decay_recurrence(r, k, v, log_w, s0=s0, chunk=64)

    y = y + xs * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, din) * jax.nn.silu(z)
    y = rms_norm(y, p["out_norm"])
    out = dense(y, p["out_proj"], "ssm_out", ctx, aux)
    return out, {"ssm": new_ssm, "conv": conv_carry}


def mamba_state_init(cfg: ModelConfig, b: int, dtype) -> dict:
    h, hd, st = mamba_dims(cfg)
    din = h * hd
    return {
        "ssm": jnp.zeros((b, h, st, hd), jnp.float32),
        "conv": jnp.zeros((b, CONV_W - 1, din + 2 * st), dtype),
    }


# ---------------------------------------------------------------------------
# RWKV6 (Finch)
# ---------------------------------------------------------------------------

RWKV_LORA = 64


def init_rwkv(key: jax.Array, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    ks = jax.random.split(key, 12)
    sc = d ** -0.5
    return {
        # time mix
        "mix": 0.5 * jnp.ones((n_layers, 5, d), dtype),       # r,k,v,g,w mixes
        "wr": jax.random.normal(ks[0], (n_layers, d, d), dtype) * sc,
        "wk": jax.random.normal(ks[1], (n_layers, d, d), dtype) * sc,
        "wv": jax.random.normal(ks[2], (n_layers, d, d), dtype) * sc,
        "wg": jax.random.normal(ks[3], (n_layers, d, d), dtype) * sc,
        "wo": jax.random.normal(ks[4], (n_layers, d, d), dtype) * sc,
        "w_base": -6.0 * jnp.ones((n_layers, d), jnp.float32),
        "w_lora_a": jax.random.normal(ks[5], (n_layers, d, RWKV_LORA), dtype)
        * sc,
        "w_lora_b": jax.random.normal(ks[6], (n_layers, RWKV_LORA, d), dtype)
        * RWKV_LORA ** -0.5,
        "u": jax.random.normal(ks[7], (n_layers, h, hd), jnp.float32) * 0.3,
        "ln_x_scale": jnp.ones((n_layers, d), dtype),
        "ln_x_bias": jnp.zeros((n_layers, d), dtype),
        # channel mix
        "cmix": 0.5 * jnp.ones((n_layers, 2, d), dtype),
        "ck": jax.random.normal(ks[8], (n_layers, d, cfg.d_ff), dtype) * sc,
        "cv": jax.random.normal(ks[9], (n_layers, cfg.d_ff, d), dtype)
        * cfg.d_ff ** -0.5,
        "cr": jax.random.normal(ks[10], (n_layers, d, d), dtype) * sc,
    }


def _token_shift(x: jax.Array, prev: Optional[jax.Array]):
    """x_{t-1} stream; ``prev``: (B,1,d) carried last token (decode)."""
    b, s, d = x.shape
    if prev is None:
        prev = jnp.zeros((b, 1, d), x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1), x[:, -1:]


def rwkv_time_mix(
    p: dict, x: jax.Array, cfg: ModelConfig, *,
    state: Optional[dict], decode: bool,
    ctx: Optional[AnalogCtx] = None, aux: Optional[dict] = None,
):
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    prev = None if state is None else state["shift_t"]
    xs, last = _token_shift(x, prev)

    def mix(i):
        m = p["mix"][i][None, None]
        return x * m + xs * (1.0 - m)

    r = dense(mix(0), p["wr"], "rwkv_wr", ctx, aux).reshape(b, s, h, hd)
    k = dense(mix(1), p["wk"], "rwkv_wk", ctx, aux).reshape(b, s, h, hd)
    v = dense(mix(2), p["wv"], "rwkv_wv", ctx, aux).reshape(b, s, h, hd)
    g = dense(mix(3), p["wg"], "rwkv_wg", ctx, aux)

    # Finch: data-dependent decay via low-rank MLP on the mixed stream
    lora = jnp.tanh(mix(4) @ p["w_lora_a"]) @ p["w_lora_b"]
    log_w = -jnp.exp(
        jnp.clip(p["w_base"][None, None].astype(jnp.float32)
                 + lora.astype(jnp.float32), -8.0, 2.0)
    )
    log_w = log_w.reshape(b, s, h, hd)

    s0 = None if state is None else state["wkv"]
    if decode:
        y1, new_wkv = decay_step(
            r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
            s0 if s0 is not None else jnp.zeros((b, h, hd, hd), jnp.float32),
            u=p["u"],
        )
        y = y1[:, None]
    else:
        y, new_wkv = chunked_decay_recurrence(
            r, k, v, log_w, u=p["u"], s0=s0, chunk=32
        )

    y = y.reshape(b, s, d)
    # per-head group norm
    yh = y.reshape(b, s, h, hd).astype(jnp.float32)
    mu = jnp.mean(yh, axis=-1, keepdims=True)
    var = jnp.var(yh, axis=-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + 64e-5)
    y = yh.reshape(b, s, d).astype(x.dtype) * p["ln_x_scale"] + p["ln_x_bias"]
    y = y * jax.nn.silu(g)
    out = dense(y, p["wo"], "rwkv_wo", ctx, aux)
    return out, {"wkv": new_wkv, "shift_t": last}


def rwkv_channel_mix(
    p: dict, x: jax.Array, *, state: Optional[dict], decode: bool,
    ctx: Optional[AnalogCtx] = None, aux: Optional[dict] = None,
):
    prev = None if state is None else state["shift_c"]
    xs, last = _token_shift(x, prev)
    mk = p["cmix"][0][None, None]
    mr = p["cmix"][1][None, None]
    xk = x * mk + xs * (1.0 - mk)
    xr = x * mr + xs * (1.0 - mr)
    kk = jnp.square(jax.nn.relu(dense(xk, p["ck"], "rwkv_ck", ctx, aux)))
    rr = jax.nn.sigmoid(dense(xr, p["cr"], "rwkv_cr", ctx, aux))
    out = rr * dense(kk, p["cv"], "rwkv_cv", ctx, aux)
    return out, {"shift_c": last}


def rwkv_state_init(cfg: ModelConfig, b: int, dtype) -> dict:
    d = cfg.d_model
    h = cfg.n_heads
    hd = d // h
    return {
        "wkv": jnp.zeros((b, h, hd, hd), jnp.float32),
        "shift_t": jnp.zeros((b, 1, d), dtype),
        "shift_c": jnp.zeros((b, 1, d), dtype),
    }
