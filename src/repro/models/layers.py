"""Shared layers: norms, RoPE, dense projections (with the analog execution
hook), activations, and streaming attention.

Attention is implemented as an online-softmax scan over KV chunks (a
JAX-level flash attention): the (Sq, Skv) score matrix never materializes,
which is what makes the 32k-prefill and 500k-decode dry-run cells fit in
HBM.  On TPU this would be a Pallas kernel; attention is not the paper's
contribution, so the lax.scan formulation is the right altitude here (see
DESIGN.md) — XLA fuses the inner block well and the roofline accounting is
identical.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.analog import AnalogSpec, AnalogWeights, analog_matmul
from repro.hw.profile import SiteSpecs

# ---------------------------------------------------------------------------
# analog execution hook
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AnalogCtx:
    """Per-layer analog execution context threaded through blocks.

    ``weights[name]`` is the :class:`AnalogWeights` for this layer (already
    sliced out of the layer-stacked pack by the scan), ``lo/hi[name]`` the
    calibrated per-slice ADC limits, ``act[name]`` the activation clip.
    ``specs`` carries the *site-resolved* spec per hook name (heterogeneous
    profiles: attention and MLP projections may sit on different hardware;
    sites absent from ``weights`` run digitally).  ``collect=True``
    bypasses the ADC and emits calibration stats into the block's aux
    dict instead.
    """

    specs: SiteSpecs = dataclasses.field(metadata=dict(static=True))
    weights: Dict[str, AnalogWeights]
    lo: Dict[str, jax.Array]
    hi: Dict[str, jax.Array]
    act: Dict[str, jax.Array]
    collect: bool = dataclasses.field(default=False, metadata=dict(static=True))


def dense(
    x: jax.Array,
    w: jax.Array,
    name: str,
    ctx: Optional[AnalogCtx],
    aux: Optional[dict] = None,
    *,
    bias: Optional[jax.Array] = None,
) -> jax.Array:
    """``x @ w`` — digitally, or through the analog pipeline when ``ctx``
    carries programmed conductances for ``name`` (executed under the
    site's own resolved :class:`AnalogSpec`)."""
    if ctx is None or name not in ctx.weights:
        y = x @ w
    else:
        aw = ctx.weights[name]
        spec = ctx.specs.spec_for(name)
        if ctx.collect:
            y, stats = analog_matmul(
                x, aw, spec, act_hi=ctx.act.get(name), collect=True
            )
            if aux is not None:
                aux[f"adc/{name}"] = stats
                from repro.core.quant import calibrate_act_range

                _, a_hi = calibrate_act_range(x, spec.input_bits)
                aux[f"act/{name}"] = a_hi
        else:
            y = analog_matmul(
                x,
                aw,
                spec,
                adc_lo=ctx.lo[name],
                adc_hi=ctx.hi[name],
                act_hi=ctx.act.get(name),
            )
        y = y.astype(x.dtype)
    if bias is not None:
        y = y + bias
    return y


# ---------------------------------------------------------------------------
# norms / embeddings / activations
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def norm(x: jax.Array, p: dict, kind: str) -> jax.Array:
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


ACTIVATIONS = {
    "swiglu": jax.nn.silu,
    "geglu": gelu,
    "gelu": gelu,
}


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding.  x: (B, S, H, hd); positions: (B, S) or (S,).

    Formulated as ``x * cos + rotate_half(x) * sin`` with full-length
    (hd-sized) trig vectors and a roll-based rotate-half.  The textbook
    slice-into-halves + concatenate form is numerically identical but must
    not be used here: concatenating slices back together along the head_dim
    axis miscompiles in the XLA SPMD partitioner when that axis is
    model-sharded on a multi-axis mesh (within-head tensor parallelism —
    the 2x4 debug mesh shards wk's kv*hd=32 output dim across 4 devices),
    silently corrupting k and the training loss.  roll and elementwise ops
    partition correctly.
    """
    hd = x.shape[-1]
    half = hd // 2
    idx = jnp.arange(hd)
    freqs = theta ** (-(idx % half).astype(jnp.float32) / half)     # (hd,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs      # (B, S, hd)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    sign = jnp.where(idx < half, -1.0, 1.0)
    rot = jnp.roll(x, half, axis=-1) * sign                     # [-x2, x1]
    return (x * cos + rot * sin).astype(x.dtype)


# ---------------------------------------------------------------------------
# streaming attention (online softmax over KV chunks)
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def streaming_attention(
    q: jax.Array,                # (B, Sq, H, hd)
    k: jax.Array,                # (B, Skv, KV, hd)
    v: jax.Array,                # (B, Skv, KV, hd)
    *,
    q_offset,                    # absolute position of q[0]: scalar or (B,)
    causal: bool = True,
    window: Optional[int] = None,
    kv_len=None,                 # dynamic valid KV length: scalar or (B,)
    chunk: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """GQA attention with an online-softmax scan over KV chunks.

    ``q_offset`` / ``kv_len`` may be per-row ``(B,)`` vectors — the
    continuous-batching decode path, where every slot sits at its own
    cache fill.  The scalar path (shared offset) lowers to the exact same
    ops as before, so single-request serving is bit-identical.
    """
    b, sq, h, hd = q.shape
    _, skv, kv_heads, _ = k.shape
    g = h // kv_heads
    scale = scale if scale is not None else hd ** -0.5

    chunk = min(chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, kv_heads, hd)
    vc = v.reshape(b, n_chunks, chunk, kv_heads, hd)
    kc = jnp.moveaxis(kc, 1, 0)          # (C, B, chunk, KV, hd)
    vc = jnp.moveaxis(vc, 1, 0)

    qg = q.reshape(b, sq, kv_heads, g, hd).astype(jnp.float32) * scale
    # (sq,) for a shared scalar offset, (B, sq) for per-row offsets
    q_pos = jnp.asarray(q_offset)[..., None] + jnp.arange(sq)

    def body(carry, inp):
        m, l, acc = carry
        j, k_j, v_j = inp
        k_pos = j * chunk + jnp.arange(chunk)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qg, k_j.astype(jnp.float32))
        mask = jnp.ones(q_pos.shape + (chunk,), bool)   # (..., sq, chunk)
        if causal:
            mask &= k_pos <= q_pos[..., None]
        if window is not None:
            mask &= k_pos > q_pos[..., None] - window
        if kv_len is not None:
            mask &= k_pos < jnp.asarray(kv_len)[..., None, None]
        if pad:
            mask &= k_pos < skv
        # broadcast onto s: (b, KV, g, sq, chunk)
        mask = mask[None, None, None] if mask.ndim == 2 else mask[:, None, None]
        s = jnp.where(mask, s, NEG_INF)
        m_chunk = jnp.max(s, axis=-1)                        # (b,k,g,q)
        m_new = jnp.maximum(m, m_chunk)
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p, v_j.astype(jnp.float32)
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kv_heads, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv_heads, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kv_heads, g, sq, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]             # (b,k,g,q,hd)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hd)
    return out.astype(q.dtype)
