"""Feed-forward blocks: gated (SwiGLU/GeGLU) and plain MLPs, plus top-k
token-choice Mixture-of-Experts with capacity-based dispatch.

MoE dispatch avoids any (tokens, experts, capacity) tensor: assignments are
flattened, positions-within-expert computed by a (tokens*k, E) cumsum, and
tokens moved with scatter/gather into an (E*C, d) buffer.  Under the
production mesh the buffer shards over the model axis (expert parallelism)
and the scatter lowers to an all-to-all-style exchange.  Dropped tokens
(beyond capacity) fall back to the residual stream, standard for
capacity-based routing.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import ACTIVATIONS, AnalogCtx, dense


def init_mlp(key: jax.Array, d: int, ff: int, act: str, n_layers: int,
             dtype) -> dict:
    ks = jax.random.split(key, 3)
    sc_in, sc_out = d ** -0.5, ff ** -0.5
    p = {
        "w_up": jax.random.normal(ks[0], (n_layers, d, ff), dtype) * sc_in,
        "w_down": jax.random.normal(ks[1], (n_layers, ff, d), dtype) * sc_out,
    }
    if act in ("swiglu", "geglu"):
        p["w_gate"] = jax.random.normal(ks[2], (n_layers, d, ff), dtype) * sc_in
    return p


def mlp_block(p: dict, x: jax.Array, act: str,
              ctx: Optional[AnalogCtx] = None,
              aux: Optional[dict] = None) -> jax.Array:
    fn = ACTIVATIONS[act]
    if "w_gate" in p:
        g = fn(dense(x, p["w_gate"], "w_gate", ctx, aux))
        h = g * dense(x, p["w_up"], "w_up", ctx, aux)
    else:
        h = fn(dense(x, p["w_up"], "w_up", ctx, aux))
    return dense(h, p["w_down"], "w_down", ctx, aux)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------


def init_moe(key: jax.Array, cfg: ModelConfig, n_layers: int, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    sc_in, sc_out = d ** -0.5, ff ** -0.5
    return {
        "router": jax.random.normal(ks[0], (n_layers, d, e), jnp.float32) * sc_in,
        "w_gate": jax.random.normal(ks[1], (n_layers, e, d, ff), dtype) * sc_in,
        "w_up": jax.random.normal(ks[2], (n_layers, e, d, ff), dtype) * sc_in,
        "w_down": jax.random.normal(ks[3], (n_layers, e, ff, d), dtype) * sc_out,
    }


def moe_capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)


def moe_block(
    p: dict,
    x: jax.Array,                  # (B, S, d)
    cfg: ModelConfig,
    ctx: Optional[AnalogCtx] = None,
    aux: Optional[dict] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, load_balance_aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    topw, topi = jax.lax.top_k(gates, k)                         # (T, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)          # renorm

    # load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[topi.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)
    ) / (t * k)
    lb_loss = e * jnp.sum(me * ce)

    # ---- dispatch -------------------------------------------------------
    cap = moe_capacity(t, cfg)
    eid = topi.reshape(-1)                                       # (T*k,)
    wgt = topw.reshape(-1).astype(x.dtype)
    tok = jnp.repeat(jnp.arange(t), k)

    onehot = jax.nn.one_hot(eid, e, dtype=jnp.int32)             # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - onehot                    # pos before me
    pos = jnp.sum(pos * onehot, axis=-1)                         # (T*k,)
    keep = pos < cap
    dest = jnp.where(keep, eid * cap + pos, e * cap)             # overflow slot

    xbuf = jnp.zeros((e * cap + 1, d), x.dtype).at[dest].add(xt[tok])
    xe = xbuf[: e * cap].reshape(e, cap, d)

    from repro.sharding.perf import FLAGS, constraint

    if FLAGS.moe_dispatch_sharding:
        # Force the dispatched buffer onto the expert-parallel layout so
        # the scatter lowers to an exchange instead of replicate+all-reduce
        # (EXPERIMENTS.md §Perf, hypothesis M1 — REFUTED in round 2:
        # GSPMD replicated the buffer and expert compute blew up 6.6x).
        xe = constraint(xe, "model", None, None)
    if FLAGS.moe_cap_shard:
        # Hypothesis M4: 2D expert parallelism — experts over "model",
        # capacity over "data", so expert FLOPs distribute over all 256
        # chips with an all-to-all dispatch instead of f-dim all-reduces.
        def _cap(z):
            try:
                return constraint(z, "model", "data", None)
            except Exception:
                return constraint(z, "model", None, None)
        xe = _cap(xe)
    if FLAGS.moe_weight_gather:
        # Hypothesis M3: expert weights are FSDP-sharded on the f
        # (contraction) dim; GSPMD then ALL-REDUCES the (E,C,d) activations
        # (10.7 GB/layer) instead of ALL-GATHERING the (E/16,d,f) weights
        # (0.3 GB/layer).  Constrain the weights to gather-before-use,
        # leaving the dispatch layout to the partitioner.
        p = dict(p)
        for wname in ("w_gate", "w_up", "w_down"):
            p[wname] = constraint(p[wname], "model", None, None)

    # ---- expert compute (batched over experts) --------------------------
    fn = ACTIVATIONS[cfg.act]
    g = fn(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"]))
    h = g * jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"])              # (E, C, d)
    if FLAGS.moe_dispatch_sharding:
        ye = constraint(ye, "model", None, None)
    if FLAGS.moe_cap_shard:
        ye = _cap(ye)

    # ---- combine ---------------------------------------------------------
    yflat = ye.reshape(e * cap, d)
    contrib = jnp.where(keep, wgt, 0.0)[:, None] * yflat[
        jnp.minimum(dest, e * cap - 1)
    ]
    y = jnp.zeros((t, d), x.dtype).at[tok].add(contrib)

    if aux is not None:
        aux["moe/lb_loss"] = lb_loss
        aux["moe/drop_frac"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return y.reshape(b, s, d), lb_loss


def moe_block_dense_ref(p, x, cfg):
    """O(E) dense reference used by tests: every expert computes every
    token, outputs weighted by the (renormalized) top-k gates."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, d)
    gates = jax.nn.softmax(xt.astype(jnp.float32) @ p["router"], axis=-1)
    topw, topi = jax.lax.top_k(gates, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    wfull = jnp.zeros_like(gates)
    wfull = jax.vmap(lambda wrow, irow, vrow: wrow.at[irow].set(vrow))(
        wfull, topi, topw
    )
    fn = ACTIVATIONS[cfg.act]
    g = fn(jnp.einsum("td,edf->etf", xt, p["w_gate"]))
    h = g * jnp.einsum("td,edf->etf", xt, p["w_up"])
    ye = jnp.einsum("etf,efd->etd", h, p["w_down"])
    y = jnp.einsum("te,etd->td", wfull.astype(x.dtype), ye)
    return y.reshape(b, s, d)
