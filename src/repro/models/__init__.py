"""Model substrate: layers, attention variants, MoE, SSM/RWKV recurrences,
decoder-only / encoder-decoder transformers, and the analog execution hook.
"""
