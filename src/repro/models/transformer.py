"""Decoder-only LM covering the dense / moe / vlm / ssm(rwkv) families.

Layers are scanned (stacked params, one traced block body) so 94-layer
configs lower to compact HLO.  The same block body serves training
(no cache), prefill (returns a cache) and decode (single-token cache
update) — the cache travels through the scan as per-layer xs/ys.

The analog execution path threads an :class:`AnalogPack` whose per-layer
conductance stacks are scanned alongside the parameters; see
``repro.serve.analog_engine`` for programming/calibration.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.core.analog import AnalogSpec, AnalogWeights, analog_matmul
from repro.hw.profile import Profile, SiteSpecs
from repro.models import ssm as ssm_mod
from repro.models.attention import attention_block, init_attention
from repro.models.layers import AnalogCtx, dense, norm, rms_norm
from repro.models.mlp import init_mlp, init_moe, mlp_block, moe_block

GLOBAL_WINDOW = 1 << 30

NO_CAST = ("a_log", "dt_bias", "u", "w_base", "d_skip", "router")


def cast_params(params, dtype):
    """Cast float params to the compute dtype, keeping numerically
    sensitive leaves (decay logs, router) in fp32."""

    def f(path, x):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if x.dtype == jnp.float32 and name not in NO_CAST:
            return x.astype(dtype)
        return x

    return jax.tree_util.tree_map_with_path(f, params)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AnalogPack:
    """Layer-stacked analog weights + calibrated ranges for the LM.

    Heterogeneous profiles: ``profile`` is the site resolver the pack was
    programmed from; ``bands`` are its maximal contiguous layer bands
    (``((0, L),)`` for any profile without layer-band rules — the uniform
    fast path, one scan, bit-identical to the pre-profile program) and
    ``band_specs[i]`` the resolved (site, spec) map serving band ``i``.
    Each site keeps ONE layer-stacked conductance array regardless of
    banding (per-band specs must agree on array geometry —
    ``repro.hw.check_band_geometry``); the scan is split at band
    boundaries so each band runs under its own static specs.
    """

    profile: Profile = dataclasses.field(metadata=dict(static=True))
    bands: Tuple[Tuple[int, int], ...] = dataclasses.field(
        metadata=dict(static=True))
    band_specs: Tuple[SiteSpecs, ...] = dataclasses.field(
        metadata=dict(static=True))
    layer_weights: Dict[str, AnalogWeights]     # arrays stacked over L
    layer_lo: Dict[str, jax.Array]              # (L, S)
    layer_hi: Dict[str, jax.Array]
    layer_act: Dict[str, jax.Array]             # (L,)
    head: Optional[AnalogWeights] = None        # lm_head
    head_lo: Optional[jax.Array] = None
    head_hi: Optional[jax.Array] = None
    head_act: Optional[jax.Array] = None
    head_spec: Optional[AnalogSpec] = dataclasses.field(
        default=None, metadata=dict(static=True))
    collect: bool = dataclasses.field(default=False, metadata=dict(static=True))

    def site_spec(self, name: str) -> AnalogSpec:
        """The spec serving ``name`` (first band where it is analog).

        Array geometry (mapping, max_rows) is band-uniform per site, so
        any analog band answers geometry questions like ``mapping.sliced``.
        """
        if name == "head" and self.head_spec is not None:
            return self.head_spec
        for ss in self.band_specs:
            s = ss.get(name)
            if s is not None:
                return s
        raise KeyError(f"site {name!r} is not analog in any band of this pack")

    def age(self, t, key: jax.Array) -> "AnalogPack":
        """Deterministic device state of this pack at age ``t`` (units of
        the programming-reference time t0; ``t = 1`` is fresh).

        Applies each site's own drift/fault models
        (``repro.core.errors``) with keys folded from the same stable
        hook-name hashes as programming, so aging is replayable and
        band-structure-invariant; bit-identical to ``self`` at ``t = 1``
        or with aging disabled.  See ``repro.serve.analog_engine.age_pack``.
        """
        from repro.serve.analog_engine import age_pack

        return age_pack(self, t, key)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    """fp32 master parameters."""
    ks = jax.random.split(key, 8)
    d, l, v = cfg.d_model, cfg.n_layers, cfg.vocab
    dt = jnp.float32
    p: Dict[str, Any] = {
        "embed": jax.random.normal(ks[0], (v, d), dt) * d ** -0.5,
        "final_norm": {"scale": jnp.zeros((d,), dt)},
    }
    if cfg.norm == "layernorm":
        p["final_norm"] = {"scale": jnp.ones((d,), dt),
                           "bias": jnp.zeros((d,), dt)}
    if not cfg.tie_embeddings:
        p["lm_head"] = jax.random.normal(ks[1], (d, v), dt) * d ** -0.5

    layers: Dict[str, Any] = {}
    if cfg.rwkv:
        layers["rwkv"] = ssm_mod.init_rwkv(ks[2], cfg, l, dt)
        layers["norm1"] = _norm_init(cfg, l, dt)
        layers["norm2"] = _norm_init(cfg, l, dt)
    else:
        layers["attn"] = init_attention(ks[2], cfg, l, dt)
        layers["norm1"] = _norm_init(cfg, l, dt)
        layers["norm2"] = _norm_init(cfg, l, dt)
        if cfg.n_experts:
            layers["moe"] = init_moe(ks[3], cfg, l, dt)
            if cfg.dense_residual:
                layers["mlp"] = init_mlp(ks[4], d, cfg.d_ff, cfg.act, l, dt)
        else:
            layers["mlp"] = init_mlp(ks[4], d, cfg.d_ff, cfg.act, l, dt)
    p["layers"] = layers
    return p


def _norm_init(cfg: ModelConfig, l: int, dt) -> dict:
    if cfg.norm == "layernorm":
        return {"scale": jnp.ones((l, cfg.d_model), dt),
                "bias": jnp.zeros((l, cfg.d_model), dt)}
    return {"scale": jnp.zeros((l, cfg.d_model), dt)}


def layer_windows(cfg: ModelConfig) -> Optional[jax.Array]:
    """Per-layer attention window (gemma3's N local : 1 global pattern)."""
    if cfg.sliding_window is None:
        return None
    if cfg.local_global_ratio == 0:
        return jnp.full((cfg.n_layers,), cfg.sliding_window, jnp.int32)
    period = cfg.local_global_ratio + 1
    idx = jnp.arange(cfg.n_layers)
    is_global = (idx % period) == (period - 1)
    return jnp.where(is_global, GLOBAL_WINDOW, cfg.sliding_window)


# ---------------------------------------------------------------------------
# block body
# ---------------------------------------------------------------------------


def _block(
    cfg: ModelConfig,
    p_l: dict,
    x: jax.Array,
    *,
    positions,
    window,
    cache_l: Optional[dict],
    cache_len,
    actx: Optional[AnalogCtx],
    paged: Optional[dict] = None,
    attn_backend: str = "stream",
) -> Tuple[jax.Array, Optional[dict], dict]:
    aux: Dict[str, jax.Array] = {}
    if cfg.rwkv:
        st = cache_l["rwkv"] if cache_l is not None else None
        h, new_t = ssm_mod.rwkv_time_mix(
            p_l["rwkv"], norm(x, p_l["norm1"], cfg.norm), cfg,
            state=st, decode=cache_len is not None and st is not None,
            ctx=actx, aux=aux,
        )
        x = x + h
        h, new_c = ssm_mod.rwkv_channel_mix(
            p_l["rwkv"], norm(x, p_l["norm2"], cfg.norm),
            state=st, decode=cache_len is not None and st is not None,
            ctx=actx, aux=aux,
        )
        x = x + h
        new_cache = {"rwkv": {**new_t, **new_c}}
        return x, new_cache, aux

    h, new_kv = attention_block(
        p_l["attn"], norm(x, p_l["norm1"], cfg.norm), cfg,
        positions=positions, window=window,
        cache=cache_l["attn"] if cache_l is not None else None,
        cache_len=cache_len, ctx=actx, aux=aux, paged=paged,
        attn_backend=attn_backend,
    )
    x = x + h
    h2_in = norm(x, p_l["norm2"], cfg.norm)
    if cfg.n_experts:
        h, _ = moe_block(p_l["moe"], h2_in, cfg, ctx=actx, aux=aux)
        if cfg.dense_residual:
            h = h + mlp_block(p_l["mlp"], h2_in, cfg.act, actx, aux)
    else:
        h = mlp_block(p_l["mlp"], h2_in, cfg.act, actx, aux)
    x = x + h
    return x, {"attn": new_kv}, aux


def _make_actx(pack: Optional[AnalogPack], sliced,
               band: int) -> Optional[AnalogCtx]:
    """Band-resolved per-layer context: only sites analog in this band
    are routed through the analog pipeline (the rest run digitally)."""
    if pack is None:
        return None
    w, lo, hi, act = sliced
    ss = pack.band_specs[band]
    names = ss.names
    return AnalogCtx(
        specs=ss,
        weights={n: w[n] for n in names if n in w},
        lo={n: lo[n] for n in names if n in lo},
        hi={n: hi[n] for n in names if n in hi},
        act={n: act[n] for n in names if n in act},
        collect=pack.collect,
    )


def _stitch_aux(auxes, bands):
    """Concatenate per-band aux stacks back to full (L, ...) stacks.

    Bands may differ in which sites are analog (digital bands emit no
    ``adc/``/``act/`` entries); absent entries are zero-filled so every
    key yields one full-length stack (the filler rows belong to layers
    that never consult them)."""
    keys: list = []
    for a in auxes:
        for k in a:
            if k not in keys:
                keys.append(k)
    out = {}
    for k in keys:
        proto = next(a[k] for a in auxes if k in a)
        parts = []
        for (lo_b, hi_b), a in zip(bands, auxes):
            parts.append(a[k] if k in a else jnp.zeros(
                (hi_b - lo_b,) + proto.shape[1:], proto.dtype))
        out[k] = jnp.concatenate(parts, axis=0)
    return out


def _scan_layers(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,
    *,
    positions,
    cache: Optional[dict],
    cache_len,
    pack: Optional[AnalogPack],
    remat: bool,
    paged: Optional[dict] = None,
    attn_backend: str = "stream",
):
    windows = layer_windows(cfg)
    xs = {"p": params["layers"]}
    if windows is not None:
        xs["w"] = windows
    if cache is not None:
        xs["c"] = cache
    if pack is not None:
        xs["a"] = (pack.layer_weights, pack.layer_lo, pack.layer_hi,
                   pack.layer_act)

    def band_scan(x, xs_band, band: int):
        def body(x, xs_l):
            actx = _make_actx(pack, xs_l.get("a"), band) \
                if pack is not None else None
            window = xs_l.get("w")
            x, new_cache, aux = _block(
                cfg, xs_l["p"], x,
                positions=positions, window=window,
                cache_l=xs_l.get("c"), cache_len=cache_len, actx=actx,
                paged=paged, attn_backend=attn_backend,
            )
            return x, {"cache": new_cache, "aux": aux}

        if remat:
            body = jax.checkpoint(body)
        return lax.scan(body, x, xs_band)

    bands = pack.bands if pack is not None else ((0, cfg.n_layers),)
    if len(bands) == 1:
        # uniform profile (or digital run): one scan, exactly the
        # pre-profile lowering — the bit-identity fast path.
        x, ys = band_scan(x, xs, 0)
        return x, ys["cache"], ys["aux"]

    caches, auxes = [], []
    for b, (lo_b, hi_b) in enumerate(bands):
        xs_band = jax.tree.map(lambda a: a[lo_b:hi_b], xs)
        x, ys = band_scan(x, xs_band, b)
        caches.append(ys["cache"])
        auxes.append(ys["aux"])
    cache_out = jax.tree.map(lambda *p: jnp.concatenate(p, axis=0), *caches)
    return x, cache_out, _stitch_aux(auxes, bands)


# ---------------------------------------------------------------------------
# public entry points
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,                 # (B, S) int32
    *,
    prefix_embeds: Optional[jax.Array] = None,   # (B, P, d) vlm stub
    pack: Optional[AnalogPack] = None,
    remat: Optional[bool] = None,
) -> Tuple[jax.Array, dict]:
    """Training/eval forward: returns (logits, aux)."""
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = _embed(cfg, cp, tokens, prefix_embeds, dtype)
    x = _maybe_seq_shard(x)
    positions = jnp.arange(tokens.shape[1])
    remat = cfg.remat if remat is None else remat
    x, _, aux = _scan_layers(
        cfg, cp, x, positions=positions, cache=None, cache_len=None,
        pack=pack, remat=remat,
    )
    if pack is not None and pack.collect:
        aux["final_hidden"] = norm(x, cp["final_norm"], cfg.norm)
    logits = _head(cfg, cp, x, pack)
    return logits, aux


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    if cfg.rwkv:
        st = ssm_mod.rwkv_state_init(cfg, batch, dtype)
        return {
            "layers": {"rwkv": jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (l,) + a.shape), st)},
            "len": jnp.zeros((), jnp.int32),
        }
    kv, hd = cfg.n_kv_heads, cfg.hd
    kvs = {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dtype),
    }
    return {"layers": {"attn": kvs}, "len": jnp.zeros((), jnp.int32)}


def prefill(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,
    max_len: int,
    *,
    prefix_embeds: Optional[jax.Array] = None,
    pack: Optional[AnalogPack] = None,
) -> Tuple[jax.Array, dict]:
    """Process a prompt, returning (last-token logits, cache)."""
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = _embed(cfg, cp, tokens, prefix_embeds, dtype)
    x = _maybe_seq_shard(x)
    positions = jnp.arange(s)
    x, new_cache, _ = _scan_layers(
        cfg, cp, x, positions=positions, cache=None, cache_len=None,
        pack=pack, remat=False,
    )
    logits = _head(cfg, cp, x[:, -1:], pack)
    if cfg.rwkv:
        cache = {"layers": new_cache, "len": jnp.asarray(s, jnp.int32)}
    else:
        kv = new_cache["attn"]
        pad = max_len - s
        kv = jax.tree.map(
            lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))),
            kv,
        )
        cache = {"layers": {"attn": kv}, "len": jnp.asarray(s, jnp.int32)}
    return logits, cache


def decode_step(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,                  # (B, 1)
    cache: dict,
    *,
    pack: Optional[AnalogPack] = None,
    attn_backend: str = "stream",
) -> Tuple[jax.Array, dict]:
    """One decode step with a KV/state cache.

    ``cache["len"]`` may be a scalar (all rows at the same fill — the
    ``greedy_decode`` path) or a per-row ``(B,)`` vector (continuous
    batching: every slot at its own fill, see ``repro.serve.runtime``).

    ``attn_backend="stream"`` runs the online-softmax lax.scan attention;
    ``"flash"`` the flash-decode Pallas kernel over the dense slot cache
    (``kernels.ops.flash_attention_decode``, no sliding-window support);
    ``"flash_oracle"`` its bitwise jnp mirror.
    """
    if attn_backend not in ("stream", "flash", "flash_oracle"):
        raise ValueError(f"unknown attn_backend {attn_backend!r}")
    if attn_backend != "stream":
        if cfg.rwkv:
            raise ValueError("attn_backend applies to attention caches "
                             "only; rwkv has no KV cache")
        if cfg.sliding_window is not None:
            raise ValueError("the flash-decode kernel has no sliding-"
                             "window mask; use attn_backend='stream'")
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = _embed(cfg, cp, token, None, dtype)
    t = cache["len"]
    positions = (t[:, None] if getattr(t, "ndim", 0) else t) \
        + jnp.arange(1)[None, :]
    x, new_cache, _ = _scan_layers(
        cfg, cp, x, positions=positions, cache=cache["layers"], cache_len=t,
        pack=pack, remat=False, attn_backend=attn_backend,
    )
    logits = _head(cfg, cp, x, pack)
    return logits, {"layers": new_cache, "len": t + 1}


def prefill_ragged(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,                 # (B, S_bucket) right-padded prompts
    *,
    true_lens: jax.Array,              # (B,) real prompt lengths
    prefix_embeds: Optional[jax.Array] = None,
    pack: Optional[AnalogPack] = None,
) -> Tuple[jax.Array, dict]:
    """Variable-length prefill for continuous batching.

    ``tokens`` is a right-padded prompt batch; ``true_lens`` gives each
    row's real length.  Returns per-row logits at position
    ``true_lens - 1`` (shape (B, 1, V)) and a cache whose ``len`` is the
    ``(B,)`` vector ``true_lens``.  Pad positions do hold K/V entries,
    but they sit at indices >= the row's fill: decode's ``kv_len`` mask
    never attends to them, and the slot's own decode tokens progressively
    overwrite them — so a padded row serves bit-identically to an
    unpadded one (causality: its last real token never sees the pads).
    """
    if cfg.rwkv:
        raise ValueError(
            "prefill_ragged does not support the rwkv family: the "
            "recurrent state folds right-pad tokens into every row; "
            "serve rwkv prompts at exact length via prefill() instead")
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = _embed(cfg, cp, tokens, prefix_embeds, dtype)
    positions = jnp.arange(s)
    x, new_cache, _ = _scan_layers(
        cfg, cp, x, positions=positions, cache=None, cache_len=None,
        pack=pack, remat=False,
    )
    true_lens = jnp.asarray(true_lens, jnp.int32)
    last = jnp.take_along_axis(x, (true_lens - 1)[:, None, None], axis=1)
    logits = _head(cfg, cp, last, pack)
    return logits, {"layers": new_cache, "len": true_lens}


def init_page_pool(cfg: ModelConfig, num_pages: int, page_size: int) -> dict:
    """Global paged KV pool: ``num_pages`` fixed-size pages per layer.

    Page 0 is the *sink* page by convention (``repro.serve.kvpool``
    never hands it out): rows without a live allocation scatter their
    decode K/V there, and no live row's block table ever references it,
    so its garbage is unreachable through any ``kv_len`` mask.
    """
    if cfg.rwkv:
        raise ValueError("paged KV applies to attention caches only; "
                         "rwkv state is O(1) per slot already")
    dtype = jnp.dtype(cfg.dtype)
    kv, hd = cfg.n_kv_heads, cfg.hd
    shape = (cfg.n_layers, num_pages, page_size, kv, hd)
    return {"attn": {"k": jnp.zeros(shape, dtype),
                     "v": jnp.zeros(shape, dtype)}}


def prefill_cached(
    cfg: ModelConfig,
    params: dict,
    tokens: jax.Array,                 # (B, S_bucket) right-padded suffixes
    *,
    true_lens: jax.Array,              # (B,) real suffix lengths
    ctx_lens: jax.Array,               # (B,) valid prefix length per row
    ctx_cache: dict,                   # {"k","v"}: (L, B, C, KV, hd)
    pack: Optional[AnalogPack] = None,
) -> Tuple[jax.Array, dict]:
    """Ragged prefill of prompt *suffixes* over per-row cached prefixes.

    The prefix-sharing path: each row already owns ``ctx_lens[b]`` valid
    KV positions (gathered from the page pool by the caller) and only
    the remaining suffix tokens run through the layers.  Every matmul
    still routes through the ``AnalogPack`` exactly as a cold prefill
    would — sharing skips recomputation, never the analog path.

    Returns per-row logits at suffix position ``true_lens - 1`` (shape
    (B, 1, V)) and a cache whose K/V hold the context in ``[0, C)`` plus
    the suffix scattered at ``ctx_lens + [0, S)``; ``len`` is the total
    fill ``ctx_lens + true_lens``.  Positions beyond a row's fill are
    garbage exactly like ``prefill_ragged`` pads — unreachable through
    the decode ``kv_len`` mask.
    """
    if cfg.rwkv:
        raise ValueError("prefill_cached does not support the rwkv family")
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = _embed(cfg, cp, tokens, None, dtype)
    ctx_lens = jnp.asarray(ctx_lens, jnp.int32)
    positions = ctx_lens[:, None] + jnp.arange(s)[None, :]
    # seq capacity C + S so every row's scatter at ctx_lens + [0, S)
    # stays in bounds (out-of-bounds scatter would clamp-corrupt)
    padded = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, s), (0, 0), (0, 0))),
        ctx_cache)
    x, new_cache, _ = _scan_layers(
        cfg, cp, x, positions=positions, cache={"attn": padded},
        cache_len=ctx_lens, pack=pack, remat=False,
    )
    true_lens = jnp.asarray(true_lens, jnp.int32)
    last = jnp.take_along_axis(x, (true_lens - 1)[:, None, None], axis=1)
    logits = _head(cfg, cp, last, pack)
    return logits, {"layers": new_cache, "len": ctx_lens + true_lens}


def decode_step_paged(
    cfg: ModelConfig,
    params: dict,
    token: jax.Array,                  # (B, 1)
    cache: dict,
    *,
    pack: Optional[AnalogPack] = None,
    backend: str = "gather",
) -> Tuple[jax.Array, dict]:
    """One decode step over the paged KV pool.

    ``cache`` is ``{"pool": init_page_pool(...), "ptab": (B, NP) int32,
    "len": (B,) int32}`` — the block table and lengths are *traced*
    data (the allocator changes them every step), the pool geometry is
    static, so the step compiles once.  ``backend="gather"`` runs the
    jnp gathered view through the same ``streaming_attention`` as the
    dense-slot decode (the bit-exactness oracle); ``"pallas"`` runs the
    in-kernel-gather flash-decode kernel (``kernels.ops.paged_attention``,
    no sliding-window support).
    """
    if backend not in ("gather", "pallas"):
        raise ValueError(f"unknown paged backend {backend!r}")
    if backend == "pallas" and cfg.sliding_window is not None:
        raise ValueError("the pallas paged-attention kernel has no "
                         "sliding-window mask; use backend='gather'")
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = _embed(cfg, cp, token, None, dtype)
    t = jnp.asarray(cache["len"], jnp.int32)
    positions = t[:, None] + jnp.arange(1)[None, :]
    page_size = cache["pool"]["attn"]["k"].shape[2]
    x, new_pool, _ = _scan_layers(
        cfg, cp, x, positions=positions, cache=cache["pool"], cache_len=t,
        pack=pack, remat=False,
        paged={"ptab": cache["ptab"], "page_size": page_size,
               "backend": backend},
    )
    logits = _head(cfg, cp, x, pack)
    return logits, {"pool": new_pool, "ptab": cache["ptab"], "len": t + 1}


def cache_slot_insert(slot_cache: dict, new_cache: dict,
                      slots: jax.Array) -> dict:
    """Insert freshly-prefilled request rows into a running slot cache.

    Both caches are ``{"layers": ..., "len": ...}`` dicts; slot leaves
    are ``(L, max_slots, S_max, ...)``, new leaves ``(L, G, s, ...)``
    with ``s <= S_max`` (the seq axis is zero-padded up to the slot
    shape).  ``slots`` (G,) names the destination slot per row;
    out-of-range ids are dropped, which is how the runtime pads
    admission groups to fixed compile shapes (dummy rows get
    ``slots == max_slots``).
    """
    def insert(dst, src):
        src = src.astype(dst.dtype)
        pad = [(0, 0)] * src.ndim
        for ax in range(2, src.ndim):
            pad[ax] = (0, dst.shape[ax] - src.shape[ax])
        if any(p != (0, 0) for p in pad):
            src = jnp.pad(src, pad)
        return dst.at[:, slots].set(src, mode="drop")

    layers = jax.tree.map(insert, slot_cache["layers"], new_cache["layers"])
    length = slot_cache["len"].at[slots].set(
        jnp.asarray(new_cache["len"], slot_cache["len"].dtype), mode="drop")
    return {"layers": layers, "len": length}


def cache_slot_evict(slot_cache: dict, slots: jax.Array) -> dict:
    """Zero freed slot rows (hygiene only — the runtime's per-slot
    ``kv_len`` masking already makes evicted data unreachable)."""
    layers = jax.tree.map(
        lambda dst: dst.at[:, slots].set(
            jnp.zeros((), dst.dtype), mode="drop"),
        slot_cache["layers"])
    length = slot_cache["len"].at[slots].set(0, mode="drop")
    return {"layers": layers, "len": length}


def greedy_decode(
    cfg: ModelConfig,
    params: dict,
    prompts: jax.Array,                # (B, S) int32
    n_new: int,
    *,
    prefix_embeds: Optional[jax.Array] = None,
    pack: Optional[AnalogPack] = None,
) -> jax.Array:
    """Batched greedy generation: one prefill, then scanned decode steps.

    The decode loop is a ``lax.scan`` over :func:`decode_step` (cache as
    carry), so the whole multi-request serving path — analog pack
    included — lowers to a single compiled program.  Returns the
    (B, n_new) generated tokens.
    """
    if n_new < 1:
        raise ValueError(f"greedy_decode needs n_new >= 1, got {n_new}")
    b, s = prompts.shape
    # the first generated token comes from the prefill logits, so only
    # n_new - 1 decode steps (and cache slots) are needed
    logits, cache = prefill(cfg, params, prompts, s + n_new - 1,
                            prefix_embeds=prefix_embeds, pack=pack)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)   # (B,)

    def body(carry, _):
        t, c = carry
        lg, c = decode_step(cfg, params, t[:, None], c, pack=pack)
        nxt = jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return (nxt, c), nxt

    _, toks = lax.scan(body, (tok, cache), None, length=n_new - 1)
    return jnp.concatenate([tok[:, None], jnp.swapaxes(toks, 0, 1)], axis=1)


# ---------------------------------------------------------------------------


def _maybe_seq_shard(x):
    from repro.sharding.perf import FLAGS, constrain_bs

    if FLAGS.seq_parallel_attn and x.shape[1] > 1:
        return constrain_bs(x, seq=True)
    return x


def _embed(cfg, cp, tokens, prefix_embeds, dtype):
    x = cp["embed"][tokens].astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    if prefix_embeds is not None:
        p = prefix_embeds.shape[1]
        x = lax.dynamic_update_slice(x, prefix_embeds.astype(dtype), (0, 0, 0))
        del p
    return x


def _head(cfg, cp, x, pack: Optional[AnalogPack]):
    x = norm(x, cp["final_norm"], cfg.norm)
    w = cp["embed"].T if cfg.tie_embeddings else cp["lm_head"]
    if pack is not None and pack.head is not None and not pack.collect:
        y = analog_matmul(x, pack.head, pack.head_spec, adc_lo=pack.head_lo,
                          adc_hi=pack.head_hi, act_hi=pack.head_act)
        return y.astype(jnp.float32)
    return (x @ w).astype(jnp.float32)
