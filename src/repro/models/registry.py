"""Model registry: family -> (init, forward, prefill, decode, init_cache)."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.config import ModelConfig
from repro.models import encdec, hybrid, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    forward: Callable          # (cfg, params, tokens, **kw) -> (logits, aux)
    prefill: Callable          # (cfg, params, tokens, max_len, **kw)
    decode_step: Callable      # (cfg, params, token, cache, **kw)
    init_cache: Callable       # (cfg, batch, max_len)
    # batched greedy serving loop: (cfg, params, prompts, n_new, **kw)
    # -> (B, n_new) tokens; None for families without one (encoder-decoder
    # needs per-utterance encoder state, see repro.models.encdec)
    decode_loop: Optional[Callable] = None
    # continuous-batching support (repro.serve.runtime): variable-length
    # right-padded prefill + slot-wise cache insert/evict; None for
    # families without them
    prefill_ragged: Optional[Callable] = None
    cache_slot_insert: Optional[Callable] = None
    cache_slot_evict: Optional[Callable] = None
    # paged-KV serving (repro.serve.paged): global page pool, ragged
    # suffix prefill over shared prefixes, block-table decode; None for
    # families without a paged cache layout
    init_page_pool: Optional[Callable] = None
    prefill_cached: Optional[Callable] = None
    decode_step_paged: Optional[Callable] = None


_TRANSFORMER = ModelApi(
    init_params=transformer.init_params,
    forward=transformer.forward,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    init_cache=transformer.init_cache,
    decode_loop=transformer.greedy_decode,
    prefill_ragged=transformer.prefill_ragged,
    cache_slot_insert=transformer.cache_slot_insert,
    cache_slot_evict=transformer.cache_slot_evict,
    init_page_pool=transformer.init_page_pool,
    prefill_cached=transformer.prefill_cached,
    decode_step_paged=transformer.decode_step_paged,
)

_HYBRID = ModelApi(
    init_params=hybrid.init_params,
    forward=hybrid.forward,
    prefill=hybrid.prefill,
    decode_step=hybrid.decode_step,
    init_cache=hybrid.init_cache,
)

_ENCDEC = ModelApi(
    init_params=encdec.init_params,
    forward=encdec.forward,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    init_cache=encdec.init_cache,
)


# dense / moe / vlm / ssm(rwkv) all run on the unified transformer
_BY_FAMILY = {
    "audio": _ENCDEC,
    "hybrid": _HYBRID,
    "dense": _TRANSFORMER,
    "moe": _TRANSFORMER,
    "vlm": _TRANSFORMER,
    "ssm": _TRANSFORMER,
}


def families_with(attr: str) -> tuple:
    """Families whose ModelApi provides ``attr`` — derived from the
    registry so user-facing error messages can't drift from it."""
    return tuple(sorted(f for f, api in _BY_FAMILY.items()
                        if getattr(api, attr) is not None))


def decode_loop_families() -> tuple:
    """Families with the batched serving decode loop (repro.serve)."""
    return families_with("decode_loop")


def get_model(cfg: ModelConfig) -> ModelApi:
    return _BY_FAMILY.get(cfg.family, _TRANSFORMER)
