"""Model registry: family -> (init, forward, prefill, decode, init_cache)."""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.config import ModelConfig
from repro.models import encdec, hybrid, transformer


@dataclasses.dataclass(frozen=True)
class ModelApi:
    init_params: Callable
    forward: Callable          # (cfg, params, tokens, **kw) -> (logits, aux)
    prefill: Callable          # (cfg, params, tokens, max_len, **kw)
    decode_step: Callable      # (cfg, params, token, cache, **kw)
    init_cache: Callable       # (cfg, batch, max_len)
    # batched greedy serving loop: (cfg, params, prompts, n_new, **kw)
    # -> (B, n_new) tokens; None for families without one (encoder-decoder
    # needs per-utterance encoder state, see repro.models.encdec)
    decode_loop: Optional[Callable] = None


_TRANSFORMER = ModelApi(
    init_params=transformer.init_params,
    forward=transformer.forward,
    prefill=transformer.prefill,
    decode_step=transformer.decode_step,
    init_cache=transformer.init_cache,
    decode_loop=transformer.greedy_decode,
)

_HYBRID = ModelApi(
    init_params=hybrid.init_params,
    forward=hybrid.forward,
    prefill=hybrid.prefill,
    decode_step=hybrid.decode_step,
    init_cache=hybrid.init_cache,
)

_ENCDEC = ModelApi(
    init_params=encdec.init_params,
    forward=encdec.forward,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    init_cache=encdec.init_cache,
)


def get_model(cfg: ModelConfig) -> ModelApi:
    if cfg.family == "audio":
        return _ENCDEC
    if cfg.family == "hybrid":
        return _HYBRID
    # dense / moe / vlm / ssm(rwkv) all run on the unified transformer
    return _TRANSFORMER
