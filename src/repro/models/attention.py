"""Attention block: GQA/MQA, RoPE, optional QKV bias / per-head qk-norm /
sliding window, prefill + decode cache paths."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import AnalogCtx, dense, rms_norm, rope, streaming_attention


def init_attention(key: jax.Array, cfg: ModelConfig, n_layers: int,
                   dtype) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    sc = d ** -0.5
    p = {
        "wq": jax.random.normal(ks[0], (n_layers, d, h * hd), dtype) * sc,
        "wk": jax.random.normal(ks[1], (n_layers, d, kv * hd), dtype) * sc,
        "wv": jax.random.normal(ks[2], (n_layers, d, kv * hd), dtype) * sc,
        "wo": jax.random.normal(ks[3], (n_layers, h * hd, d), dtype)
        * (h * hd) ** -0.5,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n_layers, h * hd), dtype)
        p["bk"] = jnp.zeros((n_layers, kv * hd), dtype)
        p["bv"] = jnp.zeros((n_layers, kv * hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((n_layers, hd), dtype)
        p["k_norm"] = jnp.zeros((n_layers, hd), dtype)
    return p


def attention_block(
    p: dict,                      # per-layer slice (no leading L axis)
    x: jax.Array,                 # (B, S, d)
    cfg: ModelConfig,
    *,
    positions: jax.Array,         # (S,) or (B, S) absolute positions
    window,                       # scalar (possibly traced): huge = global
    cache: Optional[dict] = None,  # {"k","v"}: (B, S_max, KV, hd)
    cache_len=None,               # dynamic current cache fill
    causal: bool = True,
    ctx: Optional[AnalogCtx] = None,
    aux: Optional[dict] = None,
    paged: Optional[dict] = None,  # {"ptab", "page_size", "backend"}
    attn_backend: str = "stream",  # dense decode: stream | flash | flash_oracle
) -> Tuple[jax.Array, Optional[dict]]:
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    from repro.sharding.perf import FLAGS, constrain_bs

    seq_par = FLAGS.seq_parallel_attn and cache is None and s > 1

    q = dense(x, p["wq"], "wq", ctx, aux, bias=p.get("bq"))
    k = dense(x, p["wk"], "wk", ctx, aux, bias=p.get("bk"))
    v = dense(x, p["wv"], "wv", ctx, aux, bias=p.get("bv"))
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, kv, hd)
    v = v.reshape(b, s, kv, hd)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)

    if paged is not None:
        # paged decode: the cache is a global page pool (P, page, KV, hd)
        # and each row's page list is a block table.  The fresh token is
        # scattered into the row's current page; rows whose table entry
        # is unallocated (conventionally 0) write into the sink page,
        # whose contents are never reachable through any live row's
        # ``kv_len`` mask.
        if s != 1:
            raise ValueError("paged attention is a decode path (S == 1); "
                             "prefill goes through the dense cached path")
        ps_ = paged["page_size"]
        ptab = paged["ptab"]                              # (B, NP) int32
        pos = jnp.asarray(cache_len, jnp.int32)           # (B,)
        idx = jnp.clip(pos // ps_, 0, ptab.shape[1] - 1)
        pid = jnp.take_along_axis(ptab, idx[:, None], axis=1)[:, 0]
        off = pos % ps_
        pk = cache["k"].at[pid, off].set(k[:, 0])
        pv = cache["v"].at[pid, off].set(v[:, 0])
        if paged.get("backend", "gather") == "pallas":
            from repro.kernels.ops import paged_attention

            out = paged_attention(q[:, 0], pk, pv, ptab, pos + 1)[:, None]
        else:
            # jnp gather oracle: materialize the row-ordered view and run
            # the exact same streaming attention as the dense-slot decode
            # — with NP*page == max_len the two lower to the same program,
            # which is what pins the paged runtime bit-exact.
            np_ = ptab.shape[1]
            gk = pk[ptab].reshape(b, np_ * ps_, kv, hd)
            gv = pv[ptab].reshape(b, np_ * ps_, kv, hd)
            out = streaming_attention(
                q, gk, gv, q_offset=pos, causal=causal, window=window,
                kv_len=pos + 1,
            )
        out = out.reshape(b, s, h * hd)
        return dense(out, p["wo"], "wo", ctx, aux), {"k": pk, "v": pv}

    if cache is None:
        if seq_par:
            # context parallelism: queries stay sequence-sharded; K/V are
            # gathered over the model axis (cheap: kv_heads*hd << d).
            q = constrain_bs(q, seq=True)
            k = constrain_bs(k, seq=False)
            v = constrain_bs(v, seq=False)
        out = streaming_attention(
            q, k, v, q_offset=0, causal=causal, window=window,
        )
        if seq_par:
            out = constrain_bs(out, seq=True)
        new_cache = {"k": k, "v": v}
    else:
        # decode: insert the new token(s) at cache_len, attend over the cache
        if getattr(cache_len, "ndim", 0):
            # per-slot fills (continuous batching): each row writes its
            # token(s) at its own offset via a batched scatter
            rows = jnp.arange(b)[:, None]
            pos = cache_len[:, None] + jnp.arange(s)[None, :]
            ck = cache["k"].at[rows, pos].set(k)
            cv = cache["v"].at[rows, pos].set(v)
        else:
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k,
                                                     cache_len, 1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v,
                                                     cache_len, 1)
        if attn_backend != "stream":
            # flash-decode Pallas kernel over the dense per-slot cache
            # (the dense sibling of the paged in-kernel-gather path).
            # Like that kernel it has no sliding-window mask; the caller
            # (decode_step) rejects windowed configs up front.
            if s != 1:
                raise ValueError("flash attention is a decode path "
                                 "(S == 1); prefill uses streaming")
            from repro.kernels.ops import flash_attention_decode

            fills = jnp.broadcast_to(
                jnp.asarray(cache_len + s, jnp.int32), (b,))
            be = "oracle" if attn_backend == "flash_oracle" else "kernel"
            out = flash_attention_decode(
                q[:, 0], ck, cv, fills, backend=be)[:, None]
        else:
            out = streaming_attention(
                q, ck, cv, q_offset=cache_len, causal=causal, window=window,
                kv_len=cache_len + s,
            )
        new_cache = {"k": ck, "v": cv}

    out = out.reshape(b, s, h * hd)
    return dense(out, p["wo"], "wo", ctx, aux), new_cache


def cross_attention_block(
    p: dict,
    x: jax.Array,                 # (B, S, d) decoder stream
    enc_kv: Tuple[jax.Array, jax.Array],   # precomputed (B, Senc, KV, hd) x2
    cfg: ModelConfig,
    *,
    ctx: Optional[AnalogCtx] = None,
    aux: Optional[dict] = None,
) -> jax.Array:
    """Whisper-style cross attention against cached encoder K/V."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.hd
    q = dense(x, p["wq"], "xattn_wq", ctx, aux).reshape(b, s, h, hd)
    k, v = enc_kv
    out = streaming_attention(q, k, v, q_offset=0, causal=False, window=None)
    return dense(out.reshape(b, s, h * hd), p["wo"], "xattn_wo", ctx, aux)


def encode_cross_kv(p: dict, enc_out: jax.Array, cfg: ModelConfig) -> Tuple:
    """Project encoder output once into cross-attention K/V."""
    b, se, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(b, se, kv, hd)
    v = (enc_out @ p["wv"]).reshape(b, se, kv, hd)
    return k, v
