"""Zamba2-style hybrid: a Mamba2 backbone with a *shared* attention+MLP
block applied every ``attn_every`` layers (weight sharing across all
applications — the Zamba signature).

Scan layout: the Mamba layers are scanned with a per-layer ``apply_attn``
flag; the shared block's parameters ride along as closure constants.  Each
application has its own KV cache (activations differ per application),
carried through the scan as an (n_apps, ...) stack and updated in place at
``app_idx`` — so cache memory is n_apps x, not n_layers x.  ``lax.cond``
skips the attention compute on non-flagged layers.

Prefill is the cache-ful path with ``cache_len = 0`` (multi-token insert);
decode is the same path with one token.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.attention import attention_block, init_attention
from repro.models.layers import norm
from repro.models.mlp import init_mlp, mlp_block
from repro.models.transformer import _head, _norm_init, cast_params


def attn_positions(cfg: ModelConfig):
    period = cfg.attn_every
    return [i for i in range(cfg.n_layers) if i % period == period - 1]


def n_attn_apps(cfg: ModelConfig) -> int:
    return len(attn_positions(cfg))


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 6)
    d, l, v = cfg.d_model, cfg.n_layers, cfg.vocab
    dt = jnp.float32
    shared = {
        "attn": jax.tree.map(lambda a: a[0], init_attention(ks[1], cfg, 1, dt)),
        "mlp": jax.tree.map(lambda a: a[0],
                            init_mlp(ks[2], d, cfg.d_ff, cfg.act, 1, dt)),
        "norm1": jax.tree.map(lambda a: a[0], _norm_init(cfg, 1, dt)),
        "norm2": jax.tree.map(lambda a: a[0], _norm_init(cfg, 1, dt)),
    }
    return {
        "embed": jax.random.normal(ks[0], (v, d), dt) * d ** -0.5,
        "final_norm": {"scale": jnp.zeros((d,), dt)},
        "lm_head": jax.random.normal(ks[3], (d, v), dt) * d ** -0.5,
        "layers": {
            "mamba": ssm_mod.init_mamba(ks[4], cfg, l, dt),
            "norm": _norm_init(cfg, l, dt),
        },
        "shared": shared,
    }


def _shared_attn(cfg, sp, x, *, positions, kv_cache, cache_len):
    h, new_kv = attention_block(
        sp["attn"], norm(x, sp["norm1"], cfg.norm), cfg,
        positions=positions, window=None, cache=kv_cache,
        cache_len=cache_len,
    )
    x = x + h
    x = x + mlp_block(sp["mlp"], norm(x, sp["norm2"], cfg.norm), cfg.act)
    return x, new_kv


def _scan(cfg, cp, x, *, positions, state, kv_caches, cache_len, remat):
    l = cfg.n_layers
    flags = jnp.array(
        [1 if i % cfg.attn_every == cfg.attn_every - 1 else 0
         for i in range(l)], jnp.int32)
    app_idx = jnp.cumsum(flags) - flags
    sp = cp["shared"]
    decode = x.shape[1] == 1 and cache_len is not None

    xs = {"p": cp["layers"], "flag": flags, "app": app_idx}
    if state is not None:
        xs["s"] = state

    def body(carry, xs_l):
        x, kvs = carry

        if kvs is None:
            # training path: no cache anywhere
            def t_fn(x):
                return _shared_attn(cfg, sp, x, positions=positions,
                                    kv_cache=None, cache_len=None)[0]

            x = lax.cond(xs_l["flag"] == 1, t_fn, lambda x: x, x)
        else:
            kv_l = jax.tree.map(lambda a: a[xs_l["app"]], kvs)

            def t_fn(args):
                x, kv_l = args
                return _shared_attn(cfg, sp, x, positions=positions,
                                    kv_cache=kv_l, cache_len=cache_len)

            def f_fn(args):
                x, kv_l = args
                return x, kv_l

            x, kv_new = lax.cond(xs_l["flag"] == 1, t_fn, f_fn, (x, kv_l))
            kvs = jax.tree.map(
                lambda all_, new: lax.dynamic_update_index_in_dim(
                    all_, new, xs_l["app"], 0),
                kvs, kv_new)

        h, new_state = ssm_mod.mamba_block(
            xs_l["p"]["mamba"], norm(x, xs_l["p"]["norm"], cfg.norm), cfg,
            state=xs_l.get("s"), decode=decode,
        )
        x = x + h
        return (x, kvs), {"state": new_state}

    if remat:
        body = jax.checkpoint(body)
    (x, kvs), ys = lax.scan(body, (x, kv_caches), xs)
    return x, ys["state"], kvs


def forward(cfg: ModelConfig, params: dict, tokens, *, pack=None,
            remat: Optional[bool] = None, prefix_embeds=None):
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = cp["embed"][tokens].astype(dtype)
    positions = jnp.arange(tokens.shape[1])
    remat = cfg.remat if remat is None else remat
    x, _, _ = _scan(cfg, cp, x, positions=positions, state=None,
                    kv_caches=None, cache_len=None, remat=remat)
    return _head(cfg, cp, x, None), {}


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    l = cfg.n_layers
    apps = n_attn_apps(cfg)
    st = ssm_mod.mamba_state_init(cfg, batch, dtype)
    return {
        "state": jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (l,) + a.shape).copy(), st),
        "kv": {
            "k": jnp.zeros((apps, batch, max_len, cfg.n_kv_heads, cfg.hd),
                           dtype),
            "v": jnp.zeros((apps, batch, max_len, cfg.n_kv_heads, cfg.hd),
                           dtype),
        },
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: dict, tokens, max_len: int,
            *, pack=None, prefix_embeds=None):
    b, s = tokens.shape
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    cache = init_cache(cfg, b, max_len)
    x = cp["embed"][tokens].astype(dtype)
    positions = jnp.arange(s)
    x, states, kvs = _scan(cfg, cp, x, positions=positions,
                           state=cache["state"], kv_caches=cache["kv"],
                           cache_len=jnp.zeros((), jnp.int32), remat=False)
    logits = _head(cfg, cp, x[:, -1:], None)
    return logits, {"state": states, "kv": kvs,
                    "len": jnp.asarray(s, jnp.int32)}


def decode_step(cfg: ModelConfig, params: dict, token, cache, *, pack=None):
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    x = cp["embed"][token].astype(dtype)
    t = cache["len"]
    positions = t + jnp.arange(1)[None, :]
    x, states, kvs = _scan(cfg, cp, x, positions=positions,
                           state=cache["state"], kv_caches=cache["kv"],
                           cache_len=t, remat=False)
    logits = _head(cfg, cp, x, None)
    return logits, {"state": states, "kv": kvs, "len": t + 1}
