"""Whisper-style encoder-decoder (audio family).

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, n_frames, d).  The encoder is a
bidirectional pre-LN transformer with sinusoidal positions; the decoder is
causal self-attention + cross-attention against the (once-projected)
encoder K/V.  Decode shapes cache decoder self-attention KV plus the fixed
cross-attention K/V.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.attention import (
    attention_block,
    cross_attention_block,
    encode_cross_kv,
    init_attention,
)
from repro.models.layers import norm
from repro.models.mlp import init_mlp, mlp_block
from repro.models.transformer import _norm_init, cast_params


def _sinusoid(n: int, d: int) -> jax.Array:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    ks = jax.random.split(key, 10)
    d, v = cfg.d_model, cfg.vocab
    le, ld = cfg.n_enc_layers, cfg.n_layers
    dt = jnp.float32
    enc = {
        "attn": init_attention(ks[0], cfg, le, dt),
        "mlp": init_mlp(ks[1], d, cfg.d_ff, cfg.act, le, dt),
        "norm1": _norm_init(cfg, le, dt),
        "norm2": _norm_init(cfg, le, dt),
    }
    dec = {
        "attn": init_attention(ks[2], cfg, ld, dt),
        "xattn": init_attention(ks[3], cfg, ld, dt),
        "mlp": init_mlp(ks[4], d, cfg.d_ff, cfg.act, ld, dt),
        "norm1": _norm_init(cfg, ld, dt),
        "normx": _norm_init(cfg, ld, dt),
        "norm2": _norm_init(cfg, ld, dt),
    }
    fn = (
        {"scale": jnp.ones((d,), dt), "bias": jnp.zeros((d,), dt)}
        if cfg.norm == "layernorm"
        else {"scale": jnp.zeros((d,), dt)}
    )
    return {
        "embed": jax.random.normal(ks[5], (v, d), dt) * d ** -0.5,
        "enc_in": jax.random.normal(ks[6], (d, d), dt) * d ** -0.5,
        "encoder": enc,
        "decoder": dec,
        "enc_final_norm": dict(fn),
        "final_norm": dict(fn),
    }


def encode(cfg: ModelConfig, cp: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, n_frames, d) stub embeddings -> encoder states."""
    b, s, d = frames.shape
    x = frames @ cp["enc_in"] + _sinusoid(s, d)[None].astype(frames.dtype)
    positions = jnp.arange(s)

    def body(x, p_l):
        h, _ = attention_block(
            p_l["attn"], norm(x, p_l["norm1"], cfg.norm), cfg,
            positions=positions, window=None, causal=False,
        )
        x = x + h
        x = x + mlp_block(p_l["mlp"], norm(x, p_l["norm2"], cfg.norm), cfg.act)
        return x, None

    x, _ = lax.scan(body, x, cp["encoder"])
    return norm(x, cp["enc_final_norm"], cfg.norm)


def _decoder_scan(cfg, cp, x, *, positions, cross_kv, cache, cache_len):
    xs = {"p": cp["decoder"], "ckv": cross_kv}
    if cache is not None:
        xs["c"] = cache

    def body(x, xs_l):
        p_l = xs_l["p"]
        h, new_kv = attention_block(
            p_l["attn"], norm(x, p_l["norm1"], cfg.norm), cfg,
            positions=positions, window=None,
            cache=xs_l.get("c"), cache_len=cache_len,
        )
        x = x + h
        x = x + cross_attention_block(
            p_l["xattn"], norm(x, p_l["normx"], cfg.norm), xs_l["ckv"], cfg)
        x = x + mlp_block(p_l["mlp"], norm(x, p_l["norm2"], cfg.norm), cfg.act)
        return x, {"kv": new_kv}

    x, ys = lax.scan(body, x, xs)
    return x, ys["kv"]


def forward(cfg: ModelConfig, params: dict, tokens, *, frames=None,
            pack=None, remat: Optional[bool] = None, prefix_embeds=None):
    """Teacher-forced training forward.  ``frames`` defaults to
    ``prefix_embeds`` (the generic frontend-stub argument)."""
    frames = frames if frames is not None else prefix_embeds
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    enc = encode(cfg, cp, frames.astype(dtype))
    cross_kv = _stack_cross_kv(cfg, cp, enc)
    b, s = tokens.shape
    x = cp["embed"][tokens].astype(dtype) + _sinusoid(
        s, cfg.d_model)[None].astype(dtype)
    x, _ = _decoder_scan(cfg, cp, x, positions=jnp.arange(s),
                         cross_kv=cross_kv, cache=None, cache_len=None)
    x = norm(x, cp["final_norm"], cfg.norm)
    logits = (x @ cp["embed"].T).astype(jnp.float32)
    return logits, {}


def _stack_cross_kv(cfg, cp, enc):
    def per_layer(p_l):
        return encode_cross_kv(p_l, enc, cfg)

    return jax.vmap(per_layer)(
        {"wk": cp["decoder"]["xattn"]["wk"], "wv": cp["decoder"]["xattn"]["wv"]}
    )


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    l, kv, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
    return {
        "k": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((l, batch, max_len, kv, hd), dtype),
        "ckv": (
            jnp.zeros((l, batch, cfg.cross_kv_len, kv, hd), dtype),
            jnp.zeros((l, batch, cfg.cross_kv_len, kv, hd), dtype),
        ),
        "len": jnp.zeros((), jnp.int32),
    }


def prefill(cfg: ModelConfig, params: dict, tokens, max_len: int,
            *, frames=None, pack=None, prefix_embeds=None):
    frames = frames if frames is not None else prefix_embeds
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    enc = encode(cfg, cp, frames.astype(dtype))
    cross_kv = _stack_cross_kv(cfg, cp, enc)
    b, s = tokens.shape
    x = cp["embed"][tokens].astype(dtype) + _sinusoid(
        s, cfg.d_model)[None].astype(dtype)
    x, kv = _decoder_scan(cfg, cp, x, positions=jnp.arange(s),
                          cross_kv=cross_kv, cache=None, cache_len=None)
    x = norm(x, cp["final_norm"], cfg.norm)
    logits = (x[:, -1:] @ cp["embed"].T).astype(jnp.float32)
    pad = max_len - s
    kv = jax.tree.map(
        lambda a: jnp.pad(a, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))), kv)
    return logits, {"k": kv["k"], "v": kv["v"], "ckv": cross_kv,
                    "len": jnp.asarray(s, jnp.int32)}


def decode_step(cfg: ModelConfig, params: dict, token, cache, *, pack=None):
    dtype = jnp.dtype(cfg.dtype)
    cp = cast_params(params, dtype)
    t = cache["len"]
    x = cp["embed"][token].astype(dtype)
    x = x + jax.lax.dynamic_slice_in_dim(
        _sinusoid(1 << 16, cfg.d_model).astype(dtype), t, 1, 0)[None]
    layer_cache = {"k": cache["k"], "v": cache["v"]}
    xs_cache = layer_cache
    x, kv = _decoder_scan(cfg, cp, x, positions=t + jnp.arange(1)[None, :],
                          cross_kv=cache["ckv"], cache=xs_cache, cache_len=t)
    x = norm(x, cp["final_norm"], cfg.norm)
    logits = (x @ cp["embed"].T).astype(jnp.float32)
    return logits, {"k": kv["k"], "v": kv["v"], "ckv": cache["ckv"],
                    "len": t + 1}
