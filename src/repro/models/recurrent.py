"""Linear-recurrence substrate for SSM / RWKV architectures.

The shared primitive is the gated-decay state recurrence

    S_t = diag(w_t) S_{t-1} + k_t v_t^T          (state: (dk, dv) per head)
    y_t = r_t @ S_{t-1} + (r_t * u) . k_t * v_t  (rwkv: current-token bonus)
    y_t = r_t @ S_t                              (mamba: current included)

computed in *chunks*: within a chunk, pairwise decay factors are evaluated
in log space with non-positive exponents (numerically safe regardless of
decay rate); across chunks a ``lax.scan`` carries the state.  This is the
TPU-friendly formulation: each chunk is a handful of einsums (MXU) instead
of a length-S sequential loop.

Both RWKV6's per-channel data-dependent decay (w_t: (B,S,H,dk)) and
Mamba2's per-head scalar decay (broadcast over dk) use the same code path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def chunked_decay_recurrence(
    r: jax.Array,               # (B, S, H, dk)
    k: jax.Array,               # (B, S, H, dk)
    v: jax.Array,               # (B, S, H, dv)
    log_w: jax.Array,           # (B, S, H, dk) log-decay, <= 0
    *,
    u: Optional[jax.Array] = None,   # (H, dk) rwkv bonus; None => mamba mode
    s0: Optional[jax.Array] = None,  # (B, H, dk, dv) initial state
    chunk: int = 32,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,dv), final_state: (B,H,dk,dv))."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    include_current = u is None

    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        zf = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (s + pad) // chunk

    rc = r.reshape(b, n_chunks, chunk, h, dk).swapaxes(0, 1).astype(jnp.float32)
    kc = k.reshape(b, n_chunks, chunk, h, dk).swapaxes(0, 1).astype(jnp.float32)
    vc = v.reshape(b, n_chunks, chunk, h, dv).swapaxes(0, 1).astype(jnp.float32)
    lwc = log_w.reshape(b, n_chunks, chunk, h, dk).swapaxes(0, 1)
    lwc = lwc.astype(jnp.float32)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool), -1)   # strict lower

    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)

    def body(state, inp):
        rj, kj, vj, lwj = inp                       # (B, C, H, dk/dv)
        le = jnp.cumsum(lwj, axis=1)                # inclusive cum log-decay
        le_prev = le - lwj                          # exclusive
        le_q = le if include_current else le_prev   # decay ref for queries

        # pairwise intra-chunk decay: W_t(ref)/W_s = exp(le_q_t - le_s),
        # argument <= 0 for s <= t since le is non-increasing.
        diff = le_q[:, :, None, :, :] - le[:, None, :, :, :]   # (B,Ct,Cs,H,dk)
        decay = jnp.exp(jnp.minimum(diff, 0.0))
        a = jnp.einsum("bthd,bshd,btshd->bhts", rj, kj, decay)
        if include_current:
            mask = tri | jnp.eye(chunk, dtype=bool)
        else:
            mask = tri
        a = a * mask[None, None]
        y = jnp.einsum("bhts,bshv->bthv", a, vj)

        if u is not None:  # rwkv current-token bonus
            y = y + jnp.einsum("bthd,hd,bthd,bthv->bthv", rj, u.astype(jnp.float32), kj, vj)

        # carry-in contribution: r_t decayed to chunk start
        rq = rj * jnp.exp(le_q)
        y = y + jnp.einsum("bthd,bhdv->bthv", rq, state)

        # state update to chunk end
        le_end = le[:, -1:, :, :]                   # (B,1,H,dk)
        k_dec = kj * jnp.exp(le[:, -1:, :, :] - le) # wait: see note below
        new_state = state * jnp.exp(le_end[:, 0, :, :, None]) + jnp.einsum(
            "bshd,bshv->bhdv", k_dec, vj
        )
        return new_state, y

    # NOTE on k_dec: contribution of token s to the end-of-chunk state is
    # k_s * exp(le_end - le_s) (decay applied AFTER insertion, exclusive of
    # step s itself): S_C = diag(W_C) S_0 + sum_s diag(W_C / W_s) k_s v_s^T.
    state, ys = lax.scan(body, s0, (rc, kc, vc, lwc))
    y = ys.swapaxes(0, 1).reshape(b, n_chunks * chunk, h, dv)[:, :s]
    return y.astype(r.dtype), state


def decay_recurrence_naive(r, k, v, log_w, *, u=None, s0=None):
    """Step-by-step oracle for tests."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if s0 is None:
        s0 = jnp.zeros((b, h, dk, dv), jnp.float32)
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    wf = jnp.exp(log_w.astype(jnp.float32))

    def body(state, inp):
        rt, kt, vt, wt = inp                        # (B, H, dk/dv)
        kv = jnp.einsum("bhd,bhv->bhdv", kt, vt)
        if u is None:
            new = state * wt[..., None] + kv
            y = jnp.einsum("bhd,bhdv->bhv", rt, new)
        else:
            y = jnp.einsum("bhd,bhdv->bhv", rt, state) + jnp.einsum(
                "bhd,hd,bhd,bhv->bhv", rt, u.astype(jnp.float32), kt, vt
            )
            new = state * wt[..., None] + kv
        return new, y

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (rf, kf, vf, wf))
    state, ys = lax.scan(body, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), state


def decay_step(r, k, v, log_w, state, *, u=None):
    """Single-token decode step.  r/k/v: (B, H, dk|dv); state (B,H,dk,dv)."""
    rf, kf, vf = (a.astype(jnp.float32) for a in (r, k, v))
    w = jnp.exp(log_w.astype(jnp.float32))
    kv = jnp.einsum("bhd,bhv->bhdv", kf, vf)
    if u is None:
        new = state * w[..., None] + kv
        y = jnp.einsum("bhd,bhdv->bhv", rf, new)
    else:
        y = jnp.einsum("bhd,bhdv->bhv", rf, state) + jnp.einsum(
            "bhd,hd,bhd,bhv->bhv", rf, u.astype(jnp.float32), kf, vf
        )
        new = state * w[..., None] + kv
    return y.astype(r.dtype), new
