"""Logical-axis sharding rules (MaxText-style, pytree-path driven).

Every parameter/optimizer/cache/batch leaf is assigned a PartitionSpec by
classifying its dims from its pytree path.  The mesh axes:

* ``model`` — tensor parallel: heads / ff / vocab / experts dims.
* ``data`` (+ ``pod``) — batch (activations), and FSDP/ZeRO sharding of the
  d_model dim of weights and optimizer moments.

Divisibility is checked per-dim; a dim that does not divide falls back to
replication (e.g. zamba's 56 ssm heads over 16 model shards).  Flattened
head dims (H*hd) shard on ``model`` even when H < n_model — GSPMD then
splits within heads and inserts the needed collectives; this compiles
everywhere and shows up in the roofline as a hillclimbing lever rather
than a hard failure (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig
from repro.launch.mesh import dp_axes, model_size

# (parent, leaf) or leaf -> logical dims (layer dim added automatically for
# stacked leaves by matching rank)
LOGICAL = {
    "embed": ("vocab", "emb"),
    "lm_head": ("emb", "vocab"),
    "enc_in": ("emb", "emb2"),
    "wq": ("emb", "tp"),
    "wk": ("emb", "tp_kv"),
    "wv": ("emb", "tp_kv"),
    "wo": ("tp", "emb"),
    "bq": ("tp",),
    "bk": ("tp_kv",),
    "bv": ("tp_kv",),
    "w_up": ("emb", "tp"),
    "w_gate": ("emb", "tp"),
    "w_down": ("tp", "emb"),
    ("moe", "router"): ("emb", "rep"),
    ("moe", "w_up"): ("expert", "emb", "tp_inner"),
    ("moe", "w_gate"): ("expert", "emb", "tp_inner"),
    ("moe", "w_down"): ("expert", "tp_inner", "emb"),
    # mamba
    "in_proj": ("emb", "tp"),
    "out_proj": ("tp", "emb"),
    "conv_w": ("rep", "tp"),
    # rwkv
    "wr": ("emb", "tp"),
    "wg": ("emb", "tp"),
    "ck": ("emb", "tp"),
    "cv": ("tp", "emb"),
    "cr": ("emb", "tp"),
    "w_lora_a": ("emb", "rep"),
    "w_lora_b": ("rep", "emb"),
}

REPLICATED_LEAVES = {
    "scale", "bias", "a_log", "dt_bias", "d_skip", "out_norm", "mix",
    "cmix", "u", "w_base", "ln_x_scale", "ln_x_bias", "q_norm", "k_norm",
}


def _path_names(path) -> Tuple[str, ...]:
    return tuple(
        str(p.key) if hasattr(p, "key") else str(getattr(p, "idx", p))
        for p in path
    )


def _lookup(names: Tuple[str, ...]):
    leaf = names[-1]
    for parent in reversed(names[:-1]):
        if (parent, leaf) in LOGICAL:
            return LOGICAL[(parent, leaf)]
    return LOGICAL.get(leaf)


def _assign(logical: Tuple[str, ...], shape: Tuple[int, ...], mesh,
            *, fsdp: bool, cfg: Optional[ModelConfig] = None) -> P:
    from repro.sharding.perf import FLAGS

    nm = model_size(mesh)
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    # rank difference = leading stacked dims (layers / slices): replicated
    extra = len(shape) - len(logical)
    spec = [None] * extra
    used_data = False
    for dim, size in zip(logical, shape[extra:]):
        ax = None
        if dim in ("tp", "tp_kv", "vocab") and nm > 1 and size % nm == 0:
            ax = "model"
            if FLAGS.strict_heads and cfg is not None and dim in ("tp", "tp_kv"):
                # only shard projections on heads when whole heads divide
                heads = cfg.n_heads if dim == "tp" else cfg.n_kv_heads
                is_attn = size in (cfg.n_heads * cfg.hd,
                                   cfg.n_kv_heads * cfg.hd)
                if is_attn and heads % nm != 0:
                    ax = None
        elif dim == "expert" and nm > 1 and size % nm == 0:
            ax = "model"
        elif dim in ("emb", "tp_inner") and not used_data:
            if (fsdp and FLAGS.fsdp_params and dp_total > 1
                    and size % dp_total == 0):
                ax = dp if len(dp) > 1 else dp[0]
                used_data = True
        spec.append(ax)
    return P(*spec)


def param_spec(cfg: ModelConfig, path, shape, mesh, *, fsdp: bool = True) -> P:
    names = _path_names(path)
    if names[-1] in REPLICATED_LEAVES:
        return P()
    logical = _lookup(names)
    if logical is None:
        return P()
    return _assign(logical, tuple(shape), mesh, fsdp=fsdp, cfg=cfg)


def tree_param_shardings(cfg: ModelConfig, tree, mesh, *, fsdp: bool = True):
    """NamedSharding pytree matching ``tree`` (works on ShapeDtypeStructs)."""

    def f(path, leaf):
        return NamedSharding(
            mesh, param_spec(cfg, path, leaf.shape, mesh, fsdp=fsdp))

    return jax.tree_util.tree_map_with_path(f, tree)


# ---------------------------------------------------------------------------
# batches / caches / activations
# ---------------------------------------------------------------------------


def batch_axes_for(b: int, mesh) -> Optional[object]:
    """Largest prefix of the dp axes that divides the batch."""
    dp = dp_axes(mesh)
    full = 1
    for a in dp:
        full *= mesh.shape[a]
    if full > 1 and b % full == 0:
        return dp if len(dp) > 1 else dp[0]
    if "data" in dp and b % mesh.shape["data"] == 0 and mesh.shape["data"] > 1:
        return "data"
    if "pod" in dp and b % mesh.shape["pod"] == 0 and mesh.shape["pod"] > 1:
        return "pod"
    return None


def batch_spec(shape: Tuple[int, ...], mesh) -> P:
    ax = batch_axes_for(shape[0], mesh)
    return P(ax, *([None] * (len(shape) - 1)))


def tree_batch_shardings(tree, mesh):
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)), tree)


def cache_spec(cfg: ModelConfig, path, shape, mesh) -> P:
    """KV / state caches: (L|apps, B, S, KV, hd) or recurrent states."""
    names = _path_names(path)
    leaf = names[-1]
    nm = model_size(mesh)
    if leaf in ("k", "v") or "ckv" in names:
        l_, b, s, kv, hd = shape
        bx = batch_axes_for(b, mesh)
        if nm > 1 and kv % nm == 0:
            return P(None, bx, None, "model", None)
        if nm > 1 and s % nm == 0:
            # MQA long-context: shard the cache sequence (context parallel)
            return P(None, bx, "model", None, None)
        return P(None, bx, None, None, None)
    if leaf in ("wkv", "ssm"):                    # (L,B,H,dk,dv)
        l_, b, h = shape[:3]
        bx = batch_axes_for(b, mesh)
        ax = "model" if nm > 1 and h % nm == 0 else None
        return P(None, bx, ax, *([None] * (len(shape) - 3)))
    if leaf in ("shift_t", "shift_c", "conv"):
        b = shape[1]
        return P(None, batch_axes_for(b, mesh), *([None] * (len(shape) - 2)))
    if leaf == "len":
        return P()
    # fallback: shard dim-1 (batch) if divisible
    if len(shape) >= 2:
        return P(None, batch_axes_for(shape[1], mesh),
                 *([None] * (len(shape) - 2)))
    return P()


def tree_cache_shardings(cfg: ModelConfig, tree, mesh):
    def f(path, leaf):
        return NamedSharding(mesh, cache_spec(cfg, path, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(f, tree)


def opt_state_shardings(cfg: ModelConfig, state_tree, mesh,
                        *, fsdp: bool = True):
    """TrainState shardings: params + AdamW moments (moments shard like
    params — together with fsdp=True this is ZeRO-2/3-style)."""

    def f(path, leaf):
        names = _path_names(path)
        if names and names[-1] == "step":
            return NamedSharding(mesh, P())
        # strip the TrainState/AdamWState wrappers (params/mu/nu prefix)
        for i, n in enumerate(names):
            if n in ("params", "mu", "nu"):
                names = names[i + 1:]
                break
        spec = param_spec(cfg, _FakePath(names), leaf.shape, mesh, fsdp=fsdp)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(f, state_tree)


class _FakePath(tuple):
    """Adapter: a tuple of names quacking like a key path."""

    def __new__(cls, names):
        return super().__new__(cls, [_FakeKey(n) for n in names])


class _FakeKey:
    def __init__(self, key):
        self.key = key
