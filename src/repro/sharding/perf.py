"""Performance-iteration flags (EXPERIMENTS.md §Perf).

Each flag is one hypothesis from the hillclimbing log; the baseline is all
defaults.  Flags are process-global (set by the dry-run CLI per variant)
and read at trace time.
"""

from __future__ import annotations

import contextlib
import dataclasses


@dataclasses.dataclass
class PerfFlags:
    #: only shard attention q/kv projections on "model" when the *head
    #: count* divides the axis (instead of the flattened heads*hd dim) —
    #: avoids within-head splits and the involuntary-remat resharding storm
    strict_heads: bool = False
    #: context-parallel attention: shard the sequence dim over "model"
    #: around the attention block (for archs whose heads cannot shard)
    seq_parallel_attn: bool = False
    #: with_sharding_constraint on the MoE dispatch buffers so the
    #: token->expert scatter lowers to an all-to-all instead of
    #: replicate+all-reduce
    moe_dispatch_sharding: bool = False
    #: gather expert weights over the data axis before the expert einsums
    #: (instead of all-reducing the f-dim contraction partial sums)
    moe_weight_gather: bool = False
    #: 2D expert parallelism: shard the capacity dim of the dispatch buffer
    #: over the data axis so expert compute distributes over all chips
    moe_cap_shard: bool = False
    #: FSDP (data-axis) sharding of parameters; turning it off for serve
    #: removes per-layer weight all-gathers at the cost of replicated
    #: weight memory
    fsdp_params: bool = True
    #: gradient-compression path for the cross-pod all-reduce
    compress_pod_grads: bool = False


FLAGS = PerfFlags()

VARIANTS = {
    "baseline": {},
    "strict_heads": {"strict_heads": True},
    "seqpar": {"strict_heads": True, "seq_parallel_attn": True},
    "moe_shard": {"moe_dispatch_sharding": True},
    "moe_shard_strict": {"moe_dispatch_sharding": True, "strict_heads": True},
    "nofsdp": {"fsdp_params": False},
    "nofsdp_strict": {"fsdp_params": False, "strict_heads": True},
    "all_serve": {"fsdp_params": False, "strict_heads": True,
                  "moe_dispatch_sharding": True},
    "nofsdp_seqpar": {"fsdp_params": False, "strict_heads": True,
                      "seq_parallel_attn": True},
    "moe_wgather": {"moe_weight_gather": True},
    "moe_ep2d": {"moe_weight_gather": True, "moe_cap_shard": True},
    "moe_wgather_seqpar": {"moe_weight_gather": True,
                           "seq_parallel_attn": True},
    "seqpar_nofsdp": {"strict_heads": True, "seq_parallel_attn": True,
                      "fsdp_params": False},
}


@contextlib.contextmanager
def variant(name: str):
    global FLAGS
    old = dataclasses.replace(FLAGS)
    for k, v in VARIANTS[name].items():
        setattr(FLAGS, k, v)
    try:
        yield FLAGS
    finally:
        FLAGS = old
        globals()["FLAGS"] = old


def constraint(x, *spec):
    """with_sharding_constraint that no-ops outside a mesh context."""
    import jax
    from jax.sharding import PartitionSpec as P

    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def constrain_bs(x, *, seq: bool):
    """Constrain (B, S, ...) activations: batch over the dp axes, sequence
    over "model" when ``seq`` (whole-stream sequence parallelism)."""
    import jax
    from jax.sharding import PartitionSpec as P

    rest = [None] * (x.ndim - 2)
    for batch_ax in (("pod", "data"), "data", None):
        try:
            return jax.lax.with_sharding_constraint(
                x, P(batch_ax, "model" if seq else None, *rest))
        except Exception:
            continue
    return x
