"""Serve a trained LM analogly: program + calibrate (``analog_engine``),
one-shot batched decode (``decode_lm``), the continuous-batching request
runtime (``runtime``), and device-state management over time — drift,
stuck-cell faults, recalibration, band reprogramming (``health``)."""

from repro.serve.analog_engine import (
    age_pack,
    analog_eval_loss,
    analog_eval_metrics,
    calibrate_lm,
    decode_lm,
    lm_program_codes,
    program_lm,
    program_lm_from_codes,
)
from repro.serve.health import DriftClock, HealPolicy, PackManager
from repro.serve.runtime import (
    Completion,
    SamplerConfig,
    ServeRuntime,
    SlotState,
    request_key,
    sample_tokens,
)

__all__ = [
    "age_pack",
    "analog_eval_loss",
    "analog_eval_metrics",
    "calibrate_lm",
    "decode_lm",
    "lm_program_codes",
    "program_lm",
    "program_lm_from_codes",
    "DriftClock",
    "HealPolicy",
    "PackManager",
    "Completion",
    "SamplerConfig",
    "ServeRuntime",
    "SlotState",
    "request_key",
    "sample_tokens",
]
