"""Serve a trained LM analogly: program + calibrate (``analog_engine``),
one-shot batched decode (``decode_lm``), the continuous-batching request
runtime (``runtime``), its paged-KV + prefix-sharing variant (``paged``,
``kvpool``), and device-state management over time — drift, stuck-cell
faults, recalibration, band reprogramming (``health``)."""

from repro.serve.analog_engine import (
    age_pack,
    analog_eval_loss,
    analog_eval_metrics,
    calibrate_lm,
    decode_lm,
    lm_program_codes,
    program_lm,
    program_lm_from_codes,
)
from repro.serve.health import DriftClock, HealPolicy, PackManager
from repro.serve.kvpool import PageAllocator, PagePoolExhausted, RadixCache
from repro.serve.paged import PagedServeRuntime
from repro.serve.runtime import (
    Completion,
    SamplerConfig,
    ServeRuntime,
    SlotState,
    request_key,
    sample_tokens,
)

__all__ = [
    "age_pack",
    "analog_eval_loss",
    "analog_eval_metrics",
    "calibrate_lm",
    "decode_lm",
    "lm_program_codes",
    "program_lm",
    "program_lm_from_codes",
    "DriftClock",
    "HealPolicy",
    "PackManager",
    "PageAllocator",
    "PagePoolExhausted",
    "PagedServeRuntime",
    "RadixCache",
    "Completion",
    "SamplerConfig",
    "ServeRuntime",
    "SlotState",
    "request_key",
    "sample_tokens",
]
