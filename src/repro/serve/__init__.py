"""Serve a trained LM analogly: program + calibrate (``analog_engine``),
one-shot batched decode (``decode_lm``), and the continuous-batching
request runtime (``runtime``)."""

from repro.serve.analog_engine import (
    analog_eval_loss,
    analog_eval_metrics,
    calibrate_lm,
    decode_lm,
    lm_program_codes,
    program_lm,
    program_lm_from_codes,
)
from repro.serve.runtime import (
    Completion,
    SamplerConfig,
    ServeRuntime,
    SlotState,
    request_key,
    sample_tokens,
)

__all__ = [
    "analog_eval_loss",
    "analog_eval_metrics",
    "calibrate_lm",
    "decode_lm",
    "lm_program_codes",
    "program_lm",
    "program_lm_from_codes",
    "Completion",
    "SamplerConfig",
    "ServeRuntime",
    "SlotState",
    "request_key",
    "sample_tokens",
]
