"""Continuous-batching serving runtime over the programmed analog LM.

The sweep layer measures analog accuracy in a one-shot, equal-length,
greedy configuration (``decode_lm``).  This module is the *request-level*
serving system on top of the same substrate: a slot-based scheduler in
the style of iteration-level ("continuous") batching — ORCA / vLLM-class
scheduling, minus paged KV — where

* a fixed ``max_slots`` decode batch runs as ONE jitted step over the
  whole slot state (no per-request Python dispatch in steady state);
* requests with variable-length prompts queue up and are admitted into
  free slots via a *bucketed ragged prefill*
  (``transformer.prefill_ragged`` + ``cache_slot_insert``), so compile
  groups stay bounded: one program per (prompt bucket, admission-group
  size), both rounded to powers of two;
* each slot carries its own KV fill (``SlotState.length`` — the per-row
  ``cache["len"]`` vector the model layer understands), its own stop
  condition (EOS / ``max_new_tokens``), and its own sampling PRNG key;
* every matmul serves through the :class:`AnalogPack` when one is given
  — programming, calibration, decode and sampling all ride the same
  analog config, with ``r_hat`` / ``error.alpha`` carried in the pack's
  per-site specs, so a running server is a valid design point of the
  sweeps.  Heterogeneous packs (``repro.hw.Profile``: mixed per-site
  ADC precision, digital head, layer bands) serve unchanged — the pack
  carries its own site resolution, and the agreement contract below
  holds per site spec (pinned by ``tests/test_profile.py``).

Sampling keys compose with programming keys the same way hook keys do
(``serve.analog_engine.hook_key``): a request's stream key is folded
from a *stable hash of its uid* (:func:`request_key`), never from an
admission counter, so a request's sampled continuation is invariant to
queue position, slot assignment, and whatever else is being served.

The scheduler loop (one :meth:`ServeRuntime.step`):

1. **admit** — pop waiting requests into free slots; one ragged-prefill
   call per prompt bucket writes their K/V rows, first sampled token,
   fill lengths and keys into the slot state;
2. **decode** — one jitted ``decode_step`` over all ``max_slots`` slots
   (finished/free slots ride along masked), sample per-slot, append to
   per-slot output buffers, retire slots that hit a stop condition;
3. **collect** — completed requests are returned to the caller and their
   slots freed for the next admission.

``gang=True`` degrades the scheduler to static batching (admit only when
every slot is free, pad the whole batch to one bucket) — the baseline
``benchmarks/servebench.py`` measures continuous batching against.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.layers import NEG_INF
from repro.models.registry import get_model
from repro.models.transformer import AnalogPack
from repro.runtime.fault import resilient_step
from repro.serve.health import HEAD_BAND


# ---------------------------------------------------------------------------
# sampling
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Per-token sampling policy applied identically to every slot.

    ``greedy`` ignores keys entirely (deterministic, the configuration
    the runtime-vs-``decode_lm`` agreement contract is pinned in);
    ``temperature`` samples from the tempered softmax; ``top_k``
    restricts to the k highest logits first.
    """

    kind: str = "greedy"                 # greedy | temperature | top_k
    temperature: float = 1.0
    top_k: int = 0

    def __post_init__(self):
        kinds = ("greedy", "temperature", "top_k")
        if self.kind not in kinds:
            raise ValueError(
                f"unknown sampler kind {self.kind!r}; choose from {kinds}")
        if self.temperature <= 0.0:
            raise ValueError(f"temperature must be > 0, got {self.temperature}")
        if self.kind == "top_k" and self.top_k < 1:
            raise ValueError(f"top_k sampling needs top_k >= 1, got {self.top_k}")


def request_key(key: jax.Array, uid) -> jax.Array:
    """Fold a request's sampling key from a stable hash of its uid.

    The sampling-side sibling of ``serve.analog_engine.hook_key`` — the
    *same* fold, applied to ``str(uid)`` — so keys never derive from
    admission order or slot index and a request's sampled continuation
    is reproducible no matter what it is batched with (pinned by
    ``tests/test_runtime.py``).
    """
    from repro.serve.analog_engine import hook_key

    return hook_key(key, str(uid))


def sample_tokens(logits: jax.Array, keys: jax.Array,
                  sampler: SamplerConfig) -> Tuple[jax.Array, jax.Array]:
    """Sample one token per row: (B, V) logits + (B,) per-slot keys ->
    ((B,) int32 tokens, advanced keys).  Greedy leaves keys untouched."""
    if sampler.kind == "greedy":
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), keys

    def one(lg, k):
        use, nxt = jax.random.split(k)
        lg = lg.astype(jnp.float32) / sampler.temperature
        if sampler.kind == "top_k":
            kth = jax.lax.top_k(lg, sampler.top_k)[0][-1]
            lg = jnp.where(lg < kth, NEG_INF, lg)
        return jax.random.categorical(use, lg).astype(jnp.int32), nxt

    return jax.vmap(one)(logits, keys)


# ---------------------------------------------------------------------------
# slot state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SlotState:
    """The whole scheduler state as one pytree — the carry of the jitted
    decode step and the target of the prefill-insert scatters."""

    layers: Any            # slot-batched cache tree, leaves (L, B, S_max, ...)
    length: jax.Array      # (B,)  per-slot KV fill
    tok: jax.Array         # (B,)  last sampled token (next decode input)
    active: jax.Array      # (B,)  bool: slot holds a live request
    emitted: jax.Array     # (B,)  tokens generated so far
    max_new: jax.Array     # (B,)  per-request generation budget
    out: jax.Array         # (B, cap) generated-token buffer
    key: jax.Array         # (B, 2) per-slot sampling PRNG key


@dataclasses.dataclass
class _Pending:
    uid: Any
    prompt: np.ndarray
    max_new: int
    submit_t: float
    ttft_s: Optional[float] = None
    # decode-step counter value at which this request retires.  Exact when
    # EOS stopping is off (the budget is the only stop condition), which
    # lets _collect skip device syncs on steps where nothing can finish.
    done_step: int = 0


@dataclasses.dataclass(frozen=True)
class Completion:
    """One finished request: the generated tokens (EOS included when one
    fired) plus scheduling telemetry."""

    uid: Any
    tokens: np.ndarray          # (n_generated,) int32
    prompt_len: int
    ttft_s: float               # submit -> first token wall time


def _pow2_at_least(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


# ---------------------------------------------------------------------------
# the runtime
# ---------------------------------------------------------------------------


class ServeRuntime:
    """Slot-scheduled continuous-batching server over one (cfg, params[,
    pack]) — see the module docstring for the scheduling model.

    Parameters
    ----------
    pack:      serve through this :class:`AnalogPack` (program + calibrate
               it first, e.g. via ``repro.serve.analog_engine``); ``None``
               serves the digital model.
    max_slots: decode batch width — the fixed shape of the jitted step.
    max_len:   per-slot KV capacity; every request must satisfy
               ``len(prompt) + max_new_tokens <= max_len``.
    buckets:   allowed padded prompt lengths.  Prompts are right-padded to
               the smallest fitting bucket, so prefill compiles at most
               ``len(buckets) * log2(max_slots)`` programs.
    sampler:   :class:`SamplerConfig`; per-slot keys fold from the root
               seed via :func:`request_key`.
    manager:   a :class:`repro.serve.health.PackManager` owning the pack's
               device state over time — mutually exclusive with ``pack``.
               With a ``clock``, the served pack ages (drift + stuck-cell
               faults) as decode steps accumulate; with a ``heal`` policy,
               the runtime probes its own health and heals itself: per-site
               recalibration plus background band-by-band reprogramming,
               new conductances swapped in *between* decode steps (the
               jitted step takes the pack as a traced argument, so swaps
               never recompile and in-flight requests keep serving).
    clock:     :class:`repro.serve.health.DriftClock` mapping decode steps
               to device age; requires ``manager``.
    heal:      :class:`repro.serve.health.HealPolicy`; requires ``manager``.
    eos_id:    stop token (emitted, then the slot retires); ``None``
               disables EOS stopping (pure ``max_new_tokens`` budget).
    gang:      static-batching mode (admit only into an all-free server,
               one shared bucket) — the servebench baseline.
    attn_backend: decode-step attention implementation. ``"stream"`` is
               the online-softmax lax.scan; ``"flash"`` the flash-decode
               Pallas kernel over the dense slot cache
               (``kernels.ops.flash_attention_decode``); ``"flash_oracle"``
               its bitwise jnp mirror.  Prefill always streams (flash is
               a decode-shape kernel).  Flash has no sliding-window mask.
    measure_ttft: block on each prefill's results before stamping
               ``ttft_s``, so it measures true submit→first-token wall
               time.  Off by default: blocking defeats dispatch
               pipelining (prefills serialize against in-flight decode
               work), so the default stamps at dispatch — a submit→
               admission latency.  Benchmarks run throughput and TTFT
               as separate passes (``benchmarks/servebench.py``).
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        pack: Optional[AnalogPack] = None,
        max_slots: int = 8,
        max_len: int = 64,
        buckets: Optional[Sequence[int]] = None,
        sampler: SamplerConfig = SamplerConfig(),
        eos_id: Optional[int] = None,
        seed: int = 0,
        gang: bool = False,
        measure_ttft: bool = False,
        attn_backend: str = "stream",
        manager=None,
        clock=None,
        heal=None,
    ):
        api = get_model(cfg)
        if attn_backend not in ("stream", "flash", "flash_oracle"):
            raise ValueError(f"unknown attn_backend {attn_backend!r}")
        if attn_backend != "stream" and cfg.sliding_window is not None:
            raise ValueError(
                "the flash-decode kernel has no sliding-window mask; "
                "serve windowed configs with attn_backend='stream'")
        self.attn_backend = attn_backend
        if manager is not None and pack is not None:
            raise ValueError(
                "pass either pack= (a static AnalogPack) or manager= (a "
                "PackManager owning the pack's device state), not both")
        if (clock is not None or heal is not None) and manager is None:
            raise ValueError(
                "clock=/heal= need a manager= (repro.serve.health."
                "PackManager) to derive aged packs and reprogram bands")
        self._manager, self._clock, self._heal = manager, clock, heal
        if manager is not None:
            pack = manager.aged(clock.at(0) if clock is not None else 1.0)
        if api.prefill_ragged is None or api.cache_slot_insert is None:
            from repro.models.registry import families_with

            raise ValueError(
                f"family {cfg.family!r} has no continuous-batching support "
                f"(needs ModelApi.prefill_ragged + cache_slot_insert); "
                f"families with it: {sorted(families_with('prefill_ragged'))} "
                f"(rwkv and MoE configs excluded)")
        if cfg.rwkv:
            raise ValueError(
                "continuous batching does not support the rwkv family: "
                "ragged right-padded prefill would fold pad tokens into "
                "the recurrent state (DESIGN.md §Serving-runtime)")
        if cfg.n_experts:
            raise ValueError(
                "continuous batching does not support MoE configs: "
                "capacity-based expert routing computes token keep/drop "
                "from a batch-wide cumsum, so co-batched rows and pad "
                "tokens would change a request's output — the scheduling-"
                "never-changes-outputs contract cannot hold "
                "(DESIGN.md §Serving-runtime)")
        if max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {max_slots}")
        if buckets is None:
            # powers of two up to max_len, topped with max_len itself so
            # every request satisfying len(prompt) + max_new <= max_len
            # has a bucket
            buckets = tuple(b for b in (8, 16, 32, 64, 128, 256, 512, 1024)
                            if b < max_len) + (max_len,)
        buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not buckets or buckets[0] < 1 or buckets[-1] > max_len:
            raise ValueError(
                f"buckets must sit in [1, max_len={max_len}], got {buckets}")
        self.cfg, self.params, self.pack = cfg, params, pack
        self.max_slots, self.max_len = int(max_slots), int(max_len)
        self.buckets, self.sampler, self.gang = buckets, sampler, gang
        self.measure_ttft = measure_ttft
        self._api = api
        self._eos_enabled = eos_id is not None
        self._eos = -1 if eos_id is None else int(eos_id)
        self._root_key = jax.random.PRNGKey(seed)
        self._decode_fn = jax.jit(self._make_decode_fn())
        self._prefill_fns: Dict[Tuple[int, int], Any] = {}
        self._next_uid = 0
        self.reset()

    # -- state / bookkeeping ----------------------------------------------

    def reset(self) -> None:
        """Drop all queued/active requests and zero the slot state.
        Compiled step functions are kept, so a reset server re-serves
        without recompilation (used by benchmark warmup)."""
        b = self.max_slots
        self._state = SlotState(
            layers=self._init_layers(),
            length=jnp.zeros((b,), jnp.int32),
            tok=jnp.zeros((b,), jnp.int32),
            active=jnp.zeros((b,), bool),
            emitted=jnp.zeros((b,), jnp.int32),
            max_new=jnp.ones((b,), jnp.int32),
            out=jnp.zeros((b, self.max_len), jnp.int32),
            key=jnp.zeros((b, 2), jnp.uint32),
        )
        self._queue: Deque[_Pending] = deque()
        self._slots: List[Optional[_Pending]] = [None] * b
        self._early: List[Completion] = []
        self._live_uids: set = set()
        self._heal_queue: Deque[Any] = deque()
        self._last_health = 0
        self._stats = {"decode_steps": 0, "prefill_calls": 0,
                       "occupancy_sum": 0, "tokens_out": 0, "ttft_s": [],
                       "heal_events": 0, "bands_reprogrammed": 0,
                       "recalibrations": 0, "probe_losses": []}

    def _init_layers(self):
        """The slot-batched cache tree this runtime decodes over.  Hook
        for subclasses with a different KV layout (the paged runtime
        swaps in a global page pool, ``repro.serve.paged``)."""
        return self._api.init_cache(
            self.cfg, self.max_slots, self.max_len)["layers"]

    @property
    def stats(self) -> Dict[str, Any]:
        """Scheduling telemetry since the last :meth:`reset`:
        ``decode_steps``, ``prefill_calls``, mean ``occupancy`` (busy
        slots per decode step / ``max_slots``), ``tokens_out``, and the
        per-request ``ttft_s`` list."""
        s = dict(self._stats)
        s["ttft_s"] = list(s["ttft_s"])      # snapshot, not the live list
        s["probe_losses"] = list(s["probe_losses"])
        steps = max(s["decode_steps"], 1)
        s["occupancy"] = s.pop("occupancy_sum") / (steps * self.max_slots)
        return s

    # -- request API -------------------------------------------------------

    def submit(self, prompt, *, max_new_tokens: int, uid=None):
        """Queue one request; returns its uid (auto-assigned if None)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size < 1:
            raise ValueError("empty prompt")
        if prompt.min() < 0 or prompt.max() >= self.cfg.vocab:
            raise ValueError(
                f"prompt tokens must sit in [0, vocab={self.cfg.vocab}); "
                f"got range [{prompt.min()}, {prompt.max()}]")
        if prompt.size > self.buckets[-1]:
            raise ValueError(
                f"prompt length {prompt.size} exceeds the largest bucket "
                f"{self.buckets[-1]}; raise max_len/buckets")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {max_new_tokens}")
        if prompt.size + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt ({prompt.size}) + max_new_tokens ({max_new_tokens}) "
                f"exceeds the per-slot KV capacity max_len={self.max_len}")
        if uid is None:
            # auto-assignment shares a namespace with caller-chosen uids:
            # skip over any that are already in flight
            while str(self._next_uid) in self._live_uids:
                self._next_uid += 1
            uid, self._next_uid = self._next_uid, self._next_uid + 1
        # keys fold from str(uid), so "7" and 7 would share a sampling
        # stream — and run() keys completions by uid
        if str(uid) in self._live_uids:
            raise ValueError(f"request uid {uid!r} is already in flight")
        self._live_uids.add(str(uid))
        self._queue.append(_Pending(uid, prompt, int(max_new_tokens),
                                    time.perf_counter()))
        return uid

    @property
    def idle(self) -> bool:
        return not self._queue and all(p is None for p in self._slots)

    def run(self) -> Dict[Any, np.ndarray]:
        """Drain the queue to completion; returns {uid: generated tokens}."""
        done: Dict[Any, np.ndarray] = {}
        while not self.idle:
            for c in self.step():
                done[c.uid] = c.tokens
        while self._heal_queue:      # finish healing that started late
            self._maintain()
        return done

    # -- scheduler ---------------------------------------------------------

    def step(self) -> List[Completion]:
        """One scheduler iteration: maintain -> admit -> decode -> collect."""
        self._maintain()
        self._admit()
        early, self._early = self._early, []
        # lanes past their budget (done_step <= t: retired at prefill, or
        # certainly finished) need collecting, not decoding — don't burn a
        # model step on them.  An EOS that fired early on a lane with
        # budget left is device-side knowledge; _collect (which syncs
        # every step when EOS is on) frees that slot one step later.
        t = self._stats["decode_steps"]
        live = sum(p is not None and p.done_step > t for p in self._slots)
        if live:
            self._run_decode()
            self._stats["decode_steps"] += 1
            self._stats["occupancy_sum"] += live
        return early + self._collect()

    def _run_decode(self) -> None:
        """Dispatch one jitted decode step over the slot state.  Hook for
        subclasses that thread extra traced operands through the step
        (the paged runtime passes its block table)."""
        self._state = self._decode_fn(self._state, self.pack)

    def _maintain(self) -> None:
        """Device-state upkeep between decode steps (no-op without a
        manager).  Drains the heal queue ``bands_per_step`` targets per
        call through ``resilient_step`` (retry/backoff on transient
        faults), recalibrating once the queue empties; otherwise every
        ``check_every`` steps it re-ages the served pack and probes
        health, queueing a heal when the probe loss exceeds the policy
        threshold.  In-flight requests are untouched: the pack is a
        traced argument of the jitted step, so the swap never recompiles
        and never moves slot state."""
        m = self._manager
        if m is None:
            return
        hp = self._heal
        steps = self._stats["decode_steps"]
        t = self._clock.at(steps) if self._clock is not None else 1.0
        if self._heal_queue:
            for _ in range(min(hp.bands_per_step, len(self._heal_queue))):
                target = self._heal_queue.popleft()
                if target == HEAD_BAND:
                    resilient_step(m.reprogram_head, t_now=t,
                                   max_retries=hp.max_retries,
                                   backoff_s=hp.backoff_s)
                else:
                    resilient_step(m.reprogram_band, target, t_now=t,
                                   max_retries=hp.max_retries,
                                   backoff_s=hp.backoff_s)
                self._stats["bands_reprogrammed"] += 1
            self.pack = m.aged(t)
            if not self._heal_queue and hp.recalibrate:
                self.pack = m.recalibrate(self.pack)
                self._stats["recalibrations"] += 1
            return
        every = (hp.check_every if hp is not None
                 else (self._clock.update_every
                       if self._clock is not None else 0))
        if not every or (steps - self._last_health) < every:
            return
        self._last_health = steps
        if self._clock is not None:
            self.pack = m.aged(t)
        if hp is None:
            return
        loss = m.probe_loss(self.pack)
        self._stats["probe_losses"].append(loss)
        if loss > m.ref_loss * hp.loss_mult + hp.loss_add:
            self._stats["heal_events"] += 1
            if hp.reprogram:
                self._heal_queue.extend(m.heal_targets())
            elif hp.recalibrate:
                self.pack = m.recalibrate(self.pack)
                self._stats["recalibrations"] += 1

    def _admit(self) -> None:
        """Admit queued requests until slots or queue run dry.

        Lanes that retire *at prefill* — a 1-token generation budget, or
        an immediate EOS when stopping is on — release their slot (and,
        in the paged runtime, their KV pages) right here, and the loop
        re-admits into the freed capacity.  A bursty queue of short
        requests therefore drains within one scheduler step instead of
        each batch holding slots through a decode step it never needed.
        """
        while self._admit_batch():
            if not self._queue:
                return
            t = self._stats["decode_steps"]
            may_retire = any(p is not None and p.done_step <= t
                             for p in self._slots)
            # with EOS stopping on, a first-token EOS also retires a lane
            # at prefill — that is device-side knowledge, so attempt a
            # (syncing) collect whenever EOS is enabled
            if not (may_retire or self._eos_enabled):
                return
            done = self._collect()
            if not done:
                return
            self._early.extend(done)

    def _admit_batch(self) -> bool:
        """Admit one batch of requests into free slots; True if any."""
        free = [i for i, p in enumerate(self._slots) if p is None]
        if not free or not self._queue:
            return False
        if self.gang and len(free) < self.max_slots:
            return False                # static batching: wait for a full drain
        take: List[_Pending] = []
        while self._queue and len(take) < len(free):
            if not self._reserve(self._queue[0]):
                break                   # backpressure: keep FIFO order intact
            take.append(self._queue.popleft())
        if not take:
            return False
        groups: Dict[Tuple, List[Tuple[_Pending, int]]] = {}
        if self.gang:
            # one shared bucket: pad the whole batch to its longest prompt
            bucket = self._bucket_for(max(r.prompt.size for r in take))
            groups[(bucket,)] = [(r, free.pop(0)) for r in take]
        else:
            for r in take:
                groups.setdefault(self._group_key(r), []).append(
                    (r, free.pop(0)))
        # ascending key order; the paged runtime relies on this (groups
        # sort by cached-prefix length, so a prefix donor's prefill is
        # dispatched before any same-batch borrower gathers its pages)
        for key in sorted(groups):
            self._prefill_group(key, groups[key])
        return True

    def _reserve(self, req: _Pending) -> bool:
        """Claim admission resources for the queue head (hook).  False
        leaves the request queued — the paged runtime returns False when
        the page pool cannot hold the request right now."""
        return True

    def _group_key(self, req: _Pending) -> Tuple:
        """Compile-group key for an admitted request; the last element
        is always the padded prompt bucket."""
        return (self._bucket_for(req.prompt.size),)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise AssertionError(n)         # unreachable: submit() validates

    def _prefill_group(self, key: Tuple,
                       items: List[Tuple[_Pending, int]]) -> None:
        bucket = key[-1]
        g = min(_pow2_at_least(len(items)), self.max_slots)
        prompts = np.zeros((g, bucket), np.int32)
        true_lens = np.ones((g,), np.int32)
        slots = np.full((g,), self.max_slots, np.int32)   # dummy -> dropped
        max_new = np.ones((g,), np.int32)
        keys = [jnp.zeros((2,), jnp.uint32)] * g
        for j, (req, slot) in enumerate(items):
            prompts[j, :req.prompt.size] = req.prompt
            true_lens[j] = req.prompt.size
            slots[j] = slot
            max_new[j] = req.max_new
            keys[j] = request_key(self._root_key, req.uid)
            self._slots[slot] = req
        fn = self._prefill_fns.get((bucket, g))
        if fn is None:
            fn = self._prefill_fns[(bucket, g)] = jax.jit(
                self._make_prefill_fn())
        self._state = fn(self._state, self.pack, jnp.asarray(prompts),
                         jnp.asarray(true_lens), jnp.asarray(slots),
                         jnp.asarray(max_new), jnp.stack(keys))
        self._stats["prefill_calls"] += 1
        if self.measure_ttft:
            # first tokens exist only once the async dispatch lands —
            # without the block, ttft_s is submit->admission latency
            jax.block_until_ready(self._state.tok)
        now = time.perf_counter()
        for req, _ in items:
            req.ttft_s = now - req.submit_t
            req.done_step = self._stats["decode_steps"] + req.max_new - 1
            self._stats["ttft_s"].append(req.ttft_s)

    def _collect(self) -> List[Completion]:
        busy = [p for p in self._slots if p is not None]
        if not busy:
            return []
        if not self._eos_enabled:
            # the generation budget is the only stop condition, so finish
            # steps are host-predictable: skip the device sync entirely on
            # steps where no slot can retire (the steady-state fast path)
            t = self._stats["decode_steps"]
            if all(p.done_step > t for p in busy):
                return []
        active = np.asarray(self._state.active)
        finished = [i for i, p in enumerate(self._slots)
                    if p is not None and not active[i]]
        if not finished:
            return []
        out = np.asarray(self._state.out)
        emitted = np.asarray(self._state.emitted)
        done = []
        for i in finished:
            req = self._slots[i]
            self._free_slot(i)
            self._live_uids.discard(str(req.uid))
            toks = out[i, :emitted[i]].astype(np.int32)
            self._stats["tokens_out"] += int(emitted[i])
            done.append(Completion(uid=req.uid, tokens=toks,
                                   prompt_len=int(req.prompt.size),
                                   ttft_s=req.ttft_s))
        return done

    def _free_slot(self, i: int) -> None:
        """Return slot ``i`` to the free list (hook: the paged runtime
        also releases the slot's page references and zeroes its
        block-table row here)."""
        self._slots[i] = None

    # -- jitted step bodies ------------------------------------------------

    def _make_decode_model(self):
        """The model half of the decode step: (state, pack, *extra) ->
        (last-token logits, new cache layers, new lengths).  Hook — the
        paged runtime swaps in ``decode_step_paged`` over the page pool;
        the sampling/bookkeeping tail in ``_make_decode_fn`` is shared.
        """
        cfg, params, api = self.cfg, self.params, self._api
        attn_backend = self.attn_backend

        def model(state: SlotState, pack):
            cache = {"layers": state.layers, "len": state.length}
            logits, cache = api.decode_step(
                cfg, params, state.tok[:, None], cache, pack=pack,
                attn_backend=attn_backend)
            return logits[:, -1], cache["layers"], cache["len"]

        return model

    def _make_decode_fn(self):
        sampler, eos = self.sampler, self._eos
        model = self._make_decode_model()

        # the pack is a traced ARGUMENT, not a closure: a healed/aged pack
        # (same treedef, new conductances) swaps in between decode steps
        # without recompiling the step
        def decode(state: SlotState, pack, *extra) -> SlotState:
            logits, layers, length = model(state, pack, *extra)
            nxt, keys = sample_tokens(logits, state.key, sampler)
            act = state.active
            cap = state.out.shape[1]
            hit = (jnp.arange(cap)[None, :] == state.emitted[:, None]) \
                & act[:, None]
            out = jnp.where(hit, nxt[:, None], state.out)
            emitted = state.emitted + act.astype(state.emitted.dtype)
            done = act & ((emitted >= state.max_new) | (nxt == eos))
            return SlotState(
                layers=layers,
                length=jnp.where(act, length, state.length),
                tok=jnp.where(act, nxt, state.tok),
                active=act & ~done,
                emitted=emitted,
                max_new=state.max_new,
                out=out,
                key=jnp.where(act[:, None], keys, state.key),
            )

        return decode

    def _make_prefill_fn(self):
        cfg, params = self.cfg, self.params
        api, sampler, eos = self._api, self.sampler, self._eos

        def prefill(state: SlotState, pack, prompts, true_lens, slots,
                    max_new, keys) -> SlotState:
            logits, pcache = api.prefill_ragged(
                cfg, params, prompts, true_lens=true_lens, pack=pack)
            first, keys = sample_tokens(logits[:, -1], keys, sampler)
            slot_cache = api.cache_slot_insert(
                {"layers": state.layers, "len": state.length}, pcache, slots)
            cap = state.out.shape[1]
            row = jnp.zeros((slots.shape[0], cap), state.out.dtype)
            row = row.at[:, 0].set(first)
            # a 1-token budget (or immediate EOS) finishes at prefill
            live = (max_new > 1) & (first != eos)
            return SlotState(
                layers=slot_cache["layers"],
                length=slot_cache["len"],
                tok=state.tok.at[slots].set(first, mode="drop"),
                active=state.active.at[slots].set(live, mode="drop"),
                emitted=state.emitted.at[slots].set(1, mode="drop"),
                max_new=state.max_new.at[slots].set(max_new, mode="drop"),
                out=state.out.at[slots].set(row, mode="drop"),
                key=state.key.at[slots].set(keys, mode="drop"),
            )

        return prefill
