"""Self-healing analog serving: device-state management over time.

A programmed pack is not immortal: conductances drift (power-law
retention decay) and cells fail (stuck-at faults) — the processes of
``repro.core.errors.DriftModel`` / ``FaultModel``.  This module owns the
serving side of that story (DESIGN.md §Drift-and-healing):

* :class:`DriftClock` — maps the runtime's decode-step counter to a
  physical device age ``t`` (in units of the programming-reference time
  t0), so wall-clock aging is deterministic per served trace;
* :class:`HealPolicy` — the step-budgeted response: how often to probe
  health, the probe-loss threshold (the ``tests/test_system.py``
  tolerance by default), and the per-scheduler-step reprogram budget;
* :class:`PackManager` — owns a pack's full device state: the programmed
  integer codes, per-band reprogram epochs (which key the re-drawn
  programming noise), the aging clocks of each band, recalibration, and
  the calibration-probe loss against the fresh-pack reference.

Determinism contract: everything replays.  Aging keys fold from stable
hook-name hashes (``analog_engine.age_pack``); reprogram epoch ``e`` of
band ``b`` uses ``fold_in(fold_in(key, REPROGRAM), e)`` with epoch 0
being the original programming key, so a freshly-built manager's pack is
bit-identical to ``program_lm`` + ``calibrate_lm`` with the same key,
and reprogramming a band at epoch 0 reproduces the fresh program of that
band bit-for-bit (pinned by ``tests/test_drift.py``).

Physics of the composition (per band ``b`` programmed at age ``t_p``):

* programming noise: re-drawn per epoch (a reprogram is a new write);
* drift: relative age — ``g * (t / t_p)^-nu_cell`` — reprogramming
  resets the decay clock, which is what makes healing work;
* faults: absolute age, keyed independently of epochs — a stuck cell
  stays stuck across reprogramming (a broken device cannot be healed,
  only recalibrated around).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.analog import AnalogSpec, AnalogWeights, program_from_codes
from repro.hw.profile import Profile, as_profile
from repro.models.transformer import AnalogPack
from repro.serve.analog_engine import (
    HEAD,
    age_pack,
    analog_eval_metrics,
    calibrate_lm,
    hook_key,
    lm_program_codes,
    program_lm_from_codes,
)

#: fold tag separating reprogram-epoch keys from the original programming
#: key (epoch 0 *is* the original key — see :meth:`PackManager.epoch_key`)
_REPROGRAM_FOLD = 0x72657067  # "repg"

#: fold tag deriving the default aging key from the programming key
_AGE_KEY_FOLD = 0x64726674  # "drft"

#: the head's slot in a heal queue (bands are integer indices)
HEAD_BAND = "head"


@dataclasses.dataclass(frozen=True)
class DriftClock:
    """Decode-step counter -> device age ``t`` (t0 units, 1.0 = fresh).

    ``update_every`` is the no-heal aging cadence: a runtime with a clock
    but no :class:`HealPolicy` still refreshes its served pack every this
    many decode steps (the degradation baseline ``benchmarks/driftbench``
    measures healing against).
    """

    dt_per_step: float = 0.0
    update_every: int = 16

    def __post_init__(self):
        if self.dt_per_step < 0:
            raise ValueError(
                f"DriftClock.dt_per_step must be >= 0, got {self.dt_per_step}")
        if self.update_every < 1:
            raise ValueError(
                f"DriftClock.update_every must be >= 1, got "
                f"{self.update_every}")

    def at(self, step: int) -> float:
        return 1.0 + self.dt_per_step * step


@dataclasses.dataclass(frozen=True)
class HealPolicy:
    """Step-budgeted self-healing response of a :class:`ServeRuntime`.

    Every ``check_every`` decode steps the runtime re-ages its pack and
    measures the calibration-probe loss; when it exceeds
    ``ref * loss_mult + loss_add`` (the ``tests/test_system.py``
    tolerance formula against the fresh-pack reference) a heal event
    fires: every aging band is queued for background reprogramming,
    drained ``bands_per_step`` bands per scheduler step *between* decode
    steps — in-flight requests keep serving throughout — followed by one
    recalibration once the queue is empty.  The reprogram path runs
    through ``repro.runtime.fault.resilient_step`` with ``max_retries``/
    ``backoff_s``.  ``loss_mult=0, loss_add=-1`` forces a heal on every
    probe (used by tests).
    """

    check_every: int = 16
    loss_mult: float = 1.35
    loss_add: float = 0.2
    recalibrate: bool = True
    reprogram: bool = True
    bands_per_step: int = 1
    max_retries: int = 3
    backoff_s: float = 0.01

    def __post_init__(self):
        if self.check_every < 1:
            raise ValueError(
                f"HealPolicy.check_every must be >= 1, got {self.check_every}")
        if self.bands_per_step < 1:
            raise ValueError(
                f"HealPolicy.bands_per_step must be >= 1, got "
                f"{self.bands_per_step}")


class PackManager:
    """Owns one served pack's device state over its lifetime.

    Built like ``program_lm`` + ``calibrate_lm`` (and bit-identical to
    them at construction); then :meth:`aged` derives the pack at any
    absolute age ``t``, :meth:`reprogram_band` rewrites one band's
    conductances from the cached codes under a new epoch key (resetting
    that band's drift clock), and :meth:`recalibrate` re-fits ADC ranges
    and activation clips to the current device state.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        spec: Union[AnalogSpec, Profile],
        key: jax.Array,
        *,
        calib_tokens: jax.Array,
        include_head: bool = True,
        age_key: Optional[jax.Array] = None,
    ):
        profile = as_profile(spec)
        for selector, sp in profile.selectors():
            if float(sp.drift.t) != 1.0 or float(sp.fault.t) != 1.0:
                raise ValueError(
                    f"PackManager owns the aging clock: spec of selector "
                    f"{selector!r} must be at the fresh age (drift.t == "
                    f"fault.t == 1.0), got drift.t={sp.drift.t} "
                    f"fault.t={sp.fault.t}")
        self.cfg, self.params, self.profile = cfg, params, profile
        self.key = key
        self.age_key = (jax.random.fold_in(key, _AGE_KEY_FOLD)
                        if age_key is None else age_key)
        self.calib_tokens = calib_tokens
        self.codes = lm_program_codes(cfg, params, profile,
                                      include_head=include_head)
        pack = program_lm_from_codes(cfg, self.codes, profile, key)
        pack = calibrate_lm(cfg, params, pack, calib_tokens)
        self._fresh = pack
        self._base = pack                      # current-epoch conductances
        n_bands = len(pack.bands)
        self._epoch: List[int] = [0] * n_bands
        self._t_prog: List[float] = [1.0] * n_bands
        self._head_epoch, self._head_t = 0, 1.0
        self._probe_fn = jax.jit(
            lambda p, x, y: analog_eval_metrics(cfg, params, p, x, y)["loss"])
        self.ref_loss = float(self.probe_loss(pack))

    # -- health -----------------------------------------------------------

    def probe_loss(self, pack: AnalogPack) -> float:
        """Teacher-forced loss on the calibration batch — the health
        probe.  Jitted with the pack as a traced argument, so swapped
        (healed/aged) packs never recompile."""
        x = self.calib_tokens[:, :-1]
        y = self.calib_tokens[:, 1:]
        return float(self._probe_fn(pack, x, y))

    @property
    def fresh_pack(self) -> AnalogPack:
        """The as-built pack (epoch-0 conductances, fresh calibration)."""
        return self._fresh

    @property
    def band_epochs(self) -> List[int]:
        return list(self._epoch)

    # -- aging ------------------------------------------------------------

    def aged(self, t: float) -> AnalogPack:
        """The served pack at absolute age ``t``: drift relative to each
        band's reprogram age, faults at absolute ``t`` on the current
        epoch's conductances."""
        bands = self._base.bands
        td = [max(float(t) / tp, 1.0) for tp in self._t_prog]
        tf = [float(t)] * len(bands)
        pack = age_pack(self._base, t, self.age_key,
                        t_drift_by_band=td, t_fault_by_band=tf)
        return self._age_head(pack, t)

    def _age_head(self, pack: AnalogPack, t: float) -> AnalogPack:
        # age_pack applied the uniform t to the head; redo it relative to
        # the head's own reprogram age when they differ
        if (pack.head is None or not pack.head_spec.aging_on
                or self._head_t == 1.0):
            return pack
        from repro.serve.analog_engine import _age_weights

        t_rel = max(float(t) / self._head_t, 1.0)
        head = _age_weights(self._base.head, pack.head_spec, t_rel, t,
                            hook_key(self.age_key, HEAD))
        return dataclasses.replace(pack, head=head)

    # -- reprogramming ----------------------------------------------------

    def epoch_key(self, epoch: int) -> jax.Array:
        """Programming key of reprogram generation ``epoch`` (0 = the
        original build key, exactly)."""
        if epoch == 0:
            return self.key
        return jax.random.fold_in(
            jax.random.fold_in(self.key, _REPROGRAM_FOLD), epoch)

    def program_band(self, b: int, key: jax.Array) -> Dict[str, AnalogWeights]:
        """Freshly program band ``b``'s layers for every analog site —
        bit-identical to the same rows of a full ``program_lm_from_codes``
        with ``key`` (same ``fold_in(hook_key(key, name), absolute
        layer)`` schedule)."""
        lo, hi = self._base.bands[b]
        out: Dict[str, AnalogWeights] = {}
        for name in self._base.layer_weights:
            sp = self._base.band_specs[b].get(name)
            spec_b = sp if sp is not None else self._base.site_spec(name)
            sub = jax.tree.map(lambda a: a[lo:hi], self.codes[name])
            site_key = hook_key(key, name)
            keys = jax.vmap(lambda i: jax.random.fold_in(site_key, i))(
                jnp.arange(lo, hi))
            out[name] = jax.vmap(
                lambda c, k, s=spec_b: program_from_codes(c, s, k))(sub, keys)
        return out

    def reprogram_band(self, b: int, *, t_now: float) -> None:
        """Rewrite band ``b`` under the next epoch key and reset its
        drift clock to ``t_now``.  Mutates the manager; callers wanting
        retry/backoff wrap this in ``repro.runtime.fault.resilient_step``
        (the runtime does)."""
        e = self._epoch[b] + 1
        weights = self.program_band(b, self.epoch_key(e))
        lo, hi = self._base.bands[b]
        lw = {
            name: jax.tree.map(
                lambda full, part: full.at[lo:hi].set(part), aw, weights[name])
            for name, aw in self._base.layer_weights.items()
        }
        self._base = dataclasses.replace(self._base, layer_weights=lw)
        self._epoch[b] = e
        self._t_prog[b] = float(t_now)

    def reprogram_head(self, *, t_now: float) -> None:
        """Rewrite the head projection under its next epoch key."""
        if self._base.head is None:
            raise ValueError("this pack has no analog head to reprogram")
        e = self._head_epoch + 1
        head = program_from_codes(
            self.codes[HEAD], self._base.head_spec,
            hook_key(self.epoch_key(e), HEAD))
        self._base = dataclasses.replace(self._base, head=head)
        self._head_epoch = e
        self._head_t = float(t_now)

    def heal_targets(self) -> List[Any]:
        """The reprogram queue of one heal event: every band with at
        least one aging site, then the head if it ages."""
        targets: List[Any] = []
        for b, ss in enumerate(self._base.band_specs):
            if any(sp.aging_on for _, sp in ss.items):
                targets.append(b)
        if (self._base.head is not None
                and self._base.head_spec.aging_on):
            targets.append(HEAD_BAND)
        return targets

    # -- recalibration ----------------------------------------------------

    def recalibrate(self, pack: AnalogPack) -> AnalogPack:
        """Re-fit activation clips and ADC ranges to the aged device
        state (per-site, through the same two collect passes as the
        original calibration)."""
        return calibrate_lm(self.cfg, self.params, pack, self.calib_tokens)
