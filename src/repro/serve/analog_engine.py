"""Analog LM serving: program a trained LM onto simulated analog arrays,
calibrate its ADC ranges, and serve through the analog pipeline.

Pipeline (paper Sec. 4):

1. ``program_lm``    — every weight-stationary projection of every layer is
   quantized, mapped (per the AnalogSpec), and perturbed with program-time
   cell errors.  Per-layer PRNG keys are folded from the layer index.
2. ``calibrate_lm``  — two collect passes over a calibration batch:
   phase 1 records per-layer activation ranges (L1-optimal clip of the
   matmul *inputs*, Sec. 4.3); phase 2 re-runs with those clips installed
   and records the inner-99.98% pre-ADC ranges per (layer, slice)
   (Sec. 6.2), power-of-two constrained for sliced mappings.
3. ``analog pack`` feeds ``repro.models.transformer`` forward/prefill/
   decode — the same scanned model body, conductances scanned alongside
   parameters.

Scope: the dense/vlm/ssm(rwkv) transformer family (the paper's technique
targets weight-stationary MVMs; see DESIGN.md §Arch-applicability for the
MoE-expert / recurrence caveats).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import calibrate as cal
from repro.core.analog import AnalogSpec, AnalogWeights, program
from repro.core.quant import calibrate_act_range
from repro.models.registry import get_model
from repro.models.transformer import AnalogPack, cast_params, forward

#: weight leaves programmed to analog arrays, per family
DENSE_NAMES = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
}
RWKV_NAMES = {
    "rwkv": ("wr", "wk", "wv", "wg", "wo", "ck", "cv", "cr"),
}
# analog hook names used inside the blocks (see models/*.py dense() calls)
HOOK_NAME = {
    ("attn", "wq"): "wq", ("attn", "wk"): "wk", ("attn", "wv"): "wv",
    ("attn", "wo"): "wo",
    ("mlp", "w_gate"): "w_gate", ("mlp", "w_up"): "w_up",
    ("mlp", "w_down"): "w_down",
    ("rwkv", "wr"): "rwkv_wr", ("rwkv", "wk"): "rwkv_wk",
    ("rwkv", "wv"): "rwkv_wv", ("rwkv", "wg"): "rwkv_wg",
    ("rwkv", "wo"): "rwkv_wo", ("rwkv", "ck"): "rwkv_ck",
    ("rwkv", "cv"): "rwkv_cv", ("rwkv", "cr"): "rwkv_cr",
}


def _program_stack(w_stack: jax.Array, spec: AnalogSpec,
                   key: jax.Array) -> AnalogWeights:
    """vmap ``program`` over the layer axis of (L, K, N)."""
    l = w_stack.shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(l))
    return jax.vmap(lambda w, k: program(w, spec, k))(w_stack, keys)


def program_lm(cfg: ModelConfig, params: dict, spec: AnalogSpec,
               key: jax.Array, *, include_head: bool = True) -> AnalogPack:
    groups = RWKV_NAMES if cfg.rwkv else DENSE_NAMES
    layer_weights: Dict[str, AnalogWeights] = {}
    cp = params["layers"]
    i = 0
    for parent, leaves in groups.items():
        for leaf in leaves:
            if parent not in cp or leaf not in cp[parent]:
                continue
            name = HOOK_NAME[(parent, leaf)]
            layer_weights[name] = _program_stack(
                cp[parent][leaf].astype(jnp.float32), spec,
                jax.random.fold_in(key, i))
            i += 1
    head = None
    if include_head:
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        head = program(w.astype(jnp.float32), spec,
                       jax.random.fold_in(key, 10_000))
    s = spec.mapping.n_slices
    l = cfg.n_layers
    zeros = {n: jnp.zeros((l, s)) for n in layer_weights}
    return AnalogPack(
        spec=spec, layer_weights=layer_weights,
        layer_lo=zeros, layer_hi={n: jnp.ones((l, s)) for n in layer_weights},
        layer_act={}, head=head,
        head_lo=jnp.zeros((s,)), head_hi=jnp.ones((s,)),
        head_act=None, collect=False,
    )


def calibrate_lm(cfg: ModelConfig, params: dict, pack: AnalogPack,
                 calib_tokens: jax.Array,
                 prefix_embeds=None) -> AnalogPack:
    """Two-phase range calibration; returns a serving-ready pack."""
    api = get_model(cfg)

    # ---- phase 1: activation clip ranges (digital run, collect inputs) ---
    pack1 = dataclasses.replace(pack, collect=True)
    _, aux1 = api.forward(cfg, params, calib_tokens, pack=pack1,
                          **({"prefix_embeds": prefix_embeds}
                             if prefix_embeds is not None else {}))
    act = {}
    for k, v in aux1.items():
        if k.startswith("act/"):
            act[k[len("act/"):]] = v            # (L,) per-layer clip
    pack2 = dataclasses.replace(pack, layer_act=act, collect=True)

    # ---- phase 2: pre-ADC ranges with activation clips installed ---------
    _, aux2 = api.forward(cfg, params, calib_tokens, pack=pack2,
                          **({"prefix_embeds": prefix_embeds}
                             if prefix_embeds is not None else {}))
    lo, hi = {}, {}
    for k, v in aux2.items():
        if not k.startswith("adc/"):
            continue
        name = k[len("adc/"):]
        lo_s, hi_s = v[..., 0], v[..., 1]       # (L, S)
        if pack.spec.mapping.sliced:
            lo_s, hi_s = jax.vmap(cal.constrain_power_of_two)(lo_s, hi_s)
        lo[name], hi[name] = lo_s, hi_s

    # head calibration on the true final-norm hiddens (emitted by the
    # collect forward)
    head_lo, head_hi, head_act = pack.head_lo, pack.head_hi, None
    if pack.head is not None:
        from repro.core.analog import analog_matmul

        x = aux2["final_hidden"].reshape(-1, cfg.d_model)
        _, head_act = calibrate_act_range(x, pack.spec.input_bits)
        _, stats = analog_matmul(
            x, pack.head, pack.spec, act_hi=head_act, collect=True)
        head_lo, head_hi = stats[:, 0], stats[:, 1]

    return dataclasses.replace(
        pack, layer_lo=lo, layer_hi=hi, layer_act=act,
        head_lo=head_lo, head_hi=head_hi, head_act=head_act, collect=False,
    )


def analog_eval_loss(cfg: ModelConfig, params: dict, pack: AnalogPack,
                     tokens: jax.Array, targets: jax.Array) -> jax.Array:
    """Cross-entropy of the analog model (accuracy metric for sweeps)."""
    logits, _ = forward(cfg, params, tokens, pack=pack, remat=False)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
