"""Analog LM serving: program a trained LM onto simulated analog arrays,
calibrate its ADC ranges, and serve through the analog pipeline.

Pipeline (paper Sec. 4):

1. ``program_lm``    — every weight-stationary projection of every layer is
   quantized, mapped (per the AnalogSpec), and perturbed with program-time
   cell errors.  PRNG keys are folded from a *stable hash of the hook
   name* (then the layer index), so a projection's programming noise never
   depends on which other projections exist or on dict-iteration order.
2. ``calibrate_lm``  — two collect passes over a calibration batch:
   phase 1 records per-layer activation ranges (L1-optimal clip of the
   matmul *inputs*, Sec. 4.3); phase 2 re-runs with those clips installed
   and records the inner-99.98% pre-ADC ranges per (layer, slice)
   (Sec. 6.2), power-of-two constrained for sliced mappings.
3. ``analog pack`` feeds ``repro.models.transformer`` forward/prefill/
   decode — the same scanned model body, conductances scanned alongside
   parameters.  ``decode_lm`` is the batched multi-request serving entry
   (prefill + scanned greedy decode through the pack).

Programming is split like ``core.analog.program``:
``lm_program_codes`` (quantize + integer code mapping — deterministic,
independent of the trial key, the error magnitude, and the On/Off ratio)
and ``program_lm_from_codes`` (conductance-convert + perturb, tracer-safe
in ``error.alpha`` / ``mapping.on_off_ratio``).  The sweep engine
(``repro.sweep.ServeEvaluator``) caches the codes per
``(mapping signature, params hash)`` and vmaps the second half over trial
keys; ``program_lm`` composes the two halves, so the eager path and the
vectorized path draw identical programming noise by construction.

The full AnalogSpec rides through program → calibrate → serve unchanged,
parasitics included: a pack whose spec has ``r_hat > 0`` routes every
weight-stationary matmul (calibration collect passes and KV-cached greedy
decode alike) through the bit-line tridiagonal solve, and ``r_hat`` stays
tracer-safe so ``ServeEvaluator`` batches a whole parasitic axis through
one compilation (DESIGN.md §Parasitics).

Every programming/calibration entry point takes either one global
:class:`AnalogSpec` (applied uniformly, the legacy API — bit-identical to
the pre-profile path) or a :class:`repro.hw.Profile` that resolves each
*site* (hook name) to its own spec: heterogeneous per-site hardware,
with ``digital`` sites kept off-array and per-layer-band rules splitting
the scanned model body at band boundaries (DESIGN.md §Heterogeneous
profiles).  Programming keys stay site-keyed (``hook_key``) either way,
so a site's noise never depends on what the rest of the network runs on.

Scope: the dense/vlm/ssm(rwkv) transformer family (the paper's technique
targets weight-stationary MVMs; see DESIGN.md §Arch-applicability for the
MoE-expert / recurrence caveats).
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import calibrate as cal
from repro.core.analog import (
    AnalogSpec,
    AnalogWeights,
    ProgrammedMatrix,
    program,
    program_codes,
    program_from_codes,
)
from repro.core.quant import calibrate_act_range
from repro.hw.profile import (
    Profile,
    SiteSpecs,
    as_profile,
    check_band_geometry,
)
from repro.models.registry import get_model
from repro.models.transformer import AnalogPack, cast_params, forward

SpecLike = Union[AnalogSpec, Profile]

#: weight leaves programmed to analog arrays, per family
DENSE_NAMES = {
    "attn": ("wq", "wk", "wv", "wo"),
    "mlp": ("w_gate", "w_up", "w_down"),
}
RWKV_NAMES = {
    "rwkv": ("wr", "wk", "wv", "wg", "wo", "ck", "cv", "cr"),
}
# analog hook names used inside the blocks (see models/*.py dense() calls)
HOOK_NAME = {
    ("attn", "wq"): "wq", ("attn", "wk"): "wk", ("attn", "wv"): "wv",
    ("attn", "wo"): "wo",
    ("mlp", "w_gate"): "w_gate", ("mlp", "w_up"): "w_up",
    ("mlp", "w_down"): "w_down",
    ("rwkv", "wr"): "rwkv_wr", ("rwkv", "wk"): "rwkv_wk",
    ("rwkv", "wv"): "rwkv_wv", ("rwkv", "wg"): "rwkv_wg",
    ("rwkv", "wo"): "rwkv_wo", ("rwkv", "ck"): "rwkv_ck",
    ("rwkv", "cv"): "rwkv_cv", ("rwkv", "cr"): "rwkv_cr",
}

#: the lm_head / tied-embedding projection in an ``lm_program_codes`` dict
HEAD = "head"


def hook_key(key: jax.Array, name: str) -> jax.Array:
    """Fold a hook's programming key from a stable hash of its name.

    A running counter would tie keys to dict-iteration order, silently
    reshuffling every layer's programming noise whenever a projection is
    added or removed (pinned by ``tests/test_serve_engine.py``).
    """
    h = hashlib.blake2s(name.encode(), digest_size=4).digest()
    return jax.random.fold_in(key, int.from_bytes(h, "big") & 0x7FFFFFFF)


def _program_stack_from_codes(pm: ProgrammedMatrix, spec: AnalogSpec,
                              key: jax.Array) -> AnalogWeights:
    """vmap ``program_from_codes`` over the layer axis of a code stack."""
    l = pm.codes.c_pos.shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(l))
    return jax.vmap(lambda c, k: program_from_codes(c, spec, k))(pm, keys)


def _site_resolution(profile: Profile, sites: List[str], n_layers: int):
    """``(bands, {site: [spec-or-None per band]})`` with geometry checks.

    Per-band specs of one site must agree on array geometry (its
    conductance stack is ONE layer-stacked array) —
    :func:`repro.hw.check_band_geometry` raises otherwise.  Bands come
    from rule *identity*, never spec equality, so traced spec fields
    (sweep batching) are safe.
    """
    bands = profile.layer_bands(sites, n_layers) if sites \
        else ((0, n_layers),)
    per_site: Dict[str, List[Optional[AnalogSpec]]] = {}
    for name in sites:
        specs = []
        for lo, _hi in bands:
            sp = profile.resolve(name, lo)
            specs.append(sp if isinstance(sp, AnalogSpec) else None)
        analog = [s for s in specs if s is not None]
        if analog:
            check_band_geometry(name, analog)
        per_site[name] = specs
    return bands, per_site


def lm_hook_names(cfg: ModelConfig) -> List[str]:
    """Every potential analog layer-hook name for this family, in the
    stable programming order (head excluded)."""
    groups = RWKV_NAMES if cfg.rwkv else DENSE_NAMES
    return [HOOK_NAME[(parent, leaf)]
            for parent, leaves in groups.items() for leaf in leaves]


def lm_program_codes(cfg: ModelConfig, params: dict, spec: SpecLike,
                     *, include_head: bool = True,
                     ) -> Dict[str, ProgrammedMatrix]:
    """Quantize + map every analog hook of the LM to integer code stacks.

    The deterministic half of :func:`program_lm`: independent of the
    programming key, ``error.alpha``, and ``on_off_ratio``, hence cacheable
    per ``(per-site mapping signature, params hash)`` across trials and
    design points (see ``repro.sweep.serve_eval``).  Layer hooks carry
    codes stacked over layers; the head (``HEAD``) is a plain 2-D matrix.

    ``spec`` may be one global :class:`AnalogSpec` or a
    :class:`repro.hw.Profile`; sites the profile resolves to ``digital``
    at every layer are omitted (they serve through the exact digital
    matmul).  Codes use the site's own mapping, which is band-uniform per
    site (geometry check in :func:`program_lm_from_codes`).
    """
    profile = as_profile(spec)
    groups = RWKV_NAMES if cfg.rwkv else DENSE_NAMES
    codes: Dict[str, ProgrammedMatrix] = {}
    if "layers" not in params:
        raise ValueError(
            f"family {cfg.family!r} ({cfg.name}) has no 'layers' parameter "
            f"stack; lm_program_codes supports the unified transformer "
            f"families (dense / moe / vlm / ssm-rwkv) — see DESIGN.md "
            f"§Arch-applicability")
    cp = params["layers"]
    n_digital = 0
    for parent, leaves in groups.items():
        for leaf in leaves:
            if parent not in cp or leaf not in cp[parent]:
                continue
            name = HOOK_NAME[(parent, leaf)]
            site_spec = profile.first_analog(name, cfg.n_layers)
            if site_spec is None:
                n_digital += 1
                continue
            w_stack = cp[parent][leaf].astype(jnp.float32)
            codes[name] = jax.vmap(
                lambda w, sp=site_spec: program_codes(w, sp))(w_stack)
    if not codes:
        if n_digital:
            raise ValueError(
                f"the profile resolves every projection hook of family "
                f"{cfg.family!r} ({cfg.name}) to 'digital'; at least one "
                f"site must be analog to program a pack (rules: "
                f"{[r.pattern for r in profile.rules]}, default "
                f"{'analog' if isinstance(profile.default, AnalogSpec) else 'digital'})")
        raise ValueError(
            f"no analog hooks found for family {cfg.family!r} ({cfg.name}): "
            f"expected {'rwkv' if cfg.rwkv else 'attn/mlp'} projection "
            f"leaves {sorted(n for g in groups.values() for n in g)} under "
            f"params['layers']")
    head_spec = profile.resolve(HEAD)
    if include_head and isinstance(head_spec, AnalogSpec):
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        codes[HEAD] = program_codes(w.astype(jnp.float32), head_spec)
    return codes


def program_lm_from_codes(cfg: ModelConfig,
                          codes: Dict[str, ProgrammedMatrix],
                          spec: SpecLike, key: jax.Array) -> AnalogPack:
    """Conductance-convert + perturb cached code stacks into a pack.

    The per-trial half of :func:`program_lm`: tracer-safe in every site's
    ``error.alpha`` / ``mapping.on_off_ratio``, so the sweep engine vmaps
    it over trial keys and batches design points through one compilation.
    Key schedule: ``fold_in(hook_key(key, name), layer)`` with *absolute*
    layer indices — a site's programming noise is invariant to band
    structure and to what the rest of the network runs on.
    """
    profile = as_profile(spec)
    sites = [n for n in codes if n != HEAD]
    l = cfg.n_layers
    bands, per_site = _site_resolution(profile, sites, l)

    layer_weights: Dict[str, AnalogWeights] = {}
    for name in sites:
        layer_weights[name] = _program_site_stack(
            codes[name], per_site[name], bands, hook_key(key, name))

    head, head_spec = None, None
    if HEAD in codes:
        hs = profile.resolve(HEAD)
        if not isinstance(hs, AnalogSpec):
            raise ValueError(
                "codes include the 'head' site but the profile resolves "
                "it to 'digital'; rebuild codes with this profile "
                "(lm_program_codes omits digital sites)")
        head_spec = hs
        head = program_from_codes(codes[HEAD], hs, hook_key(key, HEAD))

    def _geom(name: str) -> AnalogSpec:
        return next(s for s in per_site[name] if s is not None)

    band_specs = tuple(
        SiteSpecs(tuple(
            (n, per_site[n][b]) for n in sites if per_site[n][b] is not None))
        for b in range(len(bands)))
    zeros = {n: jnp.zeros((l, _geom(n).mapping.n_slices))
             for n in layer_weights}
    ones = {n: jnp.ones((l, _geom(n).mapping.n_slices))
            for n in layer_weights}
    s_head = head_spec.mapping.n_slices if head_spec is not None else 1
    return AnalogPack(
        profile=profile, bands=bands, band_specs=band_specs,
        layer_weights=layer_weights,
        layer_lo=zeros, layer_hi=ones,
        layer_act={}, head=head,
        head_lo=jnp.zeros((s_head,)), head_hi=jnp.ones((s_head,)),
        head_act=None, head_spec=head_spec, collect=False,
    )


def _program_site_stack(pm: ProgrammedMatrix,
                        specs_per_band: List[Optional[AnalogSpec]],
                        bands: Tuple[Tuple[int, int], ...],
                        key: jax.Array) -> AnalogWeights:
    """Program one site's layer stack, per band, into one stacked array.

    The single-band case is exactly the legacy path (one vmap over all
    layers).  Banded sites program each band with its own spec and
    concatenate — shapes agree because per-site array geometry is
    band-uniform; layers falling in a ``digital`` band are programmed
    with the site's geometry spec purely as stack filler (the scan never
    routes them analog).
    """
    if len(bands) == 1:
        return _program_stack_from_codes(pm, specs_per_band[0], key)
    geom = next(s for s in specs_per_band if s is not None)
    parts = []
    for (lo, hi), sp in zip(bands, specs_per_band):
        sub = jax.tree.map(lambda a: a[lo:hi], pm)
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(lo, hi))
        spec_b = sp if sp is not None else geom
        parts.append(jax.vmap(
            lambda c, k: program_from_codes(c, spec_b, k))(sub, keys))
    return jax.tree.map(lambda *p: jnp.concatenate(p, axis=0), *parts)


def _age_weights(aw: AnalogWeights, spec: AnalogSpec, t_drift, t_fault,
                 key: jax.Array) -> AnalogWeights:
    """Drift + fault one programmed matrix to the given ages."""
    from repro.core.analog import age_conductances

    g_pos, g_neg, g_unit = age_conductances(
        aw.g_pos, aw.g_neg, aw.g_unit, spec, key,
        t_drift=t_drift, t_fault=t_fault)
    return dataclasses.replace(aw, g_pos=g_pos, g_neg=g_neg, g_unit=g_unit)


def _age_site_stack(aw: AnalogWeights,
                    specs_per_band: List[Optional[AnalogSpec]],
                    bands: Tuple[Tuple[int, int], ...],
                    key: jax.Array,
                    t_drift_by_band: List[float],
                    t_fault_by_band: List[float]) -> AnalogWeights:
    """Age one site's layer stack, per band, mirroring the programming
    key schedule (``fold_in(site key, absolute layer)``) so aging is
    band-structure-invariant and replayable."""
    parts = []
    for (lo, hi), sp, td, tf in zip(bands, specs_per_band,
                                    t_drift_by_band, t_fault_by_band):
        sub = jax.tree.map(lambda a: a[lo:hi], aw)
        if sp is None or not sp.aging_on:
            parts.append(sub)
            continue
        keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(
            jnp.arange(lo, hi))
        parts.append(jax.vmap(
            lambda w, k: _age_weights(w, sp, td, tf, k))(sub, keys))
    if len(parts) == 1:
        return parts[0]
    return jax.tree.map(lambda *p: jnp.concatenate(p, axis=0), *parts)


def age_pack(pack: AnalogPack, t, key: jax.Array, *,
             t_drift_by_band=None, t_fault_by_band=None) -> AnalogPack:
    """Deterministic device state of ``pack`` at age ``t`` (t0 units).

    Applies each site's own :class:`~repro.core.errors.DriftModel` /
    :class:`~repro.core.errors.FaultModel` (per band — heterogeneous
    profiles age heterogeneously) to the pack's conductances.  Keys fold
    exactly like programming keys — ``fold_in(hook_key(key, name),
    absolute_layer)`` — so aging is replayable (same pack, t, key =
    bit-identical result) and cache-safe.  At ``t = 1``, or with every
    drift/fault model disabled, the returned pack is bit-identical to
    ``pack`` (the all-disabled case returns ``pack`` itself).

    ``t_drift_by_band``/``t_fault_by_band`` override the uniform ``t``
    per band (the healer's per-band reprogram ages); the head always
    ages at the uniform ``t``.
    """
    n_bands = len(pack.bands)
    td = list(t_drift_by_band) if t_drift_by_band is not None \
        else [t] * n_bands
    tf = list(t_fault_by_band) if t_fault_by_band is not None \
        else [t] * n_bands
    changed = False
    layer_weights = {}
    for name, aw in pack.layer_weights.items():
        specs = [ss.get(name) for ss in pack.band_specs]
        if not any(s is not None and s.aging_on for s in specs):
            layer_weights[name] = aw
            continue
        changed = True
        layer_weights[name] = _age_site_stack(
            aw, specs, pack.bands, hook_key(key, name), td, tf)
    head = pack.head
    if head is not None and pack.head_spec.aging_on:
        changed = True
        head = _age_weights(head, pack.head_spec, t, t,
                            hook_key(key, HEAD))
    if not changed:
        return pack
    return dataclasses.replace(pack, layer_weights=layer_weights, head=head)


def program_lm(cfg: ModelConfig, params: dict, spec: SpecLike,
               key: jax.Array, *, include_head: bool = True) -> AnalogPack:
    """Program the LM's weight-stationary projections onto analog arrays.

    ``spec``: one global :class:`AnalogSpec` (uniform hardware — the
    legacy API, bit-identical) or a :class:`repro.hw.Profile` resolving
    each site to its own spec.
    """
    codes = lm_program_codes(cfg, params, spec, include_head=include_head)
    return program_lm_from_codes(cfg, codes, spec, key)


def calibrate_lm(cfg: ModelConfig, params: dict, pack: AnalogPack,
                 calib_tokens: jax.Array,
                 prefix_embeds=None) -> AnalogPack:
    """Two-phase range calibration; returns a serving-ready pack.

    Idempotent: any calibration already on ``pack`` is stripped before
    the collect passes, so recalibrating an aged/healed pack is a pure
    function of (conductances, tokens) — otherwise the installed clips
    would perturb the collected statistics and calibration would walk on
    every heal (``repro.serve.health.PackManager.recalibrate``)."""
    api = get_model(cfg)
    pack = dataclasses.replace(pack, layer_lo={}, layer_hi={}, layer_act={},
                               head_lo=None, head_hi=None, head_act=None)

    # ---- phase 1: activation clip ranges (digital run, collect inputs) ---
    pack1 = dataclasses.replace(pack, collect=True)
    _, aux1 = api.forward(cfg, params, calib_tokens, pack=pack1,
                          **({"prefix_embeds": prefix_embeds}
                             if prefix_embeds is not None else {}))
    act = {}
    for k, v in aux1.items():
        if k.startswith("act/"):
            act[k[len("act/"):]] = v            # (L,) per-layer clip
    pack2 = dataclasses.replace(pack, layer_act=act, collect=True)

    # ---- phase 2: pre-ADC ranges with activation clips installed ---------
    _, aux2 = api.forward(cfg, params, calib_tokens, pack=pack2,
                          **({"prefix_embeds": prefix_embeds}
                             if prefix_embeds is not None else {}))
    lo, hi = {}, {}
    for k, v in aux2.items():
        if not k.startswith("adc/"):
            continue
        name = k[len("adc/"):]
        lo_s, hi_s = v[..., 0], v[..., 1]       # (L, S)
        if pack.site_spec(name).mapping.sliced:
            lo_s, hi_s = jax.vmap(cal.constrain_power_of_two)(lo_s, hi_s)
        lo[name], hi[name] = lo_s, hi_s

    # head calibration on the true final-norm hiddens (emitted by the
    # collect forward), under the head site's own resolved spec
    head_lo, head_hi, head_act = pack.head_lo, pack.head_hi, None
    if pack.head is not None:
        from repro.core.analog import analog_matmul

        x = aux2["final_hidden"].reshape(-1, cfg.d_model)
        _, head_act = calibrate_act_range(x, pack.head_spec.input_bits)
        _, stats = analog_matmul(
            x, pack.head, pack.head_spec, act_hi=head_act, collect=True)
        head_lo, head_hi = stats[:, 0], stats[:, 1]

    return dataclasses.replace(
        pack, layer_lo=lo, layer_hi=hi, layer_act=act,
        head_lo=head_lo, head_hi=head_hi, head_act=head_act, collect=False,
    )


def analog_eval_metrics(cfg: ModelConfig, params: dict, pack: AnalogPack,
                        tokens: jax.Array, targets: jax.Array,
                        ) -> Dict[str, jax.Array]:
    """Teacher-forced serving metrics of the analog model.

    Returns ``{"loss": cross-entropy, "top1": next-token accuracy}`` —
    the per-design-point metrics of the LM accuracy sweeps.
    """
    logits, _ = forward(cfg, params, tokens, pack=pack, remat=False)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    top1 = jnp.mean((jnp.argmax(logits, axis=-1) == targets)
                    .astype(jnp.float32))
    return {"loss": jnp.mean(logz - gold), "top1": top1}


def analog_eval_loss(cfg: ModelConfig, params: dict, pack: AnalogPack,
                     tokens: jax.Array, targets: jax.Array) -> jax.Array:
    """Cross-entropy of the analog model (accuracy metric for sweeps)."""
    return analog_eval_metrics(cfg, params, pack, tokens, targets)["loss"]


def decode_lm(cfg: ModelConfig, params: dict, prompts: jax.Array,
              n_new: int, *, pack: Optional[AnalogPack] = None) -> jax.Array:
    """Batched multi-request greedy serving: prefill + scanned decode.

    ``prompts``: (B, S) int32 prompt batch.  Returns (B, n_new) generated
    tokens, every matmul routed through the analog pack when one is given
    — the serving configuration (KV-cached decode, not teacher forcing)
    the LM sweeps measure via ``decode_match``.
    """
    api = get_model(cfg)
    if api.decode_loop is None:
        from repro.models.registry import decode_loop_families

        raise ValueError(
            f"family {cfg.family!r} ({cfg.name}) has no batched decode "
            f"loop; decode_lm serves families "
            f"{sorted(decode_loop_families())} (encoder-decoder needs "
            f"per-utterance encoder state, see repro.models.encdec)")
    return api.decode_loop(cfg, params, prompts, n_new, pack=pack)
