"""Paged-KV continuous batching with prefix sharing.

:class:`PagedServeRuntime` replaces :class:`~repro.serve.runtime.
ServeRuntime`'s dense per-slot KV buffers (``max_slots`` rows of
``max_len`` positions each, mostly empty) with a *paged* layout: one
global pool of fixed-size pages (``models.transformer.init_page_pool``)
plus a per-slot **block table** mapping each slot's logical positions to
pool pages.  Capacity is then pooled — a slot holds exactly
``ceil((prompt + max_new) / page_size)`` pages instead of a full
``max_len`` row — and identical prompt *prefixes* can share pages:

* the **page allocator** (``kvpool.PageAllocator``) refcounts pages;
  page 0 is the sink page retired lanes scatter into;
* the **radix cache** (``kvpool.RadixCache``) maps page-sized token
  chunks to pages holding their K/V.  At admission a request's prompt
  is matched against it; whole-page hits are *retained* and reused as
  the request's leading block-table entries, and only the remaining
  suffix runs through prefill (``transformer.prefill_cached``).  Shared
  pages are always full, hence immutable — extension writes land past
  the shared region in the extender's own pages, so sharing is
  copy-on-extend with no copying;
* decode is one jitted step over the whole slot batch, exactly like the
  dense runtime, with the block table passed as *traced* data — the
  allocator rewrites it every admission without recompiling
  (``tools/analyze.py --contracts`` pins the compile count).

**Exactness contract** (the reason the dense runtime stays around as
the differential oracle): with ``max_len % page_size == 0`` the gathered
paged view ``pool[ptab]`` has the same ``(B, max_len)`` geometry as a
dense slot row, runs through the *same* ``streaming_attention`` with the
same ``kv_len`` masking, and a cold prefill is literally the same
``prefill_ragged`` call — so the paged runtime emits **bit-identical
tokens** to the dense runtime, greedy or seeded sampling, digital or
analog pack (pinned token-for-token by ``tests/test_paged.py``).
Prefix hits stay on the contract because ``prefill_cached`` computes
the suffix over the cached K/V with the same masked-softmax math a cold
prefill would (pinned bitwise at the model layer), and cached pages by
construction hold the bitwise-identical K/V the cold path would have
recomputed.  ``backend="pallas"`` swaps the gather for the in-kernel
block-table gather (``kernels.paged``) — numerically equivalent flash
decode, not bit-identical to the gather path, so it is opt-in.

Analog invariant: every matmul — shared-prefix suffixes included —
still routes through the :class:`AnalogPack`; sharing skips
*recomputation* of identical results, never the analog path, and
programming/sampling key derivations are untouched.
"""

from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.registry import get_model
from repro.serve.kvpool import (
    SINK_PAGE,
    PageAllocator,
    PagePoolExhausted,
    RadixCache,
    full_pages,
    pages_needed,
    shareable_prefix,
)
from repro.serve.runtime import (
    ServeRuntime,
    SlotState,
    _Pending,
    _pow2_at_least,
    request_key,
    sample_tokens,
)


class PagedServeRuntime(ServeRuntime):
    """:class:`ServeRuntime` over a paged KV pool with prefix sharing.

    Additional parameters
    ---------------------
    page_size:    tokens per KV page.  ``max_len`` must be a multiple
                  (the geometry that makes the gathered paged view
                  bit-identical to a dense slot row — see the module
                  docstring).
    num_pages:    pool size, sink page included.  Default
                  ``1 + max_slots * (max_len / page_size)`` — capacity
                  parity with the dense runtime; shrink it to pool
                  capacity instead (requests then wait at admission
                  when the pool is full, FIFO order preserved).
    prefix_cache: keep completed prompts' full pages in the radix cache
                  so identical prefixes prefill once (on by default).
    backend:      ``"gather"`` (default) decodes over the jnp-gathered
                  view — the bit-exact configuration; ``"pallas"`` uses
                  the in-kernel block-table gather kernel.

    Everything else — sampler, analog pack / manager+clock+heal, EOS,
    TTFT measurement — behaves exactly as in the dense runtime.  Gang
    (static-batching) mode is dense-only: it exists as the servebench
    baseline and has no paged counterpart.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        *,
        page_size: int = 8,
        num_pages: Optional[int] = None,
        prefix_cache: bool = True,
        backend: str = "gather",
        max_slots: int = 8,
        max_len: int = 64,
        **kw,
    ):
        if kw.get("gang"):
            raise ValueError(
                "the paged runtime has no gang mode; use the dense "
                "ServeRuntime as the static-batching baseline")
        if kw.get("attn_backend", "stream") != "stream":
            # decode_step_paged has its own gather/pallas backends; the
            # flash-decode kernel reads the *dense* per-slot cache
            raise ValueError(
                "the paged runtime ignores attn_backend (its decode path "
                "is decode_step_paged); use backend='pallas' for the "
                "paged-attention kernel, or the dense ServeRuntime for "
                "flash decode")
        if backend not in ("gather", "pallas"):
            raise ValueError(f"unknown paged backend {backend!r}; "
                             "choose 'gather' or 'pallas'")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if max_len % page_size:
            raise ValueError(
                f"max_len={max_len} must be a multiple of "
                f"page_size={page_size}: equal geometry between the "
                f"gathered paged view and a dense slot row is what pins "
                f"paged decode bit-identical to the dense runtime")
        api = get_model(cfg)
        if (api.init_page_pool is None or api.prefill_cached is None
                or api.decode_step_paged is None):
            raise ValueError(
                f"family {cfg.family!r} has no paged-KV support (needs "
                f"ModelApi.init_page_pool + prefill_cached + "
                f"decode_step_paged)")
        self.page_size = int(page_size)
        self.backend = backend
        self._np = max_len // self.page_size      # block-table width
        self.num_pages = (1 + max_slots * self._np if num_pages is None
                          else int(num_pages))
        if self.num_pages < 1 + self._np:
            raise ValueError(
                f"num_pages={self.num_pages} cannot hold even one "
                f"full-length request ({self._np} pages + sink)")
        self._use_prefix_cache = bool(prefix_cache)
        super().__init__(cfg, params, max_slots=max_slots, max_len=max_len,
                         **kw)

    # -- state ------------------------------------------------------------

    def reset(self) -> None:
        self._alloc = PageAllocator(self.num_pages)
        self._radix = (RadixCache(self._alloc, self.page_size)
                       if self._use_prefix_cache else None)
        self._resv: Dict[str, Tuple[List[int], int]] = {}
        self._ptab = np.zeros((self.max_slots, self._np), np.int32)
        self._slot_pages: List[List[int]] = [[] for _ in range(self.max_slots)]
        super().reset()
        self._stats.update(prefix_hits=0, prefix_tokens_reused=0,
                           cache_evictions=0, admission_stalls=0)

    def _init_layers(self):
        return self._api.init_page_pool(self.cfg, self.num_pages,
                                        self.page_size)

    # -- admission ---------------------------------------------------------

    def _reserve(self, req: _Pending) -> bool:
        """Claim pages for the queue head: radix-match its prompt, retain
        the shared whole-page prefix, allocate the rest.  On exhaustion,
        evict LRU cache-only pages; if still short, leave the request
        queued (capacity frees as in-flight requests complete)."""
        ps = self.page_size
        plen = int(req.prompt.size)
        total = pages_needed(plen + req.max_new, ps)
        shared: List[int] = []
        ctx = 0
        if self._radix is not None:
            match = self._radix.match(req.prompt.tolist())
            ctx = shareable_prefix(len(match), plen, ps)
            shared = match[:ctx // ps]
            if shared:
                # take slot references before any eviction can release
                # the cache's own references on these pages
                self._alloc.retain(shared)
        n_new = total - len(shared)
        if n_new > self._alloc.free_pages and self._radix is not None:
            self._stats["cache_evictions"] += self._radix.evict(n_new)
        try:
            fresh = self._alloc.alloc(n_new)
        except PagePoolExhausted:
            if shared:
                self._alloc.release(shared)
            self._stats["admission_stalls"] += 1
            return False
        pages = shared + fresh
        if self._radix is not None:
            # register the prompt's full pages now: same-batch followers
            # match them and are grouped *after* this request (ascending
            # ctx), so their gathers read this prefill's pool writes
            self._radix.insert(req.prompt.tolist(),
                               pages[:full_pages(plen, ps)])
        if ctx:
            self._stats["prefix_hits"] += 1
            self._stats["prefix_tokens_reused"] += ctx
        self._resv[str(req.uid)] = (pages, ctx)
        return True

    def _group_key(self, req: _Pending) -> Tuple:
        pages, ctx = self._resv[str(req.uid)]
        return (ctx, self._bucket_for(req.prompt.size - ctx))

    def _free_slot(self, i: int) -> None:
        pages, self._slot_pages[i] = self._slot_pages[i], []
        if pages:
            self._alloc.release(pages)
        self._ptab[i, :] = SINK_PAGE
        super()._free_slot(i)

    # -- prefill -----------------------------------------------------------

    def _prefill_group(self, key: Tuple,
                       items: List[Tuple[_Pending, int]]) -> None:
        ctx, bucket = key
        g = min(_pow2_at_least(len(items)), self.max_slots)
        ncp = ctx // self.page_size
        suffix = np.zeros((g, bucket), np.int32)
        true_lens = np.ones((g,), np.int32)
        slots = np.full((g,), self.max_slots, np.int32)   # dummy -> dropped
        max_new = np.ones((g,), np.int32)
        keys = [jnp.zeros((2,), jnp.uint32)] * g
        ctx_pages = np.zeros((g, ncp), np.int32)          # dummy -> sink
        ptabg = np.zeros((g, self._np), np.int32)
        for j, (req, slot) in enumerate(items):
            pages, rctx = self._resv.pop(str(req.uid))
            if rctx != ctx:
                raise RuntimeError(
                    f"admission group mixed cached-prefix depths: "
                    f"reserved ctx={rctx}, group ctx={ctx}")
            sfx = req.prompt[ctx:]
            suffix[j, :sfx.size] = sfx
            true_lens[j] = sfx.size
            slots[j] = slot
            max_new[j] = req.max_new
            keys[j] = request_key(self._root_key, req.uid)
            ctx_pages[j] = pages[:ncp]
            ptabg[j, :len(pages)] = pages
            self._slot_pages[slot] = pages
            self._ptab[slot, :] = SINK_PAGE
            self._ptab[slot, :len(pages)] = pages
            self._slots[slot] = req
        fnkey = (ctx, bucket, g)
        fn = self._prefill_fns.get(fnkey)
        if fn is None:
            fn = self._prefill_fns[fnkey] = jax.jit(
                self._make_paged_prefill_fn())
        self._state = fn(self._state, self.pack, jnp.asarray(suffix),
                         jnp.asarray(true_lens), jnp.asarray(slots),
                         jnp.asarray(max_new), jnp.stack(keys),
                         jnp.asarray(ctx_pages), jnp.asarray(ptabg))
        self._stats["prefill_calls"] += 1
        if self.measure_ttft:
            jax.block_until_ready(self._state.tok)
        now = time.perf_counter()
        for req, _ in items:
            req.ttft_s = now - req.submit_t
            req.done_step = self._stats["decode_steps"] + req.max_new - 1
            self._stats["ttft_s"].append(req.ttft_s)

    def _make_paged_prefill_fn(self):
        cfg, params = self.cfg, self.params
        api, sampler, eos = self._api, self.sampler, self._eos
        ps, npg = self.page_size, self._np

        def prefill(state: SlotState, pack, suffix, true_lens, slots,
                    max_new, keys, ctx_pages, ptabg) -> SlotState:
            g, s = suffix.shape
            ncp = ctx_pages.shape[1]
            ctx = ncp * ps
            pool = state.layers["attn"]
            if ncp == 0:
                # cold group: literally the dense runtime's prefill call
                # (the paged-vs-dense bitwise contract's cold half)
                logits, pcache = api.prefill_ragged(
                    cfg, params, suffix, true_lens=true_lens, pack=pack)
                kv = pcache["layers"]["attn"]
            else:
                # prefix hit: gather the shared pages into a contiguous
                # context, run only the suffix through the layers
                ctx_cache = {
                    name: pool[name][:, ctx_pages].reshape(
                        pool[name].shape[0], g, ctx,
                        *pool[name].shape[3:])
                    for name in ("k", "v")
                }
                logits, pcache = api.prefill_cached(
                    cfg, params, suffix, true_lens=true_lens,
                    ctx_lens=jnp.full((g,), ctx, jnp.int32),
                    ctx_cache=ctx_cache, pack=pack)
                # only the suffix region is new; shared pages are
                # immutable (always full) and already hold [0, ctx)
                kv = {name: a[:, :, ctx:ctx + s]
                      for name, a in pcache["layers"]["attn"].items()}
            # scatter the suffix K/V into each row's own pages; pad
            # positions (and every dummy-row position) go to the sink
            pos = ctx + jnp.arange(s)[None, :]                    # (1, S)
            valid = jnp.arange(s)[None, :] < true_lens[:, None]   # (G, S)
            pidx = jnp.broadcast_to(jnp.minimum(pos // ps, npg - 1), (g, s))
            pids = jnp.where(valid,
                             jnp.take_along_axis(ptabg, pidx, axis=1),
                             SINK_PAGE)
            offs = jnp.broadcast_to(pos % ps, (g, s))
            new_pool = {"attn": {
                name: pool[name].at[:, pids, offs].set(
                    kv[name].astype(pool[name].dtype))
                for name in ("k", "v")
            }}
            first, keys = sample_tokens(logits[:, -1], keys, sampler)
            cap = state.out.shape[1]
            row = jnp.zeros((g, cap), state.out.dtype).at[:, 0].set(first)
            # a 1-token budget (or immediate EOS) finishes at prefill
            live = (max_new > 1) & (first != eos)
            fill = ctx + true_lens
            return SlotState(
                layers=new_pool,
                length=state.length.at[slots].set(fill, mode="drop"),
                tok=state.tok.at[slots].set(first, mode="drop"),
                active=state.active.at[slots].set(live, mode="drop"),
                emitted=state.emitted.at[slots].set(1, mode="drop"),
                max_new=state.max_new.at[slots].set(max_new, mode="drop"),
                out=state.out.at[slots].set(row, mode="drop"),
                key=state.key.at[slots].set(keys, mode="drop"),
            )

        return prefill

    # -- decode ------------------------------------------------------------

    def _run_decode(self) -> None:
        # the block table is traced data: admissions rewrite it without
        # recompiling the step (repro.analysis contract "paged-decode")
        self._state = self._decode_fn(self._state, self.pack,
                                      jnp.asarray(self._ptab))

    def _make_decode_model(self):
        cfg, params, api = self.cfg, self.params, self._api
        backend = self.backend

        def model(state: SlotState, pack, ptab):
            cache = {"pool": state.layers, "ptab": ptab,
                     "len": state.length}
            logits, cache = api.decode_step_paged(
                cfg, params, state.tok[:, None], cache, pack=pack,
                backend=backend)
            return logits[:, -1], cache["pool"], cache["len"]

        return model

    # -- introspection -----------------------------------------------------

    @property
    def page_stats(self) -> Dict[str, Any]:
        """Live pool occupancy: free/used pages, cached pages, and the
        KV-token capacity actually reserved by resident requests."""
        return {
            "num_pages": self.num_pages,
            "free_pages": self._alloc.free_pages,
            "used_pages": self._alloc.used_pages,
            "pages_cached": (0 if self._radix is None
                             else self._radix.pages_cached),
            "resident_pages": sum(len(p) for p in self._slot_pages),
        }

    def check(self) -> None:
        """Cross-structure invariants (used by the differential tests):
        allocator/radix internal consistency, block tables referencing
        only live pages, and no page aliased across two slots."""
        self._alloc.check()
        if self._radix is not None:
            self._radix.check()
        holders: Dict[int, int] = {}
        for i, pages in enumerate(self._slot_pages):
            if (self._slots[i] is None) and pages:
                raise AssertionError(f"free slot {i} still owns pages")
            if len(set(pages)) != len(pages):
                raise AssertionError(f"slot {i} lists a page twice")
            for p in pages:
                if p == SINK_PAGE:
                    raise AssertionError(f"slot {i} owns the sink page")
                if self._alloc.refcount(p) < 1:
                    raise AssertionError(
                        f"slot {i} references dead page {p}")
                holders[p] = holders.get(p, 0) + 1
        for p, n in holders.items():
            # every holding slot owns one reference (sharing without a
            # matching refcount would be cross-slot aliasing: one slot's
            # free could yank pages out from under another)
            if self._alloc.refcount(p) < n:
                raise AssertionError(
                    f"page {p} held by {n} slots with only "
                    f"{self._alloc.refcount(p)} references")
