"""Host-side paged-KV bookkeeping: page allocator + radix prefix cache.

Pure Python, deliberately jax-free: the device side of paged serving is
a static-shape pool (``models.transformer.init_page_pool``) plus a
block table passed to the jitted step as *traced data*, so all
allocation policy lives here where it is cheap to run per scheduler
tick and easy to property-test (``tests/test_properties.py`` drives
these classes straight from hypothesis strategies).

Conventions shared with the device side:

* **Page 0 is the sink page** — never handed out.  Retired or inactive
  batch lanes keep scattering their decode K/V somewhere; the runtime
  zeroes their block-table rows so those writes land in page 0, which
  no live row's table ever references and no ``kv_len`` mask reaches.
* **Reference counts own pages.**  A page is held once per slot using
  it and once more if the radix cache holds it; it returns to the free
  list exactly when the last reference is released.
* **Prefix sharing is whole-page-granular.**  The radix tree maps
  page-sized token chunks to pages, so a shared page is always full
  and therefore immutable — extension writes always land in the
  extender's own pages (copy-on-extend without any copying).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

SINK_PAGE = 0


class PagePoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class PageAllocator:
    """Refcounted fixed-size page allocator over ``num_pages`` pages.

    Page ``SINK_PAGE`` (0) is reserved and never allocated; the usable
    capacity is ``num_pages - 1``.
    """

    def __init__(self, num_pages: int):
        if num_pages < 2:
            raise ValueError(
                f"need at least 2 pages (sink + 1 usable), got {num_pages}")
        self.num_pages = num_pages
        # stack: pops hand out low page ids first (nicer to inspect)
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs: Dict[int, int] = {}

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._refs)

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` distinct pages with refcount 1 each."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            raise PagePoolExhausted(
                f"requested {n} pages, {len(self._free)} free "
                f"of {self.num_pages - 1} usable")
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def retain(self, pages: Sequence[int]) -> None:
        """Add one reference to each page (pages must be live)."""
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"retain of unallocated page {p}")
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> int:
        """Drop one reference per page; returns how many pages were freed."""
        freed = 0
        for p in pages:
            refs = self._refs.get(p)
            if refs is None:
                raise ValueError(f"double free of page {p}")
            if refs == 1:
                del self._refs[p]
                self._free.append(p)
                freed += 1
            else:
                self._refs[p] = refs - 1
        return freed

    def check(self) -> None:
        """Internal-consistency assertions (used by property tests)."""
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("free list holds duplicates")
        if SINK_PAGE in free or SINK_PAGE in self._refs:
            raise AssertionError("sink page entered circulation")
        if free & set(self._refs):
            raise AssertionError("page both free and allocated")
        if len(free) + len(self._refs) != self.num_pages - 1:
            raise AssertionError("pages leaked or duplicated")
        if any(r < 1 for r in self._refs.values()):
            raise AssertionError("non-positive refcount on a live page")


class _Node:
    __slots__ = ("children", "page", "tick")

    def __init__(self, page: int, tick: int):
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.page = page
        self.tick = tick


class RadixCache:
    """Page-granular radix (trie) cache over prompt prefixes.

    Keys are tuples of ``page_size`` token ids; each node owns one
    reference on the page holding that chunk's K/V.  ``match`` returns
    the pages of the longest cached whole-page prefix; ``insert``
    registers a completed prompt's full pages; ``evict`` drops
    least-recently-used leaf nodes until enough pages are free.

    Because only *full* pages are ever cached and a prompt's total
    fill is always past its full-page region by the time it is
    inserted (the partial last page plus at least one generated token
    live beyond it), cached pages are never written again — sharing is
    copy-on-extend with no copying.
    """

    def __init__(self, alloc: PageAllocator, page_size: int):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.alloc = alloc
        self.page_size = page_size
        self._root: Dict[Tuple[int, ...], _Node] = {}
        self._tick = 0
        self.pages_cached = 0

    def _chunks(self, tokens: Sequence[int]):
        ps = self.page_size
        for i in range(0, (len(tokens) // ps) * ps, ps):
            yield tuple(int(t) for t in tokens[i:i + ps])

    def match(self, tokens: Sequence[int]) -> List[int]:
        """Pages of the longest cached whole-page prefix of ``tokens``.

        The caller owns taking references (``alloc.retain``) on the
        pages it decides to use; matching only refreshes recency.
        """
        self._tick += 1
        pages: List[int] = []
        children = self._root
        for key in self._chunks(tokens):
            node = children.get(key)
            if node is None:
                break
            node.tick = self._tick
            pages.append(node.page)
            children = node.children
        return pages

    def insert(self, tokens: Sequence[int], pages: Sequence[int]) -> int:
        """Register ``tokens``' full-page chunks as cached.

        ``pages[i]`` must hold the K/V of chunk ``i`` (the prompt's
        ordered page list).  Chunks already cached keep their existing
        page (equivalent bit-identical content — the exactness
        invariant); new chunks take one cache reference on the
        caller's page.  Returns the number of newly cached pages.
        """
        self._tick += 1
        added = 0
        children = self._root
        for i, key in enumerate(self._chunks(tokens)):
            if i >= len(pages):
                break
            node = children.get(key)
            if node is None:
                node = _Node(int(pages[i]), self._tick)
                self.alloc.retain([node.page])
                children[key] = node
                added += 1
                self.pages_cached += 1
            else:
                node.tick = self._tick
            children = node.children
        return added

    def evict(self, need_free: int) -> int:
        """Release LRU leaves until ``alloc.free_pages >= need_free``
        (or the cache is empty).  Returns the number of cache entries
        dropped.  Releasing an entry only frees its page if no slot
        still references it."""
        dropped = 0
        while self.alloc.free_pages < need_free:
            leaf = self._lru_leaf()
            if leaf is None:
                break
            parent, key, node = leaf
            self.alloc.release([node.page])
            del parent[key]
            self.pages_cached -= 1
            dropped += 1
        return dropped

    def clear(self) -> int:
        """Drop every cached entry (releases all cache references)."""
        dropped = 0
        while True:
            leaf = self._lru_leaf()
            if leaf is None:
                return dropped
            parent, key, node = leaf
            self.alloc.release([node.page])
            del parent[key]
            self.pages_cached -= 1
            dropped += 1

    def _lru_leaf(self):
        """(parent_children, key, node) of the least-recent leaf."""
        best = None
        stack = [(self._root, k, n) for k, n in self._root.items()]
        while stack:
            parent, key, node = stack.pop()
            if node.children:
                stack.extend(
                    (node.children, k, n) for k, n in node.children.items())
            elif best is None or node.tick < best[2].tick:
                best = (parent, key, node)
        return best

    def check(self) -> None:
        """Internal-consistency assertions (used by property tests)."""
        count = 0
        stack = list(self._root.values())
        while stack:
            node = stack.pop()
            count += 1
            if self.alloc.refcount(node.page) < 1:
                raise AssertionError(
                    f"cached page {node.page} has no live reference")
            if node.page == SINK_PAGE:
                raise AssertionError("sink page cached")
            stack.extend(node.children.values())
        if count != self.pages_cached:
            raise AssertionError("pages_cached out of sync with tree")


def pages_needed(total_tokens: int, page_size: int) -> int:
    """Pages required to hold ``total_tokens`` positions."""
    return -(-int(total_tokens) // int(page_size))


def full_pages(prompt_len: int, page_size: int) -> int:
    """Whole pages exactly covered by a prompt (the cacheable region)."""
    return int(prompt_len) // int(page_size)


def shareable_prefix(match_pages: int, prompt_len: int,
                     page_size: int) -> int:
    """Tokens of cached prefix a request may reuse.

    Whole pages only, and always leaving at least one prompt token to
    run through prefill — the last-token logits must come from a live
    forward pass (also what keeps a fully-cached prompt from skipping
    the analog path entirely).
    """
    if prompt_len < 1:
        return 0
    cap = (prompt_len - 1) // page_size
    return min(int(match_pages), cap) * page_size
