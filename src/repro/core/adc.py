"""ADC models (paper Sec. 2.4, 6): Full Precision Guarantee vs. calibrated
compressing ADCs.

The analog output of one array (one slice, one K-partition, one input-bit
group) is a *normalized* value ``V`` in units of ``G_max * V_in`` — i.e. the
dot product of bit planes against normalized conductances.  The ADC clips
``V`` to ``[lo, hi]`` and quantizes it to ``2**bits`` uniform levels; the
digital value handed onward is the *dequantized* analog level (the periphery
applies the known gain, Sec. 9.2's "tunable op-amp gain stage").

Two resolution policies:

* ``fpg_bits`` implements Eq. (4)/(5): a level for every possible output.
  With the range set to the full analytic output range this reproduces the
  integer dot product exactly in the error-free case (tested).
* calibrated: ``bits`` fixed (typically 8) and ``[lo, hi]`` set from the
  observed signal distribution (inner 99.98% range, Sec. 6.2), with
  per-slice ranges constrained to powers of two of each other so that
  shift-and-add aggregation needs no rescaling.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

#: fraction of probability mass kept inside the calibrated ADC range
CALIB_COVERAGE = 0.9998


def fpg_bits(weight_bits_per_cell: int, input_bits: int, n_rows: int) -> int:
    """Eq. (4)/(5): ADC bits needed for a unique level per possible output."""
    b_w, b_in = weight_bits_per_cell, input_bits
    b_out = b_w + b_in + math.log2(n_rows)
    if not (b_w > 1 and b_in > 1):
        b_out -= 1
    return math.ceil(b_out)


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    """Static ADC description.

    ``style``:
      * ``"none"``        — ideal (no quantization); used to isolate cell
                            errors as in Sec. 5.
      * ``"fpg"``         — resolution from Eq. (4), range = full analytic
                            output range.
      * ``"calibrated"``  — fixed ``bits``, range supplied at call time from
                            the calibration pass.
    """

    style: str = "calibrated"
    bits: int = 8

    def __post_init__(self):
        if self.style not in ("none", "fpg", "calibrated"):
            raise ValueError(
                f"ADCConfig.style must be one of ('none', 'fpg', "
                f"'calibrated'), got {self.style!r}")
        if self.bits < 1:
            raise ValueError(f"ADCConfig.bits must be >= 1, got {self.bits}")


def adc_quantize(
    v: jax.Array,
    lo: jax.Array,
    hi: jax.Array,
    bits: int,
) -> jax.Array:
    """Clip to ``[lo, hi]`` and quantize to ``2**bits`` uniform levels.

    Returns the dequantized analog value of the chosen level.  Deterministic
    (the paper treats ADC quantization as noiseless, Sec. 6.3).
    """
    n_levels = 2 ** bits
    lsb = (hi - lo) / (n_levels - 1)
    lsb = jnp.where(lsb <= 0, 1.0, lsb)  # degenerate range guard
    code = jnp.clip(jnp.round((v - lo) / lsb), 0, n_levels - 1)
    return lo + code * lsb


def fpg_range(
    n_rows: int,
    max_code_g: float,
    *,
    signed_inputs: bool,
    differential: bool,
) -> Tuple[float, float]:
    """Full analytic output range of one array in normalized units.

    Each of ``n_rows`` cells contributes at most ``max_code_g`` (the
    conductance of the top code) times an input-plane value in
    {-1,0,1} (signed) or {0,1} (unsigned).  Differential subtraction makes
    the output signed regardless of input polarity.
    """
    top = n_rows * max_code_g
    if signed_inputs or differential:
        return (-top, top)
    return (0.0, top)


def power_of_two_ranges(needs: jax.Array) -> jax.Array:
    """Constrain per-slice range magnitudes to powers of two of the smallest.

    ``needs``: positive per-slice required half-ranges, shape (S,).  Returns
    granted half-ranges ``>= needs`` with ``granted[s] = base * 2**k_s``
    (Sec. 6.2's shift-and-add compatibility constraint).
    """
    base = jnp.min(needs)
    k = jnp.ceil(jnp.log2(jnp.maximum(needs / base, 1.0)))
    return base * 2.0 ** k


@dataclasses.dataclass(frozen=True)
class CalibratedRange:
    """Per-(layer, slice) ADC limits produced by the calibration pass."""

    lo: jax.Array  # shape (n_slices,) or broadcastable
    hi: jax.Array


def range_from_samples(
    v: jax.Array,
    *,
    coverage: float = CALIB_COVERAGE,
    symmetric: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Inner-``coverage`` percentile range of observed pre-ADC values."""
    tail = (1.0 - coverage) / 2.0 * 100.0
    flat = v.reshape(-1)
    lo = jnp.percentile(flat, tail)
    hi = jnp.percentile(flat, 100.0 - tail)
    if symmetric:
        m = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        return -m, m
    return lo, hi
