"""Bit-line parasitic resistance model (paper Sec. 8, Fig. 19).

Circuit (Fig. 19(a)/(b)): every cell is a linear resistor of normalized
conductance ``g`` from the supply (``V_D = 1``, low-impedance power grid) to
its bit-line node, gated by the input bit.  Adjacent bit-line nodes are
separated by the normalized parasitic resistance ``r = R_p * G_max`` and the
bottom node is held at virtual ground by the column periphery.  Signed
inputs drive opposite-polarity supplies (Marinella et al. [43]), i.e. the
cell sources current toward ``s in {-1, +1}``.

KCL at node ``i`` (0 = top, K-1 = bottom, v_K = 0)::

    (v_{i-1} - v_i)/r * [i>0] + (v_{i+1} - v_i)/r + a_i g_i (s_i - v_i) = 0

with ``a_i = |x_i|`` the gate bit.  This is a symmetric positive-definite
tridiagonal system; we solve it with the Thomas algorithm via two
``lax.scan`` passes, vectorized over (batch, columns).  The column output
current is the current through the bottom segment, ``I = v_{K-1} / r``; by
Kirchhoff it equals the sum of injected cell currents (tested).

In the ideal limit ``r -> 0`` this reduces to ``I = sum_i x_i g_i`` — the
errors the paper studies are exactly the deviation from that.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def parasitics_off(r_hat) -> bool:
    """True iff ``r_hat`` is a *concrete* zero, in any scalar form (Python
    float/int, numpy scalar, concrete jnp array).

    The on/off decision is program structure, never data: a traced value
    always means the solve is in the graph (the sweep engine only batches
    ``r_hat > 0`` points — ``AnalogSpec.parasitics_on``), while a concrete
    zero of any dtype must take the ideal-matmul short-circuit (running
    the Thomas sweep at ``r = 0`` divides by zero into silent NaNs).
    """
    if isinstance(r_hat, jax.core.Tracer):
        return False
    try:
        return float(r_hat) == 0.0
    except TypeError:
        return False


def bitline_currents(
    g: jax.Array,        # (K, N) normalized conductances of one line stack
    x: jax.Array,        # (M, K) signed input plane, values in {-1, 0, +1}
    r_hat,               # normalized parasitic resistance R_p * G_max;
                         # traced scalars run the solve unconditionally
) -> jax.Array:
    """Output currents (M, N) of N bit lines under parasitic resistance.

    The ``r_hat == 0`` short-circuit (see :func:`parasitics_off`) is a
    *program-structure* decision: the sweep engine substitutes traced
    scalars for ``r_hat`` (one compiled program for a whole Fig. 19 axis),
    and a traced value always means the solve is in the graph.
    """
    if parasitics_off(r_hat):
        return x @ g

    a = jnp.abs(x)                                     # gate bits   (M, K)
    s = x                                              # signed source (M, K)
    k = g.shape[0]

    # Per-(sample, row, column) effective quantities.
    gr = a[:, :, None] * g[None, :, :] * r_hat         # (M, K, N) = a*g*r
    rhs = s[:, :, None] * g[None, :, :] * r_hat        # source term * r

    # Tridiagonal coefficients: -v_{i-1} + b_i v_i - v_{i+1} = rhs_i
    # b_0 = 1 + gr_0 (no neighbor above); b_i = 2 + gr_i otherwise.
    b = 2.0 + gr
    b = b.at[:, 0, :].set(1.0 + gr[:, 0, :])

    # Thomas forward sweep over rows: a_i = c_i = -1 (c_{K-1} = 0 handled by
    # the back-substitution never using it).
    def fwd(carry, inp):
        c_prev, d_prev = carry
        b_i, d_i = inp
        denom = b_i + c_prev                 # b_i - a_i * c'_{i-1}, a_i = -1
        c_new = -1.0 / denom
        d_new = (d_i + d_prev) / denom       # (d_i - a_i * d'_{i-1}) / denom
        return (c_new, d_new), (c_new, d_new)

    zeros = jnp.zeros(b.shape[::2], b.dtype)  # (M, N)
    b_t = jnp.moveaxis(b, 1, 0)               # (K, M, N)
    rhs_t = jnp.moveaxis(rhs, 1, 0)
    # First row has no "previous": seed with c_prev = 0, d_prev = 0.
    (_, v_last), _ = lax.scan(fwd, (zeros, zeros), (b_t, rhs_t))

    # The output only needs the bottom-node voltage: the current through the
    # bottom segment is the full column current (Kirchhoff).  d'_{K-1} IS
    # v_{K-1} since c_{K-1} = 0 in back-substitution.
    del k
    return v_last / r_hat


def bitline_voltages_dense(
    g_col: jax.Array,    # (K,) conductances of a single column
    x: jax.Array,        # (K,) signed plane
    r_hat: float,
) -> jax.Array:
    """Dense ``jnp.linalg.solve`` oracle for tests (single column)."""
    k = g_col.shape[0]
    a = jnp.abs(x)
    gr = a * g_col * r_hat
    diag = 2.0 + gr
    diag = diag.at[0].set(1.0 + gr[0])
    mat = (
        jnp.diag(diag)
        - jnp.diag(jnp.ones(k - 1), 1)
        - jnp.diag(jnp.ones(k - 1), -1)
    )
    rhs = x * g_col * r_hat
    return jnp.linalg.solve(mat, rhs)


def injected_current(
    g_col: jax.Array, x: jax.Array, v: jax.Array
) -> jax.Array:
    """Sum of cell currents given node voltages (Kirchhoff check)."""
    return jnp.sum(jnp.abs(x) * g_col * (jnp.sign(x) - v) * (jnp.abs(x) > 0))
