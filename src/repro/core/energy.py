"""Core-level energy & area model (paper Sec. 9.2-9.3, Fig. 21/22, Table 3).

A *core* is everything needed for one full-precision MVM: all weight
slices, differential pairs, K-partitions, and input bits, plus the
integrators, switched-capacitor accumulators, ADCs and shift-and-add logic.

The model is a linear composition of per-event component costs.  The
component constants were fit by non-negative least squares (relative-error
weighted) to the five published design points of Table 3 — the fit
reproduces every design within +-20% energy / +-3% area and the headline
ratios (Design E vs A: 111x energy vs paper 107x, 45x area vs paper 46x).
All constants are for the paper's embedded 40nm SONOS process and a
1152x256 8-bit x 8-bit workload normalization (1 MAC = 2 ops).

Event counts per full MVM of a K x N matrix with ``BITS`` input bits:

  ramp events      S * P * (BITS if digital-accum else 1)    per array ramp
  conversions      N * ramp_events                           per column
  integrations     N * S * d * P * BITS                      current conveyor
  sc events        integrations (analog accum only)          switched-cap
  row drives       K * BITS
  shift-adds       conversions
  cell-bit events  K * N * S * d * BITS * activity * g_avg   array power

where S = #weight slices, d = 2 for differential else 1, P = #K-partitions.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict

from repro.core.analog import AnalogSpec

# ---- fitted constants (see module docstring) ------------------------------
# energy, picojoules per event
E_RAMP_PJ = 0.0          # ramp generator (absorbed into comparator term)
E_CMP_PJ = 3.857         # per-column 8-bit conversion (comparator + count)
E_INT_PJ = 0.4529        # current-conveyor integration window (10 ns)
E_SC_PJ = 0.0            # switched-cap accumulation (absorbed into E_INT)
E_ROW_PJ = 0.2249        # row driver, per row per input bit
E_SA_PJ = 0.0            # shift-and-add (absorbed into E_CMP)
E_CELL_PJ = 0.013235     # active cell-bit at g = 1 (scales with g_avg)

# area, square microns per instance
A_CELL_UM2 = 0.16166     # 2T SONOS cell, 40 nm embedded process
A_ARRAY_UM2 = 0.0
A_COL_UM2 = 13.927       # column periphery (integrator + comparator)
A_ADC_UM2 = 0.0
A_SA_UM2 = 560.35        # parallel shift-and-add unit
A_ISA_UM2 = 94.01        # input-bit S&A (digital accumulation only)

#: default input-bit activity factor (ReLU-skewed activations, Sec. 8)
DEFAULT_ACTIVITY = 0.3


@dataclasses.dataclass(frozen=True)
class CoreCosts:
    energy_pj: float         # per full MVM
    energy_fj_per_op: float  # 1 MAC = 2 ops
    area_mm2: float
    adc_conversions: int
    n_arrays: int

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def _static_counts(spec: AnalogSpec, k: int, n: int):
    m = spec.mapping
    s = m.n_slices
    d = 2 if m.scheme == "differential" else 1
    p = spec.n_partitions(k)
    bits = spec.input_bits
    digital = spec.input_accum == "digital"
    ramp = s * p * (bits if digital else 1)
    conv = n * ramp
    integ = n * s * d * p * bits
    sc = 0 if digital else integ
    row = k * bits
    sa = conv
    return s, d, p, bits, digital, ramp, conv, integ, sc, row, sa


def core_energy(
    spec: AnalogSpec,
    k: int = 1152,
    n: int = 256,
    *,
    g_avg: float,
    activity: float = DEFAULT_ACTIVITY,
) -> float:
    """Energy in pJ for one full-precision MVM.

    ``g_avg`` is the average normalized conductance of the programmed
    arrays (Fig. 6) — the proportional-mapping lever: differential unsliced
    mappings of zero-peaked weight distributions push it to ~0.02 while
    offset mappings sit near 0.5.
    """
    s, d, p, bits, digital, ramp, conv, integ, sc, row, sa = _static_counts(
        spec, k, n
    )
    cell_events = k * n * s * d * bits * activity * g_avg
    return (
        ramp * E_RAMP_PJ
        + conv * E_CMP_PJ
        + integ * E_INT_PJ
        + sc * E_SC_PJ
        + row * E_ROW_PJ
        + sa * E_SA_PJ
        + cell_events * E_CELL_PJ
    )


def core_area(spec: AnalogSpec, k: int = 1152, n: int = 256) -> float:
    """Core area in mm^2."""
    s, d, p, bits, digital, *_ = _static_counts(spec, k, n)
    cells = k * n * s * d
    arrays = s * d * p
    cols = n * s * d * p
    adcs = s * p
    sa_units = n * s * p
    isa_units = n * s * p if digital else 0
    um2 = (
        cells * A_CELL_UM2
        + arrays * A_ARRAY_UM2
        + cols * A_COL_UM2
        + adcs * A_ADC_UM2
        + sa_units * A_SA_UM2
        + isa_units * A_ISA_UM2
    )
    return um2 / 1e6


def core_costs(
    spec: AnalogSpec,
    k: int = 1152,
    n: int = 256,
    *,
    g_avg: float,
    activity: float = DEFAULT_ACTIVITY,
) -> CoreCosts:
    e = core_energy(spec, k, n, g_avg=g_avg, activity=activity)
    ops = 2.0 * k * n
    m = spec.mapping
    d = 2 if m.scheme == "differential" else 1
    return CoreCosts(
        energy_pj=e,
        energy_fj_per_op=e * 1e3 / ops,
        area_mm2=core_area(spec, k, n),
        adc_conversions=spec.adc_conversions_per_mvm(k, n),
        n_arrays=m.n_slices * d * spec.n_partitions(k),
    )


def adc_energy(spec: AnalogSpec, k: int = 1152, n: int = 256, *,
               ramp_scaled: bool = True) -> float:
    """ADC share of one full MVM's energy in pJ, resolution-sensitive.

    The Table-3 component fit prices a conversion at ``E_CMP_PJ``
    regardless of resolution because every fitted design converts at
    8 bits.  A ramp converter counts ``2**bits`` comparator cycles per
    conversion, so ``ramp_scaled=True`` scales the per-conversion energy
    by ``2**(bits - 8)`` — the per-*site* lever heterogeneous profiles
    pull (``benchmarks/hetero_precision.py``): dropping an MLP class
    from 8 to 6 bits cuts its conversion energy 4× on the widest
    matrices of the network.  At 8 bits this reproduces the fitted
    model's ADC term exactly.
    """
    s, d, p, bits, digital, ramp, conv, integ, sc, row, sa = _static_counts(
        spec, k, n
    )
    scale = 2.0 ** (spec.adc.bits - 8) if ramp_scaled else 1.0
    return conv * E_CMP_PJ * scale + ramp * E_RAMP_PJ + sa * E_SA_PJ


def energy_breakdown(
    spec: AnalogSpec, k: int = 1152, n: int = 256, *,
    g_avg: float, activity: float = DEFAULT_ACTIVITY,
) -> Dict[str, float]:
    """Per-component energy in pJ (paper Fig. 22(b))."""
    s, d, p, bits, digital, ramp, conv, integ, sc, row, sa = _static_counts(
        spec, k, n
    )
    cell_events = k * n * s * d * bits * activity * g_avg
    return {
        "adc": ramp * E_RAMP_PJ + conv * E_CMP_PJ + sa * E_SA_PJ,
        "integrator": integ * E_INT_PJ + sc * E_SC_PJ,
        "row_drivers": row * E_ROW_PJ,
        "array": cell_events * E_CELL_PJ,
    }
