"""Weight -> conductance mapping schemes (paper Sec. 2.1, 2.3, 4.1, Fig. 4).

Two axes of the design space:

* **Negative-number handling**: ``offset`` subtraction (Eq. 2/7) versus
  ``differential`` cell pairs (Eq. 3/8).
* **Precision encoding**: *bit slicing* (1/2/4 bits per cell, shift-and-add
  reduction) versus *unsliced* weights (one multi-bit "approximate memory"
  cell per weight, Fig. 2b).

All conductances here are **normalized**: ``g = G / G_max`` in ``[0, 1]``.
A finite On/Off ratio maps the code range onto ``[g_min, 1]`` with
``g_min = 1 / on_off_ratio`` — crucially the *affine* part of that map is
known to the digital periphery and corrected exactly, so in the error-free
limit every scheme reproduces the integer dot product bit-exactly (the
paper's "functionally equivalent in the absence of analog errors").

Integer conventions (see quant.py):

* offset:       w_int in [-(2**(B-1)-1), 2**(B-1)-1]; W_prog = w_int + 2**(B-1)
* differential: w_int in [-(2**M - 1), 2**M - 1] with M magnitude bits;
                M = B - 1 unsliced, M = bpc * ceil((B-1)/bpc) rounded up to
                fully use the sliced range (the paper's 9-bit sliced case).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MappingConfig:
    """Static description of one point in the mapping design space."""

    scheme: str = "differential"          # "differential" | "offset"
    weight_bits: int = 8                  # signed weight precision B
    bits_per_cell: Optional[int] = None   # None => unsliced
    on_off_ratio: float = float("inf")    # G_max / G_min
    unit_column: bool = False             # analog offset column (offset only)

    def __post_init__(self):
        if self.scheme not in ("differential", "offset"):
            raise ValueError(
                f"MappingConfig.scheme must be 'differential' or 'offset', "
                f"got {self.scheme!r}")
        if self.bits_per_cell is not None and self.bits_per_cell not in (1, 2, 4, 8):
            raise ValueError(
                f"MappingConfig.bits_per_cell must be None (unsliced) or "
                f"one of (1, 2, 4, 8), got {self.bits_per_cell!r}")
        if self.unit_column and self.scheme != "offset":
            raise ValueError(
                "MappingConfig.unit_column=True only applies to the "
                f"'offset' scheme, got scheme={self.scheme!r}")

    # ---- derived static properties -------------------------------------
    @property
    def sliced(self) -> bool:
        return self.bits_per_cell is not None

    @property
    def cell_bits(self) -> int:
        """Bits stored per memory cell."""
        if self.sliced:
            return self.bits_per_cell
        # Unsliced: offset needs the full B bits in one cell; differential
        # stores the magnitude (B-1 bits).
        return self.weight_bits if self.scheme == "offset" else self.weight_bits - 1

    @property
    def n_slices(self) -> int:
        if not self.sliced:
            return 1
        total = self.weight_bits if self.scheme == "offset" else self.weight_bits - 1
        return math.ceil(total / self.bits_per_cell)

    @property
    def magnitude_bits(self) -> int:
        """Total magnitude bits represented (differential) or total bits
        (offset)."""
        if self.scheme == "offset":
            return self.n_slices * self.cell_bits if self.sliced else self.weight_bits
        return self.n_slices * self.cell_bits if self.sliced else self.weight_bits - 1

    @property
    def levels_per_cell(self) -> int:
        return 2 ** self.cell_bits

    @property
    def g_min(self):
        """``1 / on_off_ratio`` (0 for an infinite On/Off ratio).

        Tracer-safe: the sweep engine batches design points that differ only
        in ``on_off_ratio`` by substituting a traced scalar, so the infinity
        check must only run for concrete Python floats (``1/inf == 0``
        holds for traced values anyway).
        """
        if isinstance(self.on_off_ratio, (int, float)):
            return 0.0 if math.isinf(self.on_off_ratio) else 1.0 / self.on_off_ratio
        return 1.0 / self.on_off_ratio

    @property
    def cells_per_weight(self) -> int:
        return self.n_slices * (2 if self.scheme == "differential" else 1)

    @property
    def offset_code(self) -> int:
        """Code added to w_int under offset subtraction (2**(B-1))."""
        return 2 ** (self.weight_bits - 1)


# ---------------------------------------------------------------------------
# bit slicing
# ---------------------------------------------------------------------------

def slice_codes(codes: jax.Array, bits_per_cell: int, n_slices: int) -> jax.Array:
    """Split non-negative integer-valued ``codes`` into ``n_slices`` slices of
    ``bits_per_cell`` bits, least-significant slice first.

    Returns shape ``(n_slices,) + codes.shape`` with
    ``sum_s 2**(bpc*s) * slices[s] == codes``.
    """
    c = codes.astype(jnp.int32)
    mask = (1 << bits_per_cell) - 1
    out = []
    for s in range(n_slices):
        out.append(((c >> (bits_per_cell * s)) & mask).astype(codes.dtype))
    return jnp.stack(out, axis=0)


def unslice_codes(slices: jax.Array, bits_per_cell: int) -> jax.Array:
    """Inverse of :func:`slice_codes` (shift-and-add reduction)."""
    n_slices = slices.shape[0]
    weights = jnp.array(
        [2.0 ** (bits_per_cell * s) for s in range(n_slices)], slices.dtype
    )
    return jnp.tensordot(weights, slices, axes=1)


# ---------------------------------------------------------------------------
# code -> conductance
# ---------------------------------------------------------------------------

def codes_to_conductance(codes: jax.Array, cfg: MappingConfig) -> jax.Array:
    """Map integer cell codes in ``[0, L-1]`` to normalized conductances.

    ``g = g_min + (1 - g_min) * code / (L - 1)`` — a linear (proportional
    when ``g_min = 0``) map, Fig. 4.
    """
    lmax = cfg.levels_per_cell - 1
    return cfg.g_min + (1.0 - cfg.g_min) * codes / lmax


def conductance_to_codes(g: jax.Array, cfg: MappingConfig) -> jax.Array:
    """Exact affine inverse of :func:`codes_to_conductance` (the digital
    periphery knows the programmed transfer curve)."""
    lmax = cfg.levels_per_cell - 1
    return (g - cfg.g_min) * lmax / (1.0 - cfg.g_min)


# ---------------------------------------------------------------------------
# weight integer -> programmed conductance stacks
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ProgrammedWeights:
    """Conductance stacks for one weight matrix.

    ``g_pos`` has shape ``(n_slices, K, N)``.  For differential mappings
    ``g_neg`` holds the negative-magnitude lines; for offset mappings
    ``g_neg is None`` and ``g_unit`` optionally holds the unit column
    ``(n_slices, K, 1)``.
    """

    g_pos: jax.Array
    g_neg: Optional[jax.Array]
    g_unit: Optional[jax.Array]


@dataclasses.dataclass(frozen=True)
class ProgrammedCodes:
    """Integer cell-code stacks for one weight matrix, pre-conductance.

    Same layout as :class:`ProgrammedWeights` but in code space
    ``[0, L-1]``.  This is the g_min-*independent* half of programming: the
    sweep engine caches it per ``(mapping, weights)`` and converts to
    conductances in-trace, which lets design points that differ only in
    ``on_off_ratio`` share one compiled evaluation.
    """

    c_pos: jax.Array
    c_neg: Optional[jax.Array]
    c_unit: Optional[jax.Array]


jax.tree_util.register_dataclass(
    ProgrammedCodes, data_fields=("c_pos", "c_neg", "c_unit"), meta_fields=()
)


def program_int_codes(w_int: jax.Array, cfg: MappingConfig) -> ProgrammedCodes:
    """Map signed integer weights to cell-code stacks (error-free)."""
    if cfg.scheme == "offset":
        prog = w_int + cfg.offset_code                       # strictly >= 0
        slices = (
            slice_codes(prog, cfg.cell_bits, cfg.n_slices)
            if cfg.sliced
            else prog[None]
        )
        c_unit = None
        if cfg.unit_column:
            c_unit = slice_codes(
                jnp.full((w_int.shape[0], 1), cfg.offset_code, jnp.int32),
                cfg.cell_bits,
                cfg.n_slices,
            ) if cfg.sliced else jnp.full(
                (1, w_int.shape[0], 1), cfg.offset_code, jnp.int32
            )
        return ProgrammedCodes(c_pos=slices, c_neg=None, c_unit=c_unit)

    # differential: sign-magnitude; one line of each pair stays at code 0.
    mag = jnp.abs(w_int)
    pos = jnp.where(w_int > 0, mag, 0)
    neg = jnp.where(w_int < 0, mag, 0)
    if cfg.sliced:
        sp = slice_codes(pos, cfg.cell_bits, cfg.n_slices)
        sn = slice_codes(neg, cfg.cell_bits, cfg.n_slices)
    else:
        sp, sn = pos[None], neg[None]
    return ProgrammedCodes(c_pos=sp, c_neg=sn, c_unit=None)


def codes_to_weights(pc: ProgrammedCodes, cfg: MappingConfig) -> ProgrammedWeights:
    """Convert code stacks to conductance stacks (the g_min-dependent half)."""
    conv = lambda c: None if c is None else codes_to_conductance(c, cfg)
    return ProgrammedWeights(
        g_pos=conv(pc.c_pos), g_neg=conv(pc.c_neg), g_unit=conv(pc.c_unit)
    )


def program_weights(w_int: jax.Array, cfg: MappingConfig) -> ProgrammedWeights:
    """Map signed integer weights to conductance stacks (error-free)."""
    return codes_to_weights(program_int_codes(w_int, cfg), cfg)


def reconstruct_weights(pw: ProgrammedWeights, cfg: MappingConfig) -> jax.Array:
    """Recover signed integer weights from (possibly perturbed) conductances.

    Used by tests to prove the error-free round trip is exact, and by the
    accuracy model as the *ideal* decoder the digital periphery implements.
    """
    cp = conductance_to_codes(pw.g_pos, cfg)
    if cfg.scheme == "offset":
        codes = unslice_codes(cp, cfg.cell_bits) if cfg.sliced else cp[0]
        return codes - cfg.offset_code
    cn = conductance_to_codes(pw.g_neg, cfg)
    if cfg.sliced:
        return unslice_codes(cp, cfg.cell_bits) - unslice_codes(cn, cfg.cell_bits)
    return cp[0] - cn[0]


def average_conductance(pw: ProgrammedWeights) -> jax.Array:
    """Per-slice mean normalized conductance (paper Fig. 6)."""
    gs = [pw.g_pos] + ([pw.g_neg] if pw.g_neg is not None else [])
    stacked = jnp.concatenate([g.reshape(g.shape[0], -1) for g in gs], axis=-1)
    return jnp.mean(stacked, axis=-1)
