"""The paper's contribution: analog in-situ MVM accuracy simulation.

Public API:

* :class:`repro.core.analog.AnalogSpec` — one point in the design space
  (mapping x errors x ADC x parasitics x array size).
* :func:`repro.core.analog.program` — weights -> perturbed conductances.
* :func:`repro.core.analog.analog_matmul` — simulated analog ``x @ W``.
* :mod:`repro.core.calibrate` — activation/ADC range calibration.
* :mod:`repro.core.energy` — core energy/area model (Table 3).
"""

from repro.core.adc import ADCConfig, adc_quantize, fpg_bits
from repro.core.analog import (
    AnalogSpec,
    AnalogWeights,
    analog_matmul,
    design_a,
    design_e,
    program,
)
from repro.core.errors import (
    ErrorModel,
    sonos,
    state_independent,
    state_proportional,
)
from repro.core.mapping import MappingConfig, ProgrammedWeights, program_weights

__all__ = [
    "ADCConfig",
    "AnalogSpec",
    "AnalogWeights",
    "ErrorModel",
    "MappingConfig",
    "ProgrammedWeights",
    "adc_quantize",
    "analog_matmul",
    "design_a",
    "design_e",
    "fpg_bits",
    "program",
    "program_weights",
    "sonos",
    "state_independent",
    "state_proportional",
]
