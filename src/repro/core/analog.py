"""The analog in-situ MVM simulator — the paper's contribution as a
composable JAX op.

``program`` maps a float weight matrix onto (error-perturbed) conductance
stacks per a :class:`MappingConfig`; ``analog_matmul`` then executes
``y ~= x @ W`` through the full analog pipeline:

  quantize x -> input bit planes -> per-(K-partition, slice) analog dot
  products (optionally through the parasitic bit-line circuit) -> analog
  differential subtraction (differential scheme) -> ADC per digitized
  quantity -> shift-and-add over slices/input bits -> exact affine
  correction for g_min and offsets -> dequantize.

Design notes
------------
* Everything is shaped so XLA sees dense matmuls: bit planes are (B, M, K)
  and conductance stacks (S, P, rows, N); the hot path (differential,
  unsliced, analog input accumulation — the paper's Design A) reduces to a
  single integer-valued matmul per K-partition plus an ADC, and has a fused
  Pallas kernel (``repro.kernels``) selected via ``use_pallas``.
* "Program-time" cell errors are sampled once from an explicit key in
  ``program``; repeated inference trials vmap over keys.
* Calibration (Sec. 6.2) runs ``analog_matmul(..., collect=True)`` which
  returns per-slice pre-ADC percentile ranges instead of applying an ADC.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import adc as adc_lib
from repro.core import parasitics
from repro.core.errors import DriftModel, ErrorModel, FaultModel
from repro.core.mapping import (
    MappingConfig,
    ProgrammedCodes,
    ProgrammedWeights,
    codes_to_weights,
    program_int_codes,
)
from repro.core.quant import (
    QuantizedTensor,
    bit_planes,
    n_input_planes,
    quantize_acts,
    quantize_weights,
)


@dataclasses.dataclass(frozen=True)
class AnalogSpec:
    """Full static description of one analog core design point."""

    mapping: MappingConfig = dataclasses.field(default_factory=MappingConfig)
    adc: adc_lib.ADCConfig = dataclasses.field(default_factory=adc_lib.ADCConfig)
    error: ErrorModel = dataclasses.field(default_factory=ErrorModel)
    input_bits: int = 8
    signed_inputs: bool = True
    input_accum: str = "analog"       # "analog" | "digital"
    max_rows: int = 1152
    r_hat: float = 0.0                # normalized parasitic resistance
    use_pallas: bool = False
    fused: str = "off"                # "off" | "kernel" | "oracle"
    compute_dtype: jnp.dtype = jnp.float32
    drift: DriftModel = dataclasses.field(default_factory=DriftModel)
    fault: FaultModel = dataclasses.field(default_factory=FaultModel)

    def __post_init__(self):
        if self.input_accum not in ("analog", "digital"):
            raise ValueError(
                f"AnalogSpec.input_accum must be 'analog' or 'digital', "
                f"got {self.input_accum!r}")
        if self.fused not in ("off", "kernel", "oracle"):
            raise ValueError(
                f"AnalogSpec.fused must be 'off', 'kernel' or 'oracle', "
                f"got {self.fused!r}")
        if self.input_bits < 1:
            raise ValueError(
                f"AnalogSpec.input_bits must be >= 1, got {self.input_bits}")
        if self.max_rows < 1:
            raise ValueError(
                f"AnalogSpec.max_rows must be >= 1, got {self.max_rows}")

    @property
    def parasitics_on(self) -> bool:
        """Static program-structure bit: is the bit-line solve in-graph?

        ``r_hat`` itself is allowed to be a *traced* scalar (the sweep
        engine batches a whole parasitic axis through one compilation),
        but whether the tridiagonal solve exists in the program at all is
        a compile-time property.  Concrete ``r_hat``: on iff nonzero
        (any scalar form — see :func:`parasitics.parasitics_off`).  A
        traced ``r_hat`` always means "on" — only ``r_hat > 0`` points
        are batched; the ``r_hat == 0`` short-circuit is a different
        compiled program, never a traced value.
        """
        return not parasitics.parasitics_off(self.r_hat)

    @property
    def aging_on(self) -> bool:
        """Static program-structure bit: any time-dependent device-state
        process in-graph?  Like :attr:`parasitics_on`, the *kind* of each
        process is compile-time while its magnitude (``drift.nu``,
        ``drift.t``, ``fault.rate``, ``fault.t``) may be traced."""
        return self.drift.kind != "none" or self.fault.kind != "none"

    @property
    def n_planes(self) -> int:
        return n_input_planes(self.input_bits, self.signed_inputs)

    def n_partitions(self, k: int) -> int:
        return max(1, math.ceil(k / self.max_rows))

    def rows_per_partition(self, k: int) -> int:
        return math.ceil(k / self.n_partitions(k))

    def fpg_adc_bits(self, k: int) -> int:
        """Eq. (4)/(5) resolution for this design at matrix depth ``k``.

        One extra weight bit when the analog output is signed: differential
        subtraction (the paper's Table 3 numbers, e.g. Design A's
        B_out = 26.2 = 8 + 8 + log2(1152)) or signed input voltages.
        """
        signed_out = (
            self.mapping.scheme == "differential" or self.signed_inputs
        )
        bw = self.mapping.cell_bits + (1 if signed_out else 0)
        bin_eff = self.input_bits if self.input_accum == "analog" else 1
        return adc_lib.fpg_bits(bw, bin_eff, self.rows_per_partition(k))

    def adc_conversions_per_mvm(self, k: int, n: int) -> int:
        """ADC quantizations for one full-precision MVM (Sec. 2.2/9)."""
        per_bit = 1 if self.input_accum == "analog" else self.n_planes
        return self.n_partitions(k) * self.mapping.n_slices * per_bit * n


#: Paper Design A — the recommended configuration (Table 3).
def design_a(error: Optional[ErrorModel] = None, **kw) -> AnalogSpec:
    return AnalogSpec(
        mapping=MappingConfig(scheme="differential", weight_bits=8,
                              bits_per_cell=None, on_off_ratio=1e4),
        adc=adc_lib.ADCConfig(style="calibrated", bits=8),
        error=error or ErrorModel(),
        input_accum="analog",
        max_rows=1152,
        **kw,
    )


#: Paper Design E — the ISAAC-like offset/FPG baseline (Table 3).
def design_e(error: Optional[ErrorModel] = None, **kw) -> AnalogSpec:
    return AnalogSpec(
        mapping=MappingConfig(scheme="offset", weight_bits=8, bits_per_cell=2),
        adc=adc_lib.ADCConfig(style="calibrated", bits=8),
        error=error or ErrorModel(),
        input_accum="digital",
        max_rows=72,
        **kw,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AnalogWeights:
    """Programmed conductances + dequantization metadata for one matrix."""

    g_pos: jax.Array                 # (S, P, rows, N)
    g_neg: Optional[jax.Array]       # (S, P, rows, N) | None
    g_unit: Optional[jax.Array]      # (S, P, rows, 1) | None
    w_scale: jax.Array               # scalar quant scale
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))


def _partition(arr: jax.Array, k: int, p: int, rows: int) -> jax.Array:
    """(S, K, N) -> (S, P, rows, N), zero-padding K to P*rows."""
    s, _, n = arr.shape
    pad = p * rows - k
    if pad:
        arr = jnp.pad(arr, ((0, 0), (0, pad), (0, 0)))
    return arr.reshape(s, p, rows, n)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ProgrammedMatrix:
    """Deterministic half of :func:`program`: integer code stacks + scale.

    Everything here is independent of the trial PRNG key *and* of the
    On/Off ratio, so the sweep engine (``repro.sweep``) caches one
    ``ProgrammedMatrix`` per ``(mapping signature, weights hash)`` and
    amortizes quantize+map across all trials and all design points that
    share a compiled shape.
    """

    codes: ProgrammedCodes
    w_scale: jax.Array
    k: int = dataclasses.field(metadata=dict(static=True))
    n: int = dataclasses.field(metadata=dict(static=True))


def program_codes(w: jax.Array, spec: AnalogSpec) -> ProgrammedMatrix:
    """Quantize + map a float weight matrix ``(K, N)`` to integer codes."""
    if w.ndim != 2:
        raise ValueError(
            f"program_codes expects a 2-D (K, N) weight matrix, got shape "
            f"{w.shape}")
    k, n = w.shape
    m = spec.mapping
    mag_bits = None if m.scheme == "offset" else m.magnitude_bits
    qt = quantize_weights(w, m.weight_bits, magnitude_bits=mag_bits)
    pc = program_int_codes(qt.values.astype(jnp.int32), m)
    return ProgrammedMatrix(
        codes=pc, w_scale=qt.scale.astype(jnp.float32), k=k, n=n
    )


def program_from_codes(
    pm: ProgrammedMatrix,
    spec: AnalogSpec,
    key: Optional[jax.Array] = None,
) -> AnalogWeights:
    """Conductance-convert + partition + perturb cached code stacks.

    This is the per-trial half of :func:`program`; it is tracer-safe in
    ``spec.error.alpha`` and ``spec.mapping.on_off_ratio`` so vmapped
    trials and scalar-batched design points go through one compilation.
    """
    k, n = pm.k, pm.n
    pw = codes_to_weights(pm.codes, spec.mapping)

    p = spec.n_partitions(k)
    rows = spec.rows_per_partition(k)
    g_pos = _partition(pw.g_pos, k, p, rows)
    g_neg = _partition(pw.g_neg, k, p, rows) if pw.g_neg is not None else None
    g_unit = _partition(pw.g_unit, k, p, rows) if pw.g_unit is not None else None

    if spec.error.kind != "none" and key is not None:
        kp, kn, ku = jax.random.split(key, 3)
        g_pos = spec.error.perturb(g_pos, kp)
        g_neg = spec.error.perturb(g_neg, kn) if g_neg is not None else None
        g_unit = spec.error.perturb(g_unit, ku) if g_unit is not None else None

    if spec.aging_on and key is not None:
        g_pos, g_neg, g_unit = age_conductances(
            g_pos, g_neg, g_unit, spec, jax.random.fold_in(key, _AGE_FOLD))

    dt = spec.compute_dtype
    return AnalogWeights(
        g_pos=g_pos.astype(dt),
        g_neg=g_neg.astype(dt) if g_neg is not None else None,
        g_unit=g_unit.astype(dt) if g_unit is not None else None,
        w_scale=pm.w_scale,
        k=k,
        n=n,
    )


#: disjoint fold tag for aging keys — programming noise consumes ``key``
#: via ``split``, so folding keeps the two RNG streams independent and
#: leaves the error-model draws bit-identical when aging is off.
_AGE_FOLD = 0x616765  # "age"


def age_conductances(
    g_pos: jax.Array,
    g_neg: Optional[jax.Array],
    g_unit: Optional[jax.Array],
    spec: AnalogSpec,
    key: jax.Array,
    *,
    t_drift=None,
    t_fault=None,
) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """Apply ``spec.drift`` then ``spec.fault`` to a conductance stack.

    Drift decays the programmed (noise-perturbed) values; faults pin
    cells afterwards — a stuck cell reads its stuck value regardless of
    what was programmed into it.  ``t_drift``/``t_fault`` default to the
    spec's own evaluation ages (``spec.drift.t`` / ``spec.fault.t``);
    the serving-side healer overrides them per band
    (``repro.serve.health``: drift restarts at each reprogram, faults
    accumulate in absolute time).  At ``t = 1`` both passes are
    bit-identical no-ops.
    """
    td = spec.drift.t if t_drift is None else t_drift
    tf = spec.fault.t if t_fault is None else t_fault
    kd, kf = jax.random.split(key)
    gs = [g_pos, g_neg, g_unit]
    if spec.drift.kind != "none":
        gs = [spec.drift.apply(g, td, jax.random.fold_in(kd, i))
              if g is not None else None
              for i, g in enumerate(gs)]
    if spec.fault.kind != "none":
        g_lo = spec.mapping.g_min
        gs = [spec.fault.apply(g, tf, jax.random.fold_in(kf, i),
                               g_lo=g_lo, g_hi=1.0)
              if g is not None else None
              for i, g in enumerate(gs)]
    return gs[0], gs[1], gs[2]


def program(
    w: jax.Array,
    spec: AnalogSpec,
    key: Optional[jax.Array] = None,
) -> AnalogWeights:
    """Quantize + map + perturb a float weight matrix ``(K, N)``.

    Zero-padding rows added by partitioning are programmed at code 0 —
    with finite On/Off they still carry ``g_min`` and participate in the
    error/parasitic models, exactly like a real partially-used array.
    """
    return program_from_codes(program_codes(w, spec), spec, key)


def _apply_line(
    planes: jax.Array,   # (B, M, P, rows) signed bit planes
    g: jax.Array,        # (S, P, rows, N)
    spec: AnalogSpec,
) -> jax.Array:
    """Per-plane analog dot products -> (B, S, P, M, N)."""
    if not spec.parasitics_on:
        return jnp.einsum(
            "bmpr,sprn->bspmn", planes, g, precision=jax.lax.Precision.HIGHEST
        )
    b, m_, p, rows = planes.shape
    s, _, _, n = g.shape

    if spec.use_pallas:
        # Hot path: the Pallas Thomas-solve kernel, bit planes folded into
        # the kernel's independent-systems axis.  One call per (slice,
        # partition) via vmap (S and P are small static factors); the
        # dense lax.scan path below stays as the parity oracle.
        from repro.kernels import ops as kops

        xp = jnp.moveaxis(planes, 2, 0).reshape(p, b * m_, rows)

        def per_p(g_p, x_p):           # (rows, N), (B*M, rows)
            return kops.bitline_mvm(g_p, x_p, spec.r_hat)

        per_sp = jax.vmap(jax.vmap(per_p, in_axes=(0, 0)), in_axes=(0, None))
        out = per_sp(g, xp)            # (S, P, B*M, N)
        out = out.reshape(s, p, b, m_, n)
        return jnp.transpose(out, (2, 0, 1, 3, 4))       # (B, S, P, M, N)

    def one(plane_pk, g_pk):           # (M, rows), (rows, N)
        return parasitics.bitline_currents(g_pk, plane_pk, spec.r_hat)

    # vmap over slices, then partitions (axis 1 of planes), then input bits.
    over_p = jax.vmap(one, in_axes=(1, 0))   # (M,P,rows),(P,rows,N)->(P,M,N)
    over_sp = jax.vmap(lambda pl, gg: over_p(pl, gg), in_axes=(None, 0))
    over_bsp = jax.vmap(lambda pl, gg: over_sp(pl, gg), in_axes=(0, None))
    return over_bsp(planes, g)                           # (B, S, P, M, N)


def _maybe_pallas_fastpath(spec: AnalogSpec, collect: bool) -> bool:
    """Kernel-eligibility predicate for the differential calibrated chain.

    ``spec.fused != "off"`` selects the whole-chain fused serving kernels
    (``kernels.fused``): slice/partition-tiled, ADC + dequant in-kernel,
    both ``input_accum`` modes, and the Design-A parasitic variant (the
    per-bit Thomas solve inside the same launch).  Digital input
    accumulation under parasitics has no fused form — the parasitic
    kernel's switched-capacitor bit fold *is* analog accumulation — so
    that combination refuses here and falls back to the composed path,
    as does calibration collection and any non-differential or
    non-calibrated design.  Legacy ``use_pallas`` keeps its original,
    narrower domain (unsliced Design-A epilogue outside the kernel).
    """
    if (
        collect
        or spec.mapping.scheme != "differential"
        or spec.adc.style != "calibrated"
    ):
        return False
    if spec.fused != "off":
        return spec.input_accum == "analog" or not spec.parasitics_on
    return (
        spec.use_pallas
        and not spec.mapping.sliced
        and spec.input_accum == "analog"
    )


def fuse_signature(spec: AnalogSpec) -> Optional[Tuple]:
    """The static compile identity of a spec's fused serving kernel.

    Two fuse-eligible specs that agree on this tuple lower to the same
    fused Pallas program (the traced operands — conductances, calibrated
    ranges, scales, ``r_hat`` — carry everything else), so a profile
    compiles one fused kernel per distinct signature, not per site
    (``repro.hw.fused_site_classes``; pinned by the
    ``serve/fused-compile-per-site-class`` contract).  ``None``
    means the spec refuses to fuse (composed fallback).

    Only never-traced program-structure fields may appear here:
    mapping geometry (slice count / cell bits), ADC bit width, the
    input-accumulation mode (bit fold vs single dot), and whether the
    parasitic (Thomas-solve) kernel body is selected.
    """
    if spec.fused == "off" or not _maybe_pallas_fastpath(spec, False):
        return None
    m = spec.mapping
    n_bits = None if spec.input_accum == "analog" else spec.n_planes
    return (
        "parasitic" if spec.parasitics_on else "linear",
        m.n_slices, m.cell_bits, spec.adc.bits, n_bits,
        spec.n_planes if spec.parasitics_on else None,
    )


def analog_matmul(
    x: jax.Array,
    aw: AnalogWeights,
    spec: AnalogSpec,
    *,
    adc_lo: Optional[jax.Array] = None,   # (S,) calibrated per-slice limits
    adc_hi: Optional[jax.Array] = None,
    act_hi: Optional[jax.Array] = None,   # calibrated activation clip
    collect: bool = False,
):
    """Simulated analog ``x @ W`` for ``x`` of shape ``(..., K)``.

    Returns ``y`` of shape ``(..., N)``; with ``collect=True`` returns
    ``(y_ideal, stats)`` where ``stats`` is ``(S, 2)`` pre-ADC lo/hi
    percentiles for ADC range calibration (ADC bypassed).
    """
    m = spec.mapping
    lead = x.shape[:-1]
    k = x.shape[-1]
    if k != aw.k:
        raise ValueError(
            f"analog_matmul input depth {k} does not match the programmed "
            f"matrix depth {aw.k} (weights are ({aw.k}, {aw.n}))")
    xf = x.reshape(-1, k).astype(spec.compute_dtype)

    xq = quantize_acts(
        xf, spec.input_bits, signed=spec.signed_inputs, clip_hi=act_hi
    )
    p = spec.n_partitions(k)
    rows = spec.rows_per_partition(k)
    pad = p * rows - k
    x_int = xq.values
    if pad:
        x_int = jnp.pad(x_int, ((0, 0), (0, pad)))
    x_parts = x_int.reshape(-1, p, rows)

    lmax = m.levels_per_cell - 1
    gain = lmax / (1.0 - m.g_min)          # conductance -> code units
    slice_w = 2.0 ** (m.cell_bits * jnp.arange(m.n_slices, dtype=x.dtype))

    if _maybe_pallas_fastpath(spec, collect) and adc_lo is not None:
        from repro.kernels import ops as kops

        if spec.fused != "off":
            # Whole-chain fused kernels: ADC epilogue, dequant and slice
            # accumulation inside the launch; one traced scale operand so
            # the sweep engine batches traced on_off_ratio (hence traced
            # gain) points through a single compilation.
            backend = "oracle" if spec.fused == "oracle" else "kernel"
            scale = gain * aw.w_scale * xq.scale
            if spec.parasitics_on:
                y = kops.fused_mvm_parasitic(
                    x_parts,
                    aw.g_pos[:, :, :, : aw.n], aw.g_neg[:, :, :, : aw.n],
                    r_hat=spec.r_hat, adc_lo=adc_lo, adc_hi=adc_hi,
                    adc_bits=spec.adc.bits, cell_bits=m.cell_bits,
                    n_bits=spec.n_planes, scale=scale, backend=backend,
                )
            else:
                n_bits = (None if spec.input_accum == "analog"
                          else spec.n_planes)
                y = kops.fused_mvm(
                    x_parts,
                    aw.g_pos[:, :, :, : aw.n], aw.g_neg[:, :, :, : aw.n],
                    adc_lo=adc_lo, adc_hi=adc_hi,
                    adc_bits=spec.adc.bits, cell_bits=m.cell_bits,
                    n_bits=n_bits, scale=scale, backend=backend,
                )
            return y.reshape(*lead, aw.n)

        if spec.parasitics_on:
            d_codes = kops.analog_mvm_parasitic(
                x_parts,
                aw.g_pos[:, :, :, : aw.n], aw.g_neg[:, :, :, : aw.n],
                r_hat=spec.r_hat, n_bits=spec.n_planes,
                adc_lo=adc_lo, adc_hi=adc_hi, adc_bits=spec.adc.bits,
                gain=gain,
            )
        else:
            d_codes = kops.analog_mvm(
                x_parts,
                aw.g_pos[:, :, :, : aw.n], aw.g_neg[:, :, :, : aw.n],
                adc_lo=adc_lo, adc_hi=adc_hi, adc_bits=spec.adc.bits,
                gain=gain,
            )
        y = d_codes * aw.w_scale * xq.scale
        return y.reshape(*lead, aw.n)

    if spec.input_accum == "analog" and not spec.parasitics_on:
        # Analog accumulation over input bits commutes with the dot product:
        # sum_b 2^b plane_b == x_int, so one matmul per (slice, partition).
        planes = x_parts[None]                               # (1, M, P, rows)
        bit_w = jnp.ones((1,), x.dtype)
    else:
        nb = spec.n_planes
        planes_flat = bit_planes(x_int, nb, signed=spec.signed_inputs)
        planes = planes_flat.reshape(nb, -1, p, rows)        # (B, M, P, rows)
        bit_w = 2.0 ** jnp.arange(nb, dtype=x.dtype)

    v_pos = _apply_line(planes, aw.g_pos, spec)              # (B, S, P, M, N)
    if m.scheme == "differential":
        v = v_pos - _apply_line(planes, aw.g_neg, spec)      # analog subtract
    else:
        v = v_pos
    if spec.input_accum == "analog" and spec.parasitics_on:
        # Parasitic solve is per input bit; analog accumulation happens in
        # the switched-capacitor stage after the bit-line, before the ADC.
        v = jnp.einsum("b,bspmn->spmn", bit_w, v)[None]
        bit_w = jnp.ones((1,), x.dtype)
        s_b = x_parts.sum(axis=-1)[None]                     # (1, M, P)
    else:
        s_b = planes.sum(axis=-1)                            # (B, M, P)

    if collect:
        stats = jnp.stack(
            [
                jnp.stack(adc_lib.range_from_samples(v[:, s]))
                for s in range(m.n_slices)
            ]
        )                                                     # (S, 2)
        v_hat = v
    elif spec.adc.style == "none":
        v_hat = v
    elif spec.adc.style == "fpg":
        bits = spec.fpg_adc_bits(k)
        lo, hi = adc_lib.fpg_range(
            rows,
            1.0,
            signed_inputs=spec.signed_inputs,
            differential=(m.scheme == "differential"),
        )
        if spec.input_accum == "analog":
            scale_in = float(2 ** (spec.input_bits - 1) - 1
                             if spec.signed_inputs else 2 ** spec.input_bits - 1)
            lo, hi = lo * scale_in, hi * scale_in
        # FPG means "a unique level per possible output": snap the ADC LSB
        # to the exact analog output grid (code spacing (1-g_min)/(L-1)).
        # Eq. (4) guarantees 2**bits levels cover the full range.
        grid = (1.0 - m.g_min) / lmax
        lo = grid * math.floor(lo / grid)
        hi = lo + (2 ** bits - 1) * grid
        v_hat = adc_lib.adc_quantize(v, lo, hi, bits)
    else:
        if adc_lo is None or adc_hi is None:
            raise ValueError(
                "adc.style='calibrated' requires adc_lo/adc_hi ranges from "
                "the calibration pass (analog_matmul(..., collect=True) or "
                "core.calibrate.calibrate_adc_for_matmul)")
        lo = jnp.reshape(adc_lo, (1, m.n_slices, 1, 1, 1)).astype(v.dtype)
        hi = jnp.reshape(adc_hi, (1, m.n_slices, 1, 1, 1)).astype(v.dtype)
        v_hat = adc_lib.adc_quantize(v, lo, hi, spec.adc.bits)

    # ---- digital aggregation + exact affine corrections -----------------
    if m.scheme == "differential":
        codes = v_hat * gain                                  # g_min cancels
        d = jnp.einsum("s,b,bspmn->mn", slice_w, bit_w, codes)
    else:
        if m.unit_column:
            vu = _apply_line(planes, aw.g_unit, spec)         # (B,S,P,M,1)
            if not collect and spec.adc.style != "none":
                if spec.adc.style == "fpg":
                    vu = adc_lib.adc_quantize(vu, lo, hi, bits)
                else:
                    vu = adc_lib.adc_quantize(vu, lo, hi, spec.adc.bits)
            # Unit column codes per slice sum to the offset: analog offset.
            codes = (v_hat - vu) * gain
            d = jnp.einsum("s,b,bspmn->mn", slice_w, bit_w, codes)
        else:
            # g_min floor correction uses the exact digital sum of input
            # bits per partition (the same digital sum the offset needs).
            s_bp = jnp.swapaxes(s_b, 1, 2)        # (B, M, P) -> (B, P, M)
            codes = (v_hat - m.g_min * s_bp[:, None, :, :, None]) * gain
            d = jnp.einsum("s,b,bspmn->mn", slice_w, bit_w, codes)
            x_sum = xq.values.sum(axis=-1)                    # (M,)
            d = d - m.offset_code * x_sum[:, None]

    y = d * aw.w_scale * xq.scale
    y = y.reshape(*lead, aw.n)
    if collect:
        return y, stats
    return y


def ideal_matmul_int(x: jax.Array, aw: AnalogWeights, spec: AnalogSpec,
                     act_hi: Optional[jax.Array] = None) -> jax.Array:
    """Reference: the same quantization pipeline with a perfect analog core
    (no errors, no ADC).  Used for SNR measurements (Eq. 9/10)."""
    err_free = dataclasses.replace(
        spec, error=ErrorModel(), adc=adc_lib.ADCConfig(style="none"),
        r_hat=0.0, use_pallas=False, fused="off",
    )
    return analog_matmul(x, aw, err_free, act_hi=act_hi)
