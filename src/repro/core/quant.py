"""Integer quantization used on both sides of the analog MVM.

The paper (Sec. 4.3) quantizes weights to 8 bits before mapping them to
conductances, and activations to 8 bits during inference with a calibrated
clipping range found by minimizing an L1 reconstruction error over a
calibration set.  This module implements both, plus the bit-plane
decomposition used for input bit slicing (Sec. 2.2).

Conventions
-----------
* ``weight_bits = B`` means signed integers.  For *offset subtraction* the
  usable range is ``[-(2**(B-1)), 2**(B-1)-1]`` but we quantize symmetrically
  to ``[-(2**(B-1)-1), 2**(B-1)-1]`` so that zero is exactly representable
  and the offset algebra stays symmetric.
* For *differential* mappings the magnitude is what gets programmed, so a
  ``magnitude_bits = M`` cell pair represents ``[-(2**M-1), 2**M-1]``.
* Activations may be signed (LM residual streams) or unsigned (post-ReLU
  CNNs, the paper's case).  Signed inputs are modelled as opposite-polarity
  input voltages (Marinella et al. [43]): bit planes carry values in
  ``{-1, 0, +1}``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def qmax_signed(bits: int) -> int:
    """Largest magnitude representable by a signed ``bits``-bit integer
    under symmetric quantization."""
    return 2 ** (bits - 1) - 1


def qmax_unsigned(bits: int) -> int:
    return 2 ** bits - 1


@dataclasses.dataclass(frozen=True)
class QuantizedTensor:
    """An integer tensor together with its dequantization scale.

    ``values`` is stored as float (integer-valued) so it can feed the MXU
    directly; ``dequant = values * scale``.
    """

    values: jax.Array          # integer-valued float array
    scale: jax.Array           # scalar or per-axis scale
    bits: int
    signed: bool

    def dequant(self) -> jax.Array:
        return self.values * self.scale


def quantize_weights(
    w: jax.Array,
    bits: int = 8,
    *,
    magnitude_bits: Optional[int] = None,
    per_channel: bool = False,
    eps: float = 1e-12,
) -> QuantizedTensor:
    """Symmetric signed quantization of a weight matrix.

    ``magnitude_bits`` overrides the integer range: the paper's sliced
    differential scheme represents ``magnitude_bits = 8`` (9-bit signed
    weights) while unsliced differential uses 7 magnitude bits (8-bit
    signed).  When ``None``, ``bits - 1`` magnitude bits are used.
    """
    m = (bits - 1) if magnitude_bits is None else magnitude_bits
    qmax = 2 ** m - 1
    if per_channel:
        absmax = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    else:
        absmax = jnp.max(jnp.abs(w))
    scale = jnp.maximum(absmax, eps) / qmax
    w_int = jnp.clip(jnp.round(w / scale), -qmax, qmax)
    return QuantizedTensor(values=w_int, scale=scale, bits=m + 1, signed=True)


def quantize_acts(
    x: jax.Array,
    bits: int = 8,
    *,
    signed: bool = True,
    clip_lo: Optional[jax.Array] = None,
    clip_hi: Optional[jax.Array] = None,
    eps: float = 1e-12,
) -> QuantizedTensor:
    """Quantize activations to ``bits`` with an optional calibrated range.

    Signed activations use symmetric quantization around zero (so that the
    sign/magnitude bit-plane decomposition below is exact); unsigned use
    the range ``[0, clip_hi]``.
    """
    if signed:
        if clip_hi is None:
            absmax = jnp.max(jnp.abs(x))
        else:
            hi = jnp.asarray(clip_hi)
            lo = -hi if clip_lo is None else jnp.asarray(clip_lo)
            absmax = jnp.maximum(jnp.abs(lo), jnp.abs(hi))
        qmax = 2 ** (bits - 1) - 1
        scale = jnp.maximum(absmax, eps) / qmax
        x_int = jnp.clip(jnp.round(x / scale), -qmax, qmax)
        return QuantizedTensor(values=x_int, scale=scale, bits=bits, signed=True)
    hi = jnp.max(x) if clip_hi is None else jnp.asarray(clip_hi)
    qmax = 2 ** bits - 1
    scale = jnp.maximum(hi, eps) / qmax
    x_int = jnp.clip(jnp.round(x / scale), 0, qmax)
    return QuantizedTensor(values=x_int, scale=scale, bits=bits, signed=False)


def calibrate_act_range(
    samples: jax.Array,
    bits: int = 8,
    *,
    signed: bool = True,
    search_bits: int = 12,
) -> Tuple[jax.Array, jax.Array]:
    """Find the clipping range minimizing the L1 quantization error.

    Mirrors Sec. 4.3: candidate ranges are swept on a grid of ``2**search_bits``
    resolution (the paper's ``M = 12``) and the L1-optimal clip is chosen.
    Returns ``(lo, hi)``; for signed data the range is symmetric.
    """
    flat = samples.reshape(-1)
    absmax = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-12)
    # Sweep 32 candidate clip points between absmax/2**6 and absmax on the
    # search grid, picking the L1-optimal one.  (An exhaustive 2**12 sweep is
    # needless: the L1 error is smooth in the clip value.)
    n_cand = 32
    fracs = jnp.exp(jnp.linspace(jnp.log(2.0 ** -6), 0.0, n_cand))
    cands = absmax * fracs
    grid = 2.0 ** search_bits

    def l1_err(hi):
        hi = jnp.round(hi / absmax * grid) / grid * absmax  # snap to M-bit grid
        q = quantize_acts(flat, bits, signed=signed, clip_hi=hi)
        return jnp.sum(jnp.abs(q.dequant() - flat))

    errs = jax.vmap(l1_err)(cands)
    best = cands[jnp.argmin(errs)]
    if signed:
        return -best, best
    return jnp.zeros_like(best), best


def bit_planes(x_int: jax.Array, n_planes: int, *, signed: bool = True) -> jax.Array:
    """Decompose integer-valued ``x_int`` into bit planes.

    Returns an array of shape ``(n_planes,) + x_int.shape`` such that
    ``sum_b 2**b * planes[b] == x_int`` exactly.  For signed inputs the
    planes are the magnitude bits multiplied by ``sign(x)`` (values in
    ``{-1, 0, +1}``), modelling opposite-polarity input voltages.
    """
    if signed:
        sign = jnp.sign(x_int)
        mag = jnp.abs(x_int)
    else:
        sign = jnp.ones_like(x_int)
        mag = x_int
    mag = mag.astype(jnp.int32)
    planes = []
    for b in range(n_planes):
        planes.append(((mag >> b) & 1).astype(x_int.dtype) * sign)
    return jnp.stack(planes, axis=0)


def n_input_planes(input_bits: int, signed: bool) -> int:
    """Number of magnitude bit planes for an ``input_bits`` quantizer."""
    return input_bits - 1 if signed else input_bits
