"""Cell programming-error models (paper Sec. 5.1, Fig. 7; Sec. 9.1, Fig. 20).

All models perturb *normalized* conductances ``g = G / G_max`` with zero-mean
Gaussian noise whose standard deviation depends on the model:

* ``state_independent``:  sigma = alpha_ind            (fraction of G_max)
* ``state_proportional``: sigma = alpha_prop * g
* ``sonos``:              sigma(g) = sat * (1 - exp(-g / knee)) — the
  saturating-exponential fit to the measured SONOS distributions in
  Fig. 20(b): state-proportional with slope ~6% below ~0.3*G_max,
  saturating near 0.031*G_max above ~0.5*G_max (I_max = 1.6 uA).

Errors are *program-time*: sampled once per programmed chip from an explicit
PRNG key, then frozen.  The paper's "10 trials" become 10 vmapped keys.

Device state is additionally *time-dependent* (related work: Rasch et al.,
arXiv:2302.08469; Wan et al., arXiv:2008.02400): :class:`DriftModel` decays
programmed conductances by the retention power law and :class:`FaultModel`
pins stuck-at cells arriving as a Poisson process.  Both are disabled by
default, keyed like programming errors, and exactly the identity at the
fresh age ``t = t0`` — see DESIGN.md §Drift-and-healing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# SONOS fit constants (normalized to I_max = 1.6 uA).  sigma(I) in Fig. 20(b)
# is ~6% proportional below 0.5 uA and saturates around 0.05 uA at high
# current: sat * (1 - exp(-I/knee)) with sat = 0.05/1.6, knee chosen so the
# small-signal slope sat/knee = 0.06.
SONOS_SAT = 0.05 / 1.6
SONOS_KNEE = SONOS_SAT / 0.06
SONOS_ALPHA_PROP = 0.06
SONOS_ON_OFF = 1.0e4


@dataclasses.dataclass(frozen=True)
class ErrorModel:
    """Parameterized cell-error model; ``kind = 'none'`` disables it.

    ``clip_at_zero``: the paper's Fig. 7 models are *symmetric* Gaussians
    (its Fig. 8(a) shows fine slicing slightly HELPING under
    state-independent error, which only holds without rectification).
    Physical conductance cannot go negative; enabling the clip adds the
    half-Gaussian bias of real zero-state cells.  Default False = the
    paper's model; state-proportional/SONOS errors vanish at g=0 anyway,
    so the flag only matters for state-independent sweeps.
    """

    kind: str = "none"          # none | state_independent | state_proportional | sonos
    alpha: float = 0.0          # alpha_ind or alpha_prop (fractions, not %)
    clip_at_zero: bool = False

    def __post_init__(self):
        kinds = ("none", "state_independent", "state_proportional", "sonos")
        if self.kind not in kinds:
            raise ValueError(
                f"ErrorModel.kind must be one of {kinds}, got {self.kind!r}")

    def sigma(self, g: jax.Array) -> jax.Array:
        """Std-dev of the programming error at conductance ``g``."""
        if self.kind == "none":
            return jnp.zeros_like(g)
        if self.kind == "state_independent":
            return jnp.full_like(g, self.alpha)
        if self.kind == "state_proportional":
            return self.alpha * g
        # sonos
        return SONOS_SAT * (1.0 - jnp.exp(-g / SONOS_KNEE))

    def perturb(self, g: jax.Array, key: Optional[jax.Array]) -> jax.Array:
        """Sample programmed conductances around their targets.

        Conductances are clipped below at 0 (a memory cell cannot have
        negative conductance); no upper clip, matching the measured
        distributions which overshoot G_max slightly.
        """
        if self.kind == "none" or key is None:
            return g
        noise = jax.random.normal(key, g.shape, dtype=g.dtype)
        out = g + self.sigma(g) * noise
        if self.clip_at_zero:
            out = jnp.maximum(out, 0.0)
        return out


@dataclasses.dataclass(frozen=True)
class DriftModel:
    """Time-dependent conductance decay; ``kind = 'none'`` disables it.

    ``power_law`` is the standard retention model of charge-trap /
    phase-change cells: ``g(t) = g0 * (t/t0)^-nu`` with the *per-cell*
    exponent drawn once per device as ``nu_cell = nu * exp(sigma_nu * z)``,
    ``z ~ N(0, 1)`` — lognormal around the median ``nu``, strictly
    positive, so conductance only decays.  ``t`` is the evaluation age in
    units of the programming-reference time ``t0`` (``t = 1`` is a fresh
    device) and may be a *traced* scalar, like ``nu`` — the sweep engine
    batches whole horizon × nu grids through one compilation
    (``repro.sweep.evaluate.dynamic_fields_for``).

    Drift composes with :class:`ErrorModel`: programming noise perturbs
    the target conductance, then drift decays the *programmed* value.
    At ``t = 1`` the decay factor is exactly ``1.0^-nu_cell == 1.0``, so
    ``apply`` is a bit-identical no-op on a fresh device (pinned by
    ``tests/test_properties.py``).
    """

    kind: str = "none"          # none | power_law
    nu: float = 0.0             # median drift exponent
    sigma_nu: float = 0.0      # lognormal spread of the per-cell exponent
    t: float = 1.0              # evaluation age in t0 units (1.0 = fresh)

    def __post_init__(self):
        kinds = ("none", "power_law")
        if self.kind not in kinds:
            raise ValueError(
                f"DriftModel.kind must be one of {kinds}, got {self.kind!r}")

    def exponents(self, shape, key: jax.Array, dtype) -> jax.Array:
        """Per-cell drift exponents (a fixed device property per key)."""
        z = jax.random.normal(key, shape, dtype=dtype)
        return self.nu * jnp.exp(self.sigma_nu * z)

    def factor(self, shape, t, key: jax.Array, dtype=jnp.float32) -> jax.Array:
        """Per-cell decay factor ``(t/t0)^-nu_cell`` (clamped to ages
        >= t0: the power law is a *retention* model, not an oracle for
        the programming transient)."""
        tc = jnp.maximum(jnp.asarray(t, dtype), 1.0)
        return tc ** (-self.exponents(shape, key, dtype))

    def apply(self, g: jax.Array, t, key: Optional[jax.Array]) -> jax.Array:
        """Decay programmed conductances from age t0 to age ``t``."""
        if self.kind == "none" or key is None:
            return g
        return g * self.factor(g.shape, t, key, g.dtype)


@dataclasses.dataclass(frozen=True)
class FaultModel:
    """Stuck-at cell faults arriving as a seeded Poisson process.

    Each cell fails independently at rate ``rate`` (expected failures per
    cell per ``t0`` of age), so by age ``t`` it is stuck with probability
    ``1 - exp(-rate * (t - 1))``; a stuck cell reads ``G_max`` with
    probability ``p_hi``, else ``G_min``.  The per-cell arrival threshold
    and high/low choice are drawn once from the key, which makes fault
    masks *replayable* (same key + same t = bit-identical mask) and
    arrivals *monotone* (the stuck set at ``t1`` is a subset of the stuck
    set at ``t2 > t1``) — a cell that failed stays failed, including
    across reprogramming (reprogram pulses cannot heal a broken device).
    ``rate`` and ``t`` are tracer-safe.
    """

    kind: str = "none"          # none | stuck
    rate: float = 0.0           # expected failures per cell per t0 of age
    p_hi: float = 0.5           # fraction of stuck cells stuck at G_max
    t: float = 1.0              # evaluation age in t0 units (1.0 = fresh)

    def __post_init__(self):
        kinds = ("none", "stuck")
        if self.kind not in kinds:
            raise ValueError(
                f"FaultModel.kind must be one of {kinds}, got {self.kind!r}")
        if not 0.0 <= self.p_hi <= 1.0:
            raise ValueError(
                f"FaultModel.p_hi must sit in [0, 1], got {self.p_hi}")

    def stuck_prob(self, t, dtype=jnp.float32) -> jax.Array:
        """P(cell has failed by age ``t``) under Poisson arrivals."""
        dt = jnp.maximum(jnp.asarray(t, dtype), 1.0) - 1.0
        return -jnp.expm1(-self.rate * dt)

    def apply(self, g: jax.Array, t, key: Optional[jax.Array], *,
              g_lo=0.0, g_hi=1.0) -> jax.Array:
        """Pin failed cells to ``g_lo``/``g_hi`` (normalized G_min/G_max)."""
        if self.kind == "none" or key is None:
            return g
        ka, kh = jax.random.split(key)
        u = jax.random.uniform(ka, g.shape, dtype=g.dtype)
        stuck = u < self.stuck_prob(t, g.dtype)
        hi = jax.random.uniform(kh, g.shape, dtype=g.dtype) < self.p_hi
        val = jnp.where(hi, jnp.asarray(g_hi, g.dtype),
                        jnp.asarray(g_lo, g.dtype))
        return jnp.where(stuck, val, g)


def state_independent(alpha: float) -> ErrorModel:
    return ErrorModel(kind="state_independent", alpha=alpha)


def state_proportional(alpha: float) -> ErrorModel:
    return ErrorModel(kind="state_proportional", alpha=alpha)


def sonos() -> ErrorModel:
    return ErrorModel(kind="sonos")


def none() -> ErrorModel:
    return ErrorModel(kind="none")


def power_law_drift(nu: float, sigma_nu: float = 0.0,
                    t: float = 1.0) -> DriftModel:
    return DriftModel(kind="power_law", nu=nu, sigma_nu=sigma_nu, t=t)


def stuck_faults(rate: float, p_hi: float = 0.5,
                 t: float = 1.0) -> FaultModel:
    return FaultModel(kind="stuck", rate=rate, p_hi=p_hi, t=t)
