"""Cell programming-error models (paper Sec. 5.1, Fig. 7; Sec. 9.1, Fig. 20).

All models perturb *normalized* conductances ``g = G / G_max`` with zero-mean
Gaussian noise whose standard deviation depends on the model:

* ``state_independent``:  sigma = alpha_ind            (fraction of G_max)
* ``state_proportional``: sigma = alpha_prop * g
* ``sonos``:              sigma(g) = sat * (1 - exp(-g / knee)) — the
  saturating-exponential fit to the measured SONOS distributions in
  Fig. 20(b): state-proportional with slope ~6% below ~0.3*G_max,
  saturating near 0.031*G_max above ~0.5*G_max (I_max = 1.6 uA).

Errors are *program-time*: sampled once per programmed chip from an explicit
PRNG key, then frozen.  The paper's "10 trials" become 10 vmapped keys.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

# SONOS fit constants (normalized to I_max = 1.6 uA).  sigma(I) in Fig. 20(b)
# is ~6% proportional below 0.5 uA and saturates around 0.05 uA at high
# current: sat * (1 - exp(-I/knee)) with sat = 0.05/1.6, knee chosen so the
# small-signal slope sat/knee = 0.06.
SONOS_SAT = 0.05 / 1.6
SONOS_KNEE = SONOS_SAT / 0.06
SONOS_ALPHA_PROP = 0.06
SONOS_ON_OFF = 1.0e4


@dataclasses.dataclass(frozen=True)
class ErrorModel:
    """Parameterized cell-error model; ``kind = 'none'`` disables it.

    ``clip_at_zero``: the paper's Fig. 7 models are *symmetric* Gaussians
    (its Fig. 8(a) shows fine slicing slightly HELPING under
    state-independent error, which only holds without rectification).
    Physical conductance cannot go negative; enabling the clip adds the
    half-Gaussian bias of real zero-state cells.  Default False = the
    paper's model; state-proportional/SONOS errors vanish at g=0 anyway,
    so the flag only matters for state-independent sweeps.
    """

    kind: str = "none"          # none | state_independent | state_proportional | sonos
    alpha: float = 0.0          # alpha_ind or alpha_prop (fractions, not %)
    clip_at_zero: bool = False

    def __post_init__(self):
        kinds = ("none", "state_independent", "state_proportional", "sonos")
        if self.kind not in kinds:
            raise ValueError(
                f"ErrorModel.kind must be one of {kinds}, got {self.kind!r}")

    def sigma(self, g: jax.Array) -> jax.Array:
        """Std-dev of the programming error at conductance ``g``."""
        if self.kind == "none":
            return jnp.zeros_like(g)
        if self.kind == "state_independent":
            return jnp.full_like(g, self.alpha)
        if self.kind == "state_proportional":
            return self.alpha * g
        # sonos
        return SONOS_SAT * (1.0 - jnp.exp(-g / SONOS_KNEE))

    def perturb(self, g: jax.Array, key: Optional[jax.Array]) -> jax.Array:
        """Sample programmed conductances around their targets.

        Conductances are clipped below at 0 (a memory cell cannot have
        negative conductance); no upper clip, matching the measured
        distributions which overshoot G_max slightly.
        """
        if self.kind == "none" or key is None:
            return g
        noise = jax.random.normal(key, g.shape, dtype=g.dtype)
        out = g + self.sigma(g) * noise
        if self.clip_at_zero:
            out = jnp.maximum(out, 0.0)
        return out


def state_independent(alpha: float) -> ErrorModel:
    return ErrorModel(kind="state_independent", alpha=alpha)


def state_proportional(alpha: float) -> ErrorModel:
    return ErrorModel(kind="state_proportional", alpha=alpha)


def sonos() -> ErrorModel:
    return ErrorModel(kind="sonos")


def none() -> ErrorModel:
    return ErrorModel(kind="none")
