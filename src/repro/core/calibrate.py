"""Range-calibration pipeline (paper Sec. 4.3, 6.2).

Two calibrated quantities:

* **activation ranges** — per layer, L1-optimal clipping of the float
  activations over a calibration set (``quant.calibrate_act_range``);
* **ADC ranges** — per (layer, slice), the inner-99.98% percentile range of
  the pre-ADC analog values, with per-slice ranges constrained to powers of
  two of each other for shift-and-add compatibility.

The model integration (``repro.models``) threads these dicts of stacked
per-layer arrays through the forward pass; see ``repro.core.analog_ctx``.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import adc as adc_lib
from repro.core.analog import AnalogSpec, AnalogWeights, analog_matmul
from repro.core.quant import calibrate_act_range


def constrain_power_of_two(lo: jax.Array, hi: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Apply Sec. 6.2's power-of-two constraint across the slice axis.

    ``lo``/``hi``: per-slice limits, shape (S,).  The half-range of each
    slice is rounded up to ``base * 2**k``; limits stay centered.
    """
    center = (lo + hi) / 2.0
    half = jnp.maximum((hi - lo) / 2.0, 1e-12)
    granted = adc_lib.power_of_two_ranges(half)
    return center - granted, center + granted


def calibrate_adc_for_matmul(
    x_samples: jax.Array,
    aw: AnalogWeights,
    spec: AnalogSpec,
    *,
    act_hi: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Run the collect pass for a single matmul and derive ADC limits.

    Returns ``(adc_lo, adc_hi)`` of shape (S,).  Unsliced mappings skip the
    power-of-two constraint (Sec. 6.2: "with unsliced weights there is no
    such constraint").
    """
    _, stats = analog_matmul(x_samples, aw, spec, act_hi=act_hi, collect=True)
    lo, hi = stats[:, 0], stats[:, 1]
    if spec.mapping.sliced:
        lo, hi = constrain_power_of_two(lo, hi)
    return lo, hi


def calibrate_activations(
    samples: jax.Array, bits: int = 8, *, signed: bool = True
) -> jax.Array:
    """L1-optimal activation clip magnitude for one layer."""
    _, hi = calibrate_act_range(samples, bits, signed=signed)
    return hi


def merge_layer_stats(stats_stacked: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Merge per-layer stats stacked by a layer scan: (L, S, 2) -> ((L,S), (L,S))."""
    return stats_stacked[..., 0], stats_stacked[..., 1]
