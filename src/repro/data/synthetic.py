"""Deterministic synthetic data pipeline.

Every batch is a pure function of ``(seed, step)`` — the property the fault
tolerance story rests on: restart at step *k* replays exactly the batches a
failed run would have seen, with no iterator state beyond the step index.
Sharding: each (pod, data) shard slices its rows of the global batch by
index, so the same function serves 1 or 512 processes.

Two token streams:

* ``lm``: an affine-congruential token process with noise — enough
  structure that a few hundred training steps measurably reduce loss
  (used by the end-to-end example), fully vocabulary-general.
* ``uniform``: i.i.d. tokens (throughput benchmarking).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    cfg: ModelConfig
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "lm"            # "lm" | "uniform"
    noise: float = 0.1

    def batch(self, step) -> Dict[str, jax.Array]:
        """Global batch for ``step`` (host-shardable by row)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        b, s, v = self.global_batch, self.seq_len, self.cfg.vocab
        if self.mode == "uniform":
            tokens = jax.random.randint(key, (b, s), 0, v)
        else:
            k1, k2, k3 = jax.random.split(key, 3)
            start = jax.random.randint(k1, (b, 1), 0, v)
            mult = 31 + 2 * jax.random.randint(k2, (b, 1), 0, 8)
            idx = jnp.arange(s)[None, :]
            tokens = (start + mult * idx) % v
            noise_mask = jax.random.uniform(k3, (b, s)) < self.noise
            rand = jax.random.randint(jax.random.fold_in(k3, 1), (b, s), 0, v)
            tokens = jnp.where(noise_mask, rand, tokens)
        tokens = tokens.astype(jnp.int32)
        targets = jnp.roll(tokens, -1, axis=1)
        out = {"tokens": tokens, "targets": targets}
        if self.cfg.frontend:
            kf = jax.random.fold_in(key, 7)
            out["prefix_embeds"] = 0.02 * jax.random.normal(
                kf, (b, self.cfg.n_frontend_tokens, self.cfg.d_model),
                jnp.float32)
        return out

    def state(self, step: int) -> dict:
        """Checkpointable pipeline state — the step index is everything."""
        return {"seed": self.seed, "step": int(step), "mode": self.mode}


def for_shape(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
              mode: str = "lm") -> SyntheticLM:
    return SyntheticLM(cfg=cfg, seq_len=shape.seq_len,
                       global_batch=shape.global_batch, seed=seed, mode=mode)
