"""Roofline analysis over the dry-run results (EXPERIMENTS.md §Roofline).

Hardware model (TPU v5e-class target):
    peak bf16 compute   197 TFLOP/s per chip
    HBM bandwidth       819 GB/s per chip
    ICI link bandwidth  ~50 GB/s per link per chip

Per (arch x shape x mesh) cell, from the compiled per-device HLO:
    compute term    = flops_per_device / PEAK_FLOPS
    memory term     = hbm_bytes_per_device / HBM_BW
    collective term = collective_wire_bytes_per_device / ICI_BW
plus MODEL_FLOPS (6ND train / 2ND forward) and the useful-compute ratio
MODEL_FLOPS / (flops_per_device * n_devices).
"""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def model_flops(rec: dict) -> float:
    n = rec["active_params"]
    tokens = rec["global_batch"] * (
        rec["seq_len"] if rec["kind"] != "decode" else 1)
    if rec["kind"] == "train":
        return 6.0 * n * tokens
    return 2.0 * n * tokens


def analytic_memory_bytes(rec: dict) -> float:
    """TPU-faithful per-device HBM traffic model.

    The HLO-parsed byte count (kept as a diagnostic) is an upper bound
    taken from CPU-backend HLO, whose fusion decisions differ from TPU —
    elementwise chains that Mosaic/XLA-TPU fuse appear as separate
    HBM-visiting ops on CPU.  The roofline memory term therefore uses the
    standard analytic accounting:

      train:   3 passes over bf16 weights per microbatch (fwd, bwd, remat
               refwd) + 24 B/param optimizer traffic + 8 B/param gradient
               accumulation per microbatch + ~20*d bytes/token/layer
               activation traffic (x2 for bwd).
      prefill: 1 weight pass + activations + KV-chunk rereads of streaming
               attention (S/1024 passes over the KV written).
      decode:  1 weight pass + full cache read.
    """
    n_dev = rec["n_devices"]
    n = rec["params"]
    layers = rec.get("n_layers", 0) or 1
    d = rec.get("d_model", 0) or 1
    tokens = rec["global_batch"] * (
        rec["seq_len"] if rec["kind"] != "decode" else 1)
    act = 20.0 * d * 2.0 * tokens * layers
    kv_bytes = rec.get("kv_cache_bytes", 0.0)
    if rec["kind"] == "train":
        mb = rec.get("microbatches") or 1
        b = (3.0 * mb * 2.0 * n) + 24.0 * n + 8.0 * n * mb + 2.0 * act
    elif rec["kind"] == "prefill":
        rereads = max(rec["seq_len"] / 1024.0, 1.0)
        b = 2.0 * n + act + rereads * kv_bytes
    else:
        b = 2.0 * min(n, rec["active_params"] * rec["global_batch"]) \
            + kv_bytes + act
    return b / n_dev


def roofline_row(rec: dict) -> Optional[dict]:
    if "error" in rec or "skipped" in rec:
        return None
    t_c = rec["flops_per_device"] / PEAK_FLOPS
    t_m = analytic_memory_bytes(rec) / HBM_BW
    t_m_hlo = rec["hbm_bytes_per_device"] / HBM_BW
    t_x = rec["total_collective_bytes"] / ICI_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    dominant = max(terms, key=terms.get)
    mf = model_flops(rec)
    hlo_global = rec["flops_per_device"] * rec["n_devices"]
    bound = max(t_c, t_m, t_x)
    # fraction of roofline: time the dominant resource is busy doing useful
    # model math, vs the bound implied by all three terms
    useful = mf / max(hlo_global, 1.0)
    step_time_bound = bound
    mfu_bound = (mf / rec["n_devices"] / PEAK_FLOPS) / max(step_time_bound,
                                                           1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec["kind"], "variant": rec.get("variant", "baseline"),
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "memory_hlo_s": t_m_hlo,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": useful,
        "roofline_fraction": mfu_bound,
    }


def _enrich(rec: dict) -> dict:
    """Attach config-derived fields needed by the analytic memory model."""
    if "arch" not in rec:
        return rec
    from repro.configs import get_config

    try:
        cfg = get_config(rec["arch"])
    except Exception:
        return rec
    rec["n_layers"] = cfg.n_layers + cfg.n_enc_layers
    rec["d_model"] = cfg.d_model
    b, s = rec.get("global_batch", 1), rec.get("seq_len", 1)
    dt = 2.0
    if cfg.rwkv:
        hd = cfg.d_model // cfg.n_heads
        kv = cfg.n_layers * b * cfg.n_heads * hd * hd * 4.0
    elif cfg.ssm_state:
        apps = max(1, cfg.n_layers // max(cfg.attn_every, 1))
        kv = (apps * b * s * cfg.n_kv_heads * cfg.hd * dt * 2.0
              + cfg.n_layers * b * cfg.ssm_heads * cfg.ssm_state
              * cfg.ssm_head_dim * 4.0)
    else:
        kv = cfg.n_layers * b * s * cfg.n_kv_heads * cfg.hd * dt * 2.0
        if cfg.family == "audio":
            kv += cfg.n_layers * b * cfg.cross_kv_len * cfg.n_kv_heads \
                * cfg.hd * dt * 2.0
    rec["kv_cache_bytes"] = kv
    return rec


def load_all(results_dir: str = RESULTS_DIR) -> List[dict]:
    rows = []
    for f in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        rec = _enrich(json.load(open(f)))
        row = roofline_row(rec)
        if row is not None:
            rows.append(row)
    return rows


def format_table(rows: List[dict], mesh: str = "pod16x16") -> str:
    hdr = (f"{'arch':<22}{'shape':<13}{'comp(s)':>10}{'mem(s)':>10}"
           f"{'coll(s)':>10}{'dom':>6}{'useful':>8}{'roofl%':>8}")
    out = [hdr, "-" * len(hdr)]
    for r in rows:
        if r["mesh"] != mesh or r.get("variant", "baseline") != "baseline":
            continue
        out.append(
            f"{r['arch']:<22}{r['shape']:<13}{r['compute_s']:>10.2e}"
            f"{r['memory_s']:>10.2e}{r['collective_s']:>10.2e}"
            f"{r['dominant'][:4]:>6}{r['useful_ratio']:>8.2f}"
            f"{100*r['roofline_fraction']:>7.1f}%")
    return "\n".join(out)


def main():
    rows = load_all()
    print(format_table(rows, "pod16x16"))
    print()
    print(format_table(rows, "pod2x16x16"))


if __name__ == "__main__":
    main()
