"""Production mesh definition.

Kept as functions (never module-level constants) so importing this module
never touches JAX device state — the dry-run entry point must set
``XLA_FLAGS`` before the first device query.
"""

from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int) -> dict:
    """``axis_types`` only exists on newer jax; older versions default to
    Auto semantics anyway, so omit the argument there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    Axis semantics: ``pod`` is the DCN-connected data-parallel axis (only
    gradient reductions cross it), ``data`` the intra-pod DP/FSDP axis,
    ``model`` the tensor/expert-parallel axis.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_debug_mesh(n_data: int = 2, n_model: int = 4):
    """Small mesh for the 8-device subprocess tests."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         **_mesh_kwargs(2))


def dp_axes(mesh) -> tuple:
    """The data-parallel axis names present in ``mesh`` (pod included)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n
