"""Loop-aware roofline accounting from compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits while bodies ONCE (verified: a
10-iteration scan reports 1/10th the unrolled FLOPs), so a layer-scanned
transformer would be undercounted ~n_layers x.  This analyzer parses the
per-device HLO module into its computation graph and weights every op by
the product of enclosing loop trip counts (``known_trip_count`` backend
config emitted by XLA for lax.scan loops).

Per-op accounting:

* **dot FLOPs**: ``2 * numel(result) * prod(lhs contracting dim sizes)``.
  (All model compute is dots; elementwise FLOPs are noise at these shapes.)
* **HBM bytes**: result bytes + operand bytes for every top-level op
  (fusion internals excluded — the fusion op's own operands/results are
  the real HBM traffic), excluding no-cost ops (tuple/gte/bitcast/param).
* **collective wire bytes**, ring-algorithm factors for group size n:
  all-gather/reduce-scatter/all-to-all (n-1)/n, all-reduce 2(n-1)/n,
  collective-permute 1.

Conditionals are counted at the max over branches — an upper bound; for
zamba2 (attention branch taken 1/6 of layers) the compute term is
explicitly an upper bound, noted in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

NO_COST_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "iota", "after-all", "partition-id", "replica-id", "copy-start",
    "copy-done", "opt-barrier",
}

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->.*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s+=\s+(.*)$")
_SHAPE_TOKEN = re.compile(r"(\w+)\[([\d,]*)\]")
_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")
_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_COND_BRANCHES = re.compile(
    r"(?:branch_computations|true_computation|false_computation)="
    r"\{?%?([\w.\-,% ]+)\}?")
_WHILE_PARTS = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_TO_APPLY = re.compile(r"to_apply=%?([\w.\-]+)")


def _shape_bytes(tok: str) -> int:
    total = 0
    for m in _SHAPE_TOKEN.finditer(tok):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _split_result_and_op(rhs: str) -> Tuple[str, str, str]:
    """rhs like 'f32[4,32]{1,0} dot(%a, %b), meta...' ->
    (result_shape_str, op_kind, rest)."""
    rhs = rhs.strip()
    if rhs.startswith("("):
        depth = 0
        for i, ch in enumerate(rhs):
            depth += ch == "("
            depth -= ch == ")"
            if depth == 0:
                shape, rest = rhs[: i + 1], rhs[i + 1:].strip()
                break
    else:
        shape, _, rest = rhs.partition(" ")
    m = re.match(r"([\w\-]+)\(", rest)
    op = m.group(1) if m else ""
    return shape, op, rest


@dataclasses.dataclass
class OpInfo:
    name: str
    op: str
    result_bytes: int
    flops: float
    operands: List[str]
    line: str


@dataclasses.dataclass
class CompStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    children: List[Tuple[str, float, str]] = dataclasses.field(
        default_factory=list)  # (comp_name, multiplier, kind)
    fused: List[str] = dataclasses.field(default_factory=list)
    max_constant: int = 1


def _dot_flops(result_bytes_tok: str, rest: str, defs: Dict[str, int],
               operand_names: List[str]) -> float:
    numel = 0
    m = _SHAPE_TOKEN.search(result_bytes_tok)
    if m:
        numel = 1
        for d in m.group(2).split(","):
            if d:
                numel *= int(d)
    cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
    if not operand_names or cm is None:
        return 2.0 * numel
    lhs_shape = defs.get("__shape__" + operand_names[0])
    if lhs_shape is None:
        return 2.0 * numel
    k = 1
    for d in cm.group(1).split(","):
        if d:
            k *= lhs_shape[int(d)]
    return 2.0 * numel * k


def parse_hlo(text: str) -> Dict[str, CompStats]:
    comps: Dict[str, CompStats] = {}
    entry: Optional[str] = None
    cur: Optional[str] = None
    defs: Dict[str, object] = {}

    for raw in text.splitlines():
        hdr = _COMP_HDR.match(raw)
        if hdr:
            cur = hdr.group(2)
            comps[cur] = CompStats()
            defs = {}
            if hdr.group(1):
                entry = cur
            # parameters typed in the header are not needed: gte lines carry
            # their own result shapes.
            continue
        if cur is None:
            continue
        if raw.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(raw)
        if not m:
            # track integer constants for trip-count fallback in conds
            cm = re.search(r"constant\((\d+)\)", raw)
            if cm:
                comps[cur].max_constant = max(
                    comps[cur].max_constant, int(cm.group(1)))
            continue
        name, rhs = m.group(1), m.group(2)
        shape_tok, op, rest = _split_result_and_op(rhs)
        rbytes = _shape_bytes(shape_tok)
        # record shape dims of this def for dot contracting lookups
        sm = _SHAPE_TOKEN.search(shape_tok)
        if sm:
            dims = tuple(int(d) for d in sm.group(2).split(",") if d)
            defs["__shape__" + name] = dims
        defs[name] = rbytes
        st = comps[cur]

        cm = re.search(r"constant\((\d+)\)", rhs)
        if cm:
            st.max_constant = max(st.max_constant, int(cm.group(1)))

        operands = re.findall(r"%([\w.\-]+)", rest)
        if op == "while":
            w = _WHILE_PARTS.search(rest)
            tm = _TRIP_RE.search(rest)
            trip = float(tm.group(1)) if tm else None
            if w:
                st.children.append((w.group(2), trip if trip else -1.0, "while"))
                st.children.append((w.group(1), trip if trip else -1.0, "while"))
            continue
        if op == "conditional":
            bm = _COND_BRANCHES.findall(rest)
            branches = []
            for g in bm:
                branches += [b.strip().lstrip("%") for b in g.split(",")]
            for b in branches:
                if b:
                    st.children.append((b, 1.0, "cond_branch"))
            continue
        if op in ("fusion",):
            c = _CALLS.search(rest)
            if c:
                st.fused.append(c.group(1))
                st.children.append((c.group(1), 1.0, "fusion_flops_only"))
            st.hbm_bytes += rbytes + sum(
                defs.get(o, 0) for o in operands if isinstance(defs.get(o), int))
            continue
        if op in ("call", "custom-call", "async-start"):
            c = _CALLS.search(rest) or _TO_APPLY.search(rest)
            if c:
                st.children.append((c.group(1), 1.0, "call"))
            st.hbm_bytes += rbytes
            continue

        is_coll = False
        for cname in COLLECTIVES:
            if op == cname or op == cname + "-start":
                gm = _GROUPS_IOTA.search(rest)
                if gm:
                    n = int(gm.group(2))
                else:
                    gl = _GROUPS_LIST.search(rest)
                    n = (len([x for x in gl.group(1).split(",") if x.strip()])
                         if gl else 2)
                if cname == "all-reduce":
                    factor = 2.0 * (n - 1) / max(n, 1)
                elif cname == "collective-permute":
                    factor = 1.0
                else:
                    factor = (n - 1) / max(n, 1)
                payload = rbytes
                if cname in ("all-reduce", "reduce-scatter", "all-to-all"):
                    payload = max(
                        rbytes,
                        sum(defs.get(o, 0) for o in operands
                            if isinstance(defs.get(o), int)),
                    )
                st.coll_bytes[cname] += payload * factor
                st.coll_counts[cname] += 1
                is_coll = True
                break
        if is_coll or op.endswith("-done"):
            continue

        if op == "dot":
            st.flops += _dot_flops(shape_tok, rest, defs, operands)
        if op not in NO_COST_OPS:
            st.hbm_bytes += rbytes + sum(
                defs.get(o, 0) for o in operands if isinstance(defs.get(o), int))
    if entry is not None:
        comps["__entry__"] = comps[entry]
    return comps


@dataclasses.dataclass
class HloSummary:
    flops: float
    hbm_bytes: float
    coll_bytes: Dict[str, float]
    coll_counts: Dict[str, float]

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def analyze(text: str) -> HloSummary:
    comps = parse_hlo(text)
    entry = comps.get("__entry__")
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")

    memo: Dict[int, HloSummary] = {}

    def visit(st: CompStats, flops_only: bool) -> HloSummary:
        key = (id(st), flops_only)
        if key in memo:
            return memo[key]
        out = HloSummary(
            flops=st.flops,
            hbm_bytes=0.0 if flops_only else st.hbm_bytes,
            coll_bytes=dict(st.coll_bytes),
            coll_counts=dict(st.coll_counts),
        )
        if flops_only:
            out.coll_bytes = {c: 0.0 for c in COLLECTIVES}
            out.coll_counts = {c: 0.0 for c in COLLECTIVES}
        for child_name, mult, kind in st.children:
            child = comps.get(child_name)
            if child is None:
                continue
            if mult < 0:  # unknown trip count: use cond's max constant
                cond_guess = st.max_constant
                mult = max(float(child.max_constant), float(cond_guess), 1.0)
            sub = visit(child, flops_only or kind == "fusion_flops_only")
            out.flops += mult * sub.flops
            out.hbm_bytes += mult * sub.hbm_bytes
            for c in COLLECTIVES:
                out.coll_bytes[c] += mult * sub.coll_bytes[c]
                out.coll_counts[c] += mult * sub.coll_counts[c]
        memo[key] = out
        return out

    return visit(entry, False)


def collective_stats(hlo_text: str, *, n_devices: int) -> Dict:
    """Back-compat helper: trip-weighted collective summary."""
    s = analyze(hlo_text)
    out = {c: {"count": s.coll_counts[c], "bytes": s.coll_bytes[c]}
           for c in COLLECTIVES}
    out["total_bytes"] = s.total_coll_bytes
    return out
