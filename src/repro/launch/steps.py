"""Builders for the sharded (pjit) train/prefill/decode steps plus the
ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.

No device memory is touched here: parameters/optimizer/caches are
``jax.eval_shape`` structs; the dry-run lowers and compiles against them.
"""

from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, SHAPES
from repro.models.registry import get_model
from repro.sharding import rules
from repro.train.step import TrainState, make_train_state, train_step_fn


def default_microbatches(cfg: ModelConfig, shape: ShapeConfig) -> int:
    if shape.kind != "train":
        return 1
    big = cfg.param_count() > 2e10
    return 8 if big else 4


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        spec = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "targets": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.frontend:
            spec["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.frontend:
            spec["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_frontend_tokens, cfg.d_model), jnp.float32)
        return spec
    # decode: one new token against a seq_len cache
    api = get_model(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(cfg, b, s))
    return {"token": jax.ShapeDtypeStruct((b, 1), i32), "cache": cache}


# ---------------------------------------------------------------------------


def build_train_step(cfg: ModelConfig, mesh, shape: ShapeConfig,
                     *, microbatches: Optional[int] = None):
    """Returns (jitted_fn, (state_struct, batch_struct)) ready to lower."""
    mb = default_microbatches(cfg, shape) if microbatches is None else microbatches
    step = train_step_fn(cfg, microbatches=mb)
    state_struct = jax.eval_shape(
        lambda: make_train_state(cfg, jax.random.PRNGKey(0)))
    batch_struct = input_specs(cfg, shape)

    state_sh = rules.opt_state_shardings(cfg, state_struct, mesh, fsdp=True)
    batch_sh = rules.tree_batch_shardings(batch_struct, mesh)
    metric_sh = {
        "loss": NamedSharding(mesh, P()),
        "grad_norm": NamedSharding(mesh, P()),
        "lr": NamedSharding(mesh, P()),
    }
    jitted = jax.jit(
        step,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, metric_sh),
        donate_argnums=(0,),
    )
    return jitted, (state_struct, batch_struct)


def build_prefill(cfg: ModelConfig, mesh, shape: ShapeConfig):
    api = get_model(cfg)
    params_struct = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    batch_struct = input_specs(cfg, shape)
    max_len = shape.seq_len

    def fn(params, batch):
        kw = {}
        if "prefix_embeds" in batch:
            kw["prefix_embeds"] = batch["prefix_embeds"]
        return api.prefill(cfg, params, batch["tokens"], max_len, **kw)

    out_struct = jax.eval_shape(fn, params_struct, batch_struct)
    params_sh = rules.tree_param_shardings(cfg, params_struct, mesh, fsdp=True)
    batch_sh = rules.tree_batch_shardings(batch_struct, mesh)
    logits_sh = NamedSharding(mesh, rules.batch_spec(out_struct[0].shape, mesh))
    cache_sh = rules.tree_cache_shardings(cfg, out_struct[1], mesh)
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, batch_sh),
        out_shardings=(logits_sh, cache_sh),
    )
    return jitted, (params_struct, batch_struct)


def build_decode(cfg: ModelConfig, mesh, shape: ShapeConfig):
    api = get_model(cfg)
    params_struct = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
    batch_struct = input_specs(cfg, shape)

    def fn(params, batch):
        return api.decode_step(cfg, params, batch["token"], batch["cache"])

    out_struct = jax.eval_shape(fn, params_struct, batch_struct)
    params_sh = rules.tree_param_shardings(cfg, params_struct, mesh, fsdp=True)
    batch_sh = {
        "token": NamedSharding(
            mesh, rules.batch_spec(batch_struct["token"].shape, mesh)),
        "cache": rules.tree_cache_shardings(cfg, batch_struct["cache"], mesh),
    }
    logits_sh = NamedSharding(mesh, rules.batch_spec(out_struct[0].shape, mesh))
    cache_sh = rules.tree_cache_shardings(cfg, out_struct[1], mesh)
    jitted = jax.jit(
        fn,
        in_shardings=(params_sh, batch_sh),
        out_shardings=(logits_sh, cache_sh),
        donate_argnums=(1,),
    )
    return jitted, (params_struct, batch_struct)


def build_step(cfg: ModelConfig, mesh, shape: ShapeConfig, **kw):
    if shape.kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if shape.kind == "prefill":
        return build_prefill(cfg, mesh, shape)
    return build_decode(cfg, mesh, shape)
