import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell against the production mesh, with no device allocation
(ShapeDtypeStruct inputs), and record memory/cost/collective statistics.

The two lines above MUST stay first: jax locks the device count on first
backend initialization.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-2b \
        --shape train_4k [--multi-pod] [--analog]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # resumable sweep

Results land in dryrun_results/<arch>__<shape>__<mesh>.json; existing files
are skipped (the sweep is resumable / parallelizable across invocations).
"""

import argparse
import json
import time
import traceback

import jax

from repro.config import SHAPES
from repro.configs import ARCH_IDS, get_config
from repro.launch import hlo_stats
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import build_step, default_microbatches

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "dryrun_results")


def should_skip(cfg, shape) -> str:
    if shape.name == "long_500k" and not cfg.subquadratic:
        return ("pure full-attention architecture: long_500k requires "
                "sub-quadratic attention (DESIGN.md skip table)")
    return ""


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             microbatches=None, variant: str = "baseline") -> dict:
    from repro.sharding import perf

    with perf.variant(variant):
        return _run_cell_inner(arch, shape_name, multi_pod=multi_pod,
                               microbatches=microbatches, variant=variant)


def _run_cell_inner(arch: str, shape_name: str, *, multi_pod: bool,
                    microbatches=None, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    skip = should_skip(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    meta = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant,
        "kind": shape.kind,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
    }
    if skip:
        return {**meta, "skipped": skip}

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.size
    t0 = time.time()
    with mesh:
        jitted, structs = build_step(cfg, mesh, shape,
                                     **({} if shape.kind != "train" else
                                        {"microbatches": microbatches}))
        lowered = jitted.lower(*structs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes"):
        try:
            mem_d[attr] = int(getattr(mem, attr))
        except (AttributeError, TypeError, ValueError):
            continue        # older jaxlibs omit some memory-analysis attrs
    try:
        cost = dict(compiled.cost_analysis())
        cost = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float))}
    except Exception:
        cost = {}

    txt = compiled.as_text()
    summary = hlo_stats.analyze(txt)
    mb = (default_microbatches(cfg, shape)
          if (shape.kind == "train" and microbatches is None)
          else microbatches)

    return {
        **meta,
        "n_devices": n_dev,
        "microbatches": mb if shape.kind == "train" else None,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": mem_d,
        "cost_analysis_raw": cost,                  # NOTE: loop bodies x1
        "flops_per_device": summary.flops,          # trip-weighted
        "hbm_bytes_per_device": summary.hbm_bytes,
        "collective_bytes_per_device": summary.coll_bytes,
        "collective_counts": summary.coll_counts,
        "total_collective_bytes": summary.total_coll_bytes,
    }


def cell_path(arch, shape_name, multi_pod, variant="baseline"):
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(RESULTS_DIR, exist_ok=True)
    suffix = "" if variant == "baseline" else f"__{variant}"
    return os.path.join(
        RESULTS_DIR, f"{arch}__{shape_name}__{mesh_name}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in (False, True):
                    cells.append((arch, shape, mp))
    else:
        if not (args.arch and args.shape):
            ap.error("--arch and --shape are required unless --all is set")
        cells.append((args.arch, args.shape, args.multi_pod))

    for arch, shape, mp in cells:
        path = cell_path(arch, shape, mp, args.variant)
        if os.path.exists(path) and not args.force:
            print(f"[skip existing] {path}")
            continue
        print(f"=== {arch} x {shape} x "
              f"{'pod2x16x16' if mp else 'pod16x16'} ===", flush=True)
        try:
            res = run_cell(arch, shape, multi_pod=mp,
                           microbatches=args.microbatches,
                           variant=args.variant)
        except Exception as e:
            res = {
                "arch": arch, "shape": shape,
                "mesh": "pod2x16x16" if mp else "pod16x16",
                "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
            }
            print(res["error"], flush=True)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        if "skipped" in res:
            print(f"skipped: {res['skipped']}")
        elif "error" not in res:
            print(f"ok: flops/dev={res['flops_per_device']:.3e} "
                  f"hbm/dev={res['hbm_bytes_per_device']:.3e} "
                  f"coll/dev={res['total_collective_bytes']:.3e} "
                  f"compile={res['compile_s']}s", flush=True)


if __name__ == "__main__":
    main()
