"""Training step: loss, microbatched gradient accumulation, clipping,
AdamW — a single jit-able function suitable for pjit sharding.

Microbatching splits the per-step batch along the batch axis and
accumulates gradients with a ``lax.scan`` (constant memory in the number of
microbatches).  Remat inside the model body (per-layer ``jax.checkpoint``)
plus microbatching is the standard memory lever for the large train cells.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models.registry import get_model
from repro.optim import adamw

MOE_LB_COEF = 0.01


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def make_train_state(cfg: ModelConfig, key: jax.Array,
                     lr: float = 3e-4) -> TrainState:
    api = get_model(cfg)
    params = api.init_params(cfg, key)
    return TrainState(params=params, opt=adamw.init(params),
                      step=jnp.zeros((), jnp.int32))


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    api = get_model(cfg)
    kw = {}
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    logits, aux = api.forward(cfg, params, batch["tokens"], **kw)
    loss = softmax_xent(logits, batch["targets"])
    if "moe/lb_loss" in aux:
        loss = loss + MOE_LB_COEF * jnp.mean(aux["moe/lb_loss"])
    return loss, aux


def train_step_fn(
    cfg: ModelConfig,
    *,
    microbatches: int = 1,
    lr_schedule: Optional[Callable] = None,
    max_grad_norm: float = 1.0,
    weight_decay: float = 0.1,
    lr: float = 3e-4,
) -> Callable[[TrainState, Dict[str, jax.Array]], Tuple[TrainState, dict]]:
    """Build the (jit-able) train step for ``cfg``."""

    grad_fn = jax.grad(lambda p, b: loss_fn(cfg, p, b)[0])

    def split_micro(batch):
        def f(x):
            b = x.shape[0]
            if b % microbatches:
                raise ValueError(
                    f"batch dim {b} not divisible by {microbatches} "
                    f"microbatches")
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        return jax.tree.map(f, batch)

    def step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        if microbatches == 1:
            loss, aux = loss_fn(cfg, state.params, batch)
            grads = grad_fn(state.params, batch)
        else:
            micro = split_micro(batch)

            def acc_body(carry, mb):
                g_acc, l_acc = carry
                l, _ = loss_fn(cfg, state.params, mb)
                g = grad_fn(state.params, mb)
                g_acc = jax.tree.map(jnp.add, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (grads, loss), _ = lax.scan(acc_body, (g0, jnp.zeros(())), micro)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss / microbatches
            aux = {}

        grads, gnorm = adamw.clip_by_global_norm(grads, max_grad_norm)
        lr_t = lr_schedule(state.step) if lr_schedule is not None else lr
        new_params, new_opt = adamw.update(
            grads, state.opt, state.params, lr=lr_t,
            weight_decay=weight_decay)
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": jnp.asarray(lr_t)}
        return (
            TrainState(params=new_params, opt=new_opt, step=state.step + 1),
            metrics,
        )

    return step
