"""Unified model/architecture configuration.

One dataclass covers all ten assigned architectures; family-specific fields
are ignored by families that do not use them.  Every arch file in
``repro.configs`` exports ``CONFIG`` (the exact published shape) and
``smoke_config()`` (a reduced same-family shape for CPU tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    act: str = "swiglu"               # swiglu | geglu | gelu
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma-style sqrt(d) embedding scaling
    # --- attention pattern ----------------------------------------------
    sliding_window: Optional[int] = None   # local layers' window
    local_global_ratio: int = 0            # N local : 1 global (0 = all global)
    # --- MoE --------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # --- SSM / hybrid ------------------------------------------------------
    ssm_state: int = 0                # mamba2 state dim
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    attn_every: int = 0               # zamba: shared attn block period
    rwkv: bool = False
    # --- encoder-decoder / frontends ----------------------------------------
    n_enc_layers: int = 0
    frontend: Optional[str] = None    # "audio_frames" | "vision_patches"
    n_frontend_tokens: int = 0        # patches/frames supplied by the stub
    cross_kv_len: int = 1500          # whisper encoder output length
    # --- numerics ----------------------------------------------------------
    dtype: str = "bfloat16"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.rwkv

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k shape (see DESIGN.md skip table)."""
        return (
            self.rwkv
            or self.ssm_state > 0
            or (self.sliding_window is not None and self.local_global_ratio > 0)
        )

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, hd = self.d_model, self.hd
        attn = d * self.n_heads * hd * 2 + d * self.n_kv_heads * hd * 2
        if self.act in ("swiglu", "geglu"):
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        per_layer = attn + mlp
        if self.n_experts:
            per_layer = attn + self.n_experts * 3 * d * self.moe_d_ff
            if self.dense_residual:
                per_layer += 3 * d * self.d_ff
        if self.ssm_state:
            # mamba2-ish: in_proj + out_proj dominate
            din = self.ssm_heads * self.ssm_head_dim
            per_layer = d * (2 * din + 2 * self.ssm_state + self.ssm_heads) + din * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        total = self.n_layers * per_layer + emb
        if self.n_enc_layers:
            total += self.n_enc_layers * (attn + mlp)
        return int(total)

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE top-k active experts)."""
        if not self.n_experts:
            return self.param_count()
        d = self.d_model
        attn = d * self.n_heads * self.hd * 2 + d * self.n_kv_heads * self.hd * 2
        per_layer = attn + self.top_k * 3 * d * self.moe_d_ff
        if self.dense_residual:
            per_layer += 3 * d * self.d_ff
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(self.n_layers * per_layer + emb)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
