"""Fault tolerance & straggler mitigation for the training runtime.

On a real cluster the failure domains are: device loss (XLA raises), host
loss (process death — covered by checkpoint/restart + deterministic data
replay), and slow nodes.  This module provides the single-process pieces:

* ``resilient_step`` — retries a step on transient errors with exponential
  backoff; non-transient (deterministic) errors re-raise immediately.
  After ``max_retries`` it raises ``StepFailed`` so the launcher can
  checkpoint-restart (or shrink the mesh — see ``elastic.py``).
* ``StragglerMonitor`` — tracks per-step wall times, flags ``> mean +
  k*std`` outliers, and calls an eviction hook.  On multi-pod deployments
  the hook would demote the slow host and trigger an elastic restart; here
  it records the event (tested with injected delays).
* ``Heartbeat`` — a daemon-thread liveness file (mtime = last heartbeat),
  the signal an external supervisor (k8s / SLURM) watches.
"""

from __future__ import annotations

import os
import statistics
import threading
import time
from typing import Callable, List, Optional, Tuple

TRANSIENT_ERRORS = (OSError, RuntimeError)


class StepFailed(RuntimeError):
    pass


def resilient_step(
    fn: Callable,
    *args,
    max_retries: int = 3,
    backoff_s: float = 0.05,
    transient: Tuple = TRANSIENT_ERRORS,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    **kwargs,
):
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except transient as e:  # pragma: no branch
            attempt += 1
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt > max_retries:
                raise StepFailed(
                    f"step failed after {max_retries} retries: {e!r}"
                ) from e
            time.sleep(backoff_s * (2 ** (attempt - 1)))


class StragglerMonitor:
    def __init__(self, *, k_sigma: float = 3.0, window: int = 50,
                 min_samples: int = 10,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.k = k_sigma
        self.window = window
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.flagged: List[Tuple[int, float]] = []
        self._step = 0

    def record(self, dt: float) -> bool:
        """Record one step duration; returns True if flagged."""
        self._step += 1
        hist = self.times[-self.window:]
        flagged = False
        if len(hist) >= self.min_samples:
            mu = statistics.fmean(hist)
            sd = statistics.pstdev(hist) or 1e-9
            if dt > mu + self.k * sd:
                flagged = True
                self.flagged.append((self._step, dt))
                if self.on_straggler is not None:
                    self.on_straggler(self._step, dt)
        self.times.append(dt)
        return flagged

    def timed(self, fn: Callable, *args, **kwargs):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        self.record(time.perf_counter() - t0)
        return out


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 5.0):
        self.path = path
        self.interval = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        def beat():
            while not self._stop.wait(self.interval):
                self._touch()

        self._touch()
        self._thread = threading.Thread(target=beat, daemon=True)
        self._thread.start()

    def _touch(self):
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join()

    def age(self) -> float:
        return time.time() - os.path.getmtime(self.path)
