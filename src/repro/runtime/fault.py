"""Fault tolerance & straggler mitigation for the training runtime.

On a real cluster the failure domains are: device loss (XLA raises), host
loss (process death — covered by checkpoint/restart + deterministic data
replay), and slow nodes.  This module provides the single-process pieces:

* ``resilient_step`` — retries a step on transient errors with exponential
  backoff; non-transient (deterministic) errors re-raise immediately.
  After ``max_retries`` it raises ``StepFailed`` so the launcher can
  checkpoint-restart (or shrink the mesh — see ``elastic.py``).  What
  counts as transient is deliberately narrow (:func:`is_transient`):
  connection/timeout OS errors, plus XLA runtime errors whose message
  carries an explicitly-transient RPC status (UNAVAILABLE, DEADLINE
  EXCEEDED, ...).  A bare ``RuntimeError`` is *not* transient — retrying
  a deterministic failure (shape error, NaN guard, assertion) just burns
  ``max_retries`` walltime before failing anyway, and in the serving
  heal path would triple-program a band for nothing.
* ``StragglerMonitor`` — tracks per-step wall times, flags ``> mean +
  k*std`` outliers, and calls an eviction hook.  On multi-pod deployments
  the hook would demote the slow host and trigger an elastic restart; here
  it records the event (tested with injected delays).
* ``Heartbeat`` — a daemon-thread liveness file (mtime = last heartbeat),
  the signal an external supervisor (k8s / SLURM) watches.
"""

from __future__ import annotations

import os
import statistics
import threading
import time
from typing import Callable, List, Optional, Tuple

#: exception types that are transient *by construction* — lost
#: connections and timeouts get retried, everything else re-raises.
#: (``OSError``/``RuntimeError`` wholesale would swallow deterministic
#: failures: FileNotFoundError is an OSError, XLA shape errors are
#: RuntimeErrors.)
TRANSIENT_ERRORS = (
    ConnectionError,
    TimeoutError,
    InterruptedError,
)

#: RPC status fragments marking a jaxlib ``XlaRuntimeError`` (a
#: RuntimeError subclass with no stable taxonomy of its own) as
#: transient: gRPC/absl status codes of retryable distributed-runtime
#: failures, plus device-side transfer hiccups.
TRANSIENT_XLA_MESSAGES = (
    "UNAVAILABLE",
    "DEADLINE_EXCEEDED",
    "DEADLINE EXCEEDED",
    "ABORTED",
    "RESOURCE_EXHAUSTED",
    "RESOURCE EXHAUSTED",
    "failed to transfer",
    "connection reset",
)


class StepFailed(RuntimeError):
    pass


def is_transient(e: BaseException) -> bool:
    """Is ``e`` worth retrying?  Explicit transient types, or an XLA
    runtime error whose status string is on the transient allowlist."""
    if isinstance(e, TRANSIENT_ERRORS):
        return True
    if type(e).__name__ == "XlaRuntimeError":
        msg = str(e).upper()
        return any(frag.upper() in msg for frag in TRANSIENT_XLA_MESSAGES)
    return False


def resilient_step(
    fn: Callable,
    *args,
    max_retries: int = 3,
    backoff_s: float = 0.05,
    transient: Optional[Tuple] = None,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
    **kwargs,
):
    attempt = 0
    while True:
        try:
            return fn(*args, **kwargs)
        except Exception as e:
            retryable = (is_transient(e) if transient is None
                         else isinstance(e, transient))
            if not retryable:
                raise
            attempt += 1
            if on_retry is not None:
                on_retry(attempt, e)
            if attempt > max_retries:
                raise StepFailed(
                    f"step failed after {max_retries} retries: {e!r}"
                ) from e
            time.sleep(backoff_s * (2 ** (attempt - 1)))


class StragglerMonitor:
    def __init__(self, *, k_sigma: float = 3.0, window: int = 50,
                 min_samples: int = 10,
                 on_straggler: Optional[Callable[[int, float], None]] = None):
        self.k = k_sigma
        self.window = window
        self.min_samples = min_samples
        self.on_straggler = on_straggler
        self.times: List[float] = []
        self.flagged: List[Tuple[int, float]] = []
        self._step = 0

    def record(self, dt: float) -> bool:
        """Record one step duration; returns True if flagged."""
        self._step += 1
        hist = self.times[-self.window:]
        flagged = False
        if len(hist) >= self.min_samples:
            mu = statistics.fmean(hist)
            sd = statistics.pstdev(hist) or 1e-9
            if dt > mu + self.k * sd:
                flagged = True
                self.flagged.append((self._step, dt))
                if self.on_straggler is not None:
                    self.on_straggler(self._step, dt)
        self.times.append(dt)
        return flagged

    def timed(self, fn: Callable, *args, **kwargs):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        self.record(time.perf_counter() - t0)
        return out


class Heartbeat:
    def __init__(self, path: str, interval_s: float = 5.0):
        self.path = path
        self.interval = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self):
        def beat():
            while not self._stop.wait(self.interval):
                self._touch()

        self._touch()
        self._thread = threading.Thread(target=beat, daemon=True)
        self._thread.start()

    def _touch(self):
        with open(self.path, "w") as f:
            f.write(str(time.time()))

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join()

    def age(self) -> float:
        return time.time() - os.path.getmtime(self.path)
