"""arctic-480b [moe]: 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 PLUS a parallel dense residual FFN
[hf:Snowflake/snowflake-arctic-base; hf]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=4864, vocab=32000, act="swiglu",
    n_experts=128, top_k=2, moe_d_ff=4864, dense_residual=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, n_experts=8, top_k=2, moe_d_ff=48, capacity_factor=8.0,
        dtype="float32", remat=False)
