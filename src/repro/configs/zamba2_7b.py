"""zamba2-7b [hybrid]: 81L d=3584 32H (MHA) d_ff=14336 vocab=32000,
Mamba2 backbone (ssm_state=64) + shared attention blocks
[arXiv:2411.15242; unverified]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32,
    d_ff=14336, vocab=32000, act="geglu",
    ssm_state=64, ssm_heads=56, ssm_head_dim=128, attn_every=6,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=128, ssm_state=8, ssm_heads=4, ssm_head_dim=16,
        attn_every=2, dtype="float32", remat=False)
