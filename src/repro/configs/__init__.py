"""Assigned architecture configs.  Each module exports ``CONFIG`` (the exact
published shape) and ``smoke_config()`` (a reduced same-family shape).

``get_config(arch_id)`` resolves by id (dashes or underscores).
"""

from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCH_IDS = [
    "gemma-2b",
    "gemma3-1b",
    "qwen1.5-4b",
    "qwen3-14b",
    "arctic-480b",
    "qwen3-moe-235b-a22b",
    "zamba2-7b",
    "internvl2-26b",
    "rwkv6-3b",
    "whisper-large-v3",
]


def _module_name(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_module_name(arch_id)}")
    return mod.smoke_config()
