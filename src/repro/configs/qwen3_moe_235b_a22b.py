"""qwen3-moe-235b-a22b [moe]: 94L d=4096 64H (GQA kv=4) expert d_ff=1536
vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3 MoE family; hf]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4,
    d_ff=1536, vocab=151936, act="swiglu", qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=1536,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=48, vocab=128, n_experts=8, top_k=2, moe_d_ff=48, capacity_factor=8.0,
        dtype="float32", remat=False)
