"""rwkv6-3b "Finch" [ssm]: 32L d=2560 (attention-free) d_ff=8960
vocab=65536, data-dependent decay [arXiv:2404.05892; hf]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536, act="gelu", rwkv=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=128, dtype="float32", remat=False)
