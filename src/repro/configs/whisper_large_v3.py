"""whisper-large-v3 [audio]: 32+32L enc-dec, d=1280 20H (MHA) d_ff=5120
vocab=51866; conv/mel frontend is a STUB (precomputed frame embeddings)
[arXiv:2212.04356; unverified].  Assigned seq shapes apply to the decoder
token stream; the encoder runs the fixed 1500-frame (30 s) window."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio",
    n_layers=32, n_enc_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, act="gelu", norm="layernorm",
    tie_embeddings=True, frontend="audio_frames", n_frontend_tokens=1500,
    cross_kv_len=1500,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=96, vocab=128, n_frontend_tokens=8,
        cross_kv_len=8, dtype="float32", remat=False)
