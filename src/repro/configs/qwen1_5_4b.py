"""qwen1.5-4b [dense]: 40L d=2560 20H (MHA kv=20) d_ff=6912 vocab=151936,
QKV bias [hf:Qwen/Qwen1.5 family; hf]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_heads=20, n_kv_heads=20,
    d_ff=6912, vocab=151936, act="swiglu", qkv_bias=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=96, vocab=128, dtype="float32", remat=False)
