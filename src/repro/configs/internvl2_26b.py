"""internvl2-26b [vlm]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92553
(InternLM2-20B text backbone); InternViT frontend is a STUB — input_specs
supplies precomputed patch embeddings [arXiv:2404.16821; hf]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92553, act="swiglu",
    frontend="vision_patches", n_frontend_tokens=256,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, n_frontend_tokens=4, dtype="float32", remat=False)
