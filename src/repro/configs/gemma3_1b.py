"""gemma3-1b [dense]: 26L d=1152 4H (kv=1) d_ff=6912 vocab=262144,
5:1 local:global sliding-window attention, 128k ctx
[hf:google/gemma-3-1b-pt; unverified]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab=262144, act="geglu", qk_norm=True,
    tie_embeddings=True, embed_scale=True,
    sliding_window=1024, local_global_ratio=5, rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=2, n_kv_heads=1, head_dim=32,
        d_ff=96, vocab=128, sliding_window=8, local_global_ratio=2,
        dtype="float32", remat=False)
