"""qwen3-14b [dense]: 40L d=5120 40H (GQA kv=8) d_ff=17408 vocab=151936,
per-head qk_norm [hf:Qwen/Qwen3 family; hf]."""

import dataclasses

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8,
    d_ff=17408, vocab=151936, act="swiglu", qk_norm=True,
    rope_theta=1_000_000.0,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=96, vocab=128, dtype="float32", remat=False)
