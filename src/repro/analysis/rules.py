"""The lint passes: hazard classes this repo has actually shipped.

Each rule is a function over a parsed :class:`~repro.analysis.walker.
Module` returning :class:`~repro.analysis.findings.Finding` rows.  The
catalog (DESIGN.md §Static-analysis):

``spmd-concat``
    Concatenation that reassembles slices of one array along an axis —
    the exact shape of the PR 3 rope miscompile: XLA's SPMD partitioner
    miscompiles concat-of-slices on a model-sharded dim on multi-axis
    meshes, *silently* (even an identity slice+concat corrupts).  Any
    ``concatenate([f(x[..., :h]), g(x[..., h:])], axis)`` where two or
    more operands contain non-trivial slices of the same base array.

``pallas-tile``
    Pallas ``BlockSpec`` tile shapes violating Mosaic's TPU layout
    rules: the lane (last) tile must be a multiple of 128, the sublane
    (second-to-last) a multiple of 8 (the float32 floor; 16/32 for
    narrower dtypes).  Interpret mode tolerates any tile, which is how
    the ``_pick_tile`` sublane-rounded N tile stayed latent until TPU
    compilation (PR 3).  Literal shapes and one-step constant
    assignments are checked; unresolvable dynamic tiles are skipped.

``prng-reuse``
    One PRNG key expression consumed by two sampling calls without an
    interleaving ``split``/``fold_in`` — correlated draws that silently
    destroy trial independence.  Straight-line per-function scan; a key
    reassigned between uses is refreshed, and keys that are themselves
    fresh ``split``/``fold_in`` call results are exempt.

``prng-seed``
    Literal integer seeds (``jax.random.PRNGKey(0)``) in library code —
    seeds must be threaded parameters so callers control determinism
    (tests and benchmarks pin seeds deliberately and are not scanned).
    Keys built inside ``jax.eval_shape`` are exempt: they are
    shape-structural and never draw randomness.

``host-sync``
    ``.item()`` / ``float()`` / ``np.asarray`` / ``jax.device_get``
    lexically reachable (same-module call graph) from a jitted body —
    a trace-time crash at best, a silent device sync in the decode hot
    path at worst.  Roots are ``@jax.jit`` defs, ``jax.jit(fn)`` /
    ``jax.jit(jax.vmap(fn))`` call sites, and the returned inner defs
    of ``jax.jit(make_fn())`` factories.

``bare-assert``
    ``assert`` in library code: stripped under ``python -O``, and the
    bare form carries no actionable message (the class cleaned up
    piecemeal in PRs 4-6 — entry points now raise ``ValueError``).

``silent-except``
    ``except:`` / ``except Exception:`` whose body is only ``pass`` —
    the silent-fallback class: failures vanish instead of narrowing the
    handler to the exceptions actually expected.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.walker import Module, rule

# -- canonical name sets ----------------------------------------------------

_CONCAT_FNS = {
    "jax.numpy.concatenate", "jax.numpy.concat", "numpy.concatenate",
    "jax.lax.concatenate",
}

#: jax.random calls that CONSUME a key (draw randomness from it)
_PRNG_CONSUMERS = {
    "ball", "bernoulli", "beta", "bits", "categorical", "cauchy", "chisquare",
    "choice", "dirichlet", "double_sided_maxwell", "exponential", "gamma",
    "generalized_normal", "geometric", "gumbel", "laplace", "loggamma",
    "logistic", "maxwell", "multivariate_normal", "normal", "orthogonal",
    "pareto", "permutation", "poisson", "rademacher", "randint", "rayleigh",
    "t", "truncated_normal", "uniform", "wald", "weibull_min",
}
#: jax.random calls that derive fresh keys (refresh, never consume)
_PRNG_DERIVERS = {"split", "fold_in", "clone", "PRNGKey", "key"}

_HOST_SYNC_FNS = {
    "numpy.asarray": "np.asarray",
    "numpy.array": "np.array",
    "jax.device_get": "jax.device_get",
    "float": "float()",
}

_SUBLANE = 8          # float32 sublane multiple (16/32 for bf16/int8)
_LANE = 128           # Mosaic lane width, all dtypes


# ---------------------------------------------------------------------------
# (a) SPMD hazard: concat-of-slices
# ---------------------------------------------------------------------------


def _slice_bases(node: ast.AST) -> Set[str]:
    """Base names of non-trivially-sliced subscripts inside ``node``.

    Non-trivial = the subscript contains a ``Slice`` with an explicit
    bound (``x[..., :h]``, ``x[h:]``); full slices used for newaxis
    plumbing (``x[:, None]``) don't count.
    """
    bases: Set[str] = set()
    for sub in ast.walk(node):
        if not isinstance(sub, ast.Subscript):
            continue
        slices = (sub.slice.elts if isinstance(sub.slice, ast.Tuple)
                  else [sub.slice])
        if not any(isinstance(s, ast.Slice)
                   and (s.lower is not None or s.upper is not None)
                   for s in slices):
            continue
        base = sub.value
        while isinstance(base, (ast.Subscript, ast.Attribute)):
            base = base.value
        if isinstance(base, ast.Name):
            bases.add(base.id)
    return bases


def _slice_aliases(scope: ast.AST) -> Dict[str, Set[str]]:
    """Names assigned exactly once from a sliced expression in ``scope``,
    mapped to the slice's base names — resolves the rope's idiom
    ``x1, x2 = x[..., :half], x[..., half:]`` so the concat check sees
    through the intermediate names."""
    aliases: Dict[str, Set[str]] = {}
    counts: Dict[str, int] = {}
    for node in ast.walk(scope):
        if not isinstance(node, ast.Assign):
            continue
        for tgt in node.targets:
            if (isinstance(tgt, (ast.Tuple, ast.List))
                    and isinstance(node.value, (ast.Tuple, ast.List))
                    and len(tgt.elts) == len(node.value.elts)):
                pairs = list(zip(tgt.elts, node.value.elts))
            else:
                pairs = [(tgt, node.value)]
            for t, v in pairs:
                if isinstance(t, ast.Name):
                    counts[t.id] = counts.get(t.id, 0) + 1
                    bases = _slice_bases(v)
                    if bases:
                        aliases[t.id] = bases
    return {n: b for n, b in aliases.items() if counts.get(n) == 1}


@rule("spmd-concat")
def check_spmd_concat(mod: Module) -> List[Finding]:
    out = []
    for call in mod.walk_calls():
        if mod.call_name(call) not in _CONCAT_FNS:
            continue
        if not call.args or not isinstance(call.args[0], (ast.List, ast.Tuple)):
            continue
        elems = call.args[0].elts
        if len(elems) < 2:
            continue
        scope = mod.enclosing_function(call) or mod.tree
        aliases = _slice_aliases(scope)

        def elem_bases(e: ast.AST) -> Set[str]:
            bases = _slice_bases(e)
            for n in ast.walk(e):
                if isinstance(n, ast.Name) and n.id in aliases:
                    bases |= aliases[n.id]
            return bases

        per_elem = [elem_bases(e) for e in elems]
        shared = sorted(
            b for b in set().union(*per_elem)
            if sum(b in bs for bs in per_elem) >= 2)
        for base in shared:
            out.append(Finding(
                "spmd-concat", mod.path, call.lineno,
                f"concatenation reassembles slices of {base!r}: "
                f"concat-of-slices along a model-sharded dim miscompiles "
                f"in the XLA SPMD partitioner on multi-axis meshes (the "
                f"PR 3 rope bug) — rewrite with roll/where/elementwise "
                f"ops on the full array"))
    return out


# ---------------------------------------------------------------------------
# (b) Pallas BlockSpec tile constraints
# ---------------------------------------------------------------------------


@rule("pallas-tile")
def check_pallas_tile(mod: Module) -> List[Finding]:
    out = []
    for call in mod.walk_calls():
        name = mod.call_name(call)
        if name is None or not name.endswith("BlockSpec"):
            continue
        if not call.args or not isinstance(call.args[0], ast.Tuple):
            continue
        shape = call.args[0].elts
        scope = mod.enclosing_function(call)
        dims = [mod.int_value(e, scope) for e in shape]
        if len(dims) >= 1 and dims[-1] is not None:
            lane = dims[-1]
            if lane != 1 and lane % _LANE != 0:
                out.append(Finding(
                    "pallas-tile", mod.path, call.lineno,
                    f"BlockSpec lane (last-dim) tile {lane} is not a "
                    f"multiple of {_LANE}: Mosaic requires full lane "
                    f"tiles — interpret mode tolerates this, TPU "
                    f"compilation does not (the _pick_tile bug class); "
                    f"pad N up to one {_LANE} tile instead"))
        if len(dims) >= 2 and dims[-2] is not None:
            sub = dims[-2]
            if sub != 1 and sub % _SUBLANE != 0:
                out.append(Finding(
                    "pallas-tile", mod.path, call.lineno,
                    f"BlockSpec sublane (second-minor) tile {sub} is not "
                    f"a multiple of {_SUBLANE} (the float32 sublane "
                    f"multiple; narrower dtypes need 16/32)"))
    return out


# ---------------------------------------------------------------------------
# (c) PRNG hygiene
# ---------------------------------------------------------------------------


def _prng_call_kind(mod: Module, call: ast.Call) -> Optional[str]:
    """'consume' / 'derive' / None for a call node."""
    name = mod.call_name(call)
    if name is None or not name.startswith("jax.random."):
        return None
    tail = name.rsplit(".", 1)[-1]
    if tail in _PRNG_CONSUMERS:
        return "consume"
    if tail in _PRNG_DERIVERS:
        return "derive"
    return None


def _key_arg(call: ast.Call) -> Optional[ast.AST]:
    if call.args:
        return call.args[0]
    for kw in call.keywords:
        if kw.arg == "key":
            return kw.value
    return None


def _assigned_names(stmt: ast.stmt) -> Set[str]:
    """Names (re)bound by ``stmt`` — assignment targets, loop vars, withitems."""
    names: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign, ast.For)):
        targets = [stmt.target]
    elif isinstance(stmt, ast.With):
        targets = [i.optional_vars for i in stmt.items if i.optional_vars]
    for t in targets:
        for n in ast.walk(t):
            if isinstance(n, ast.Name):
                names.add(n.id)
    for n in ast.walk(stmt):
        if isinstance(n, ast.NamedExpr) and isinstance(n.target, ast.Name):
            names.add(n.target.id)
    return names


def _scan_prng_block(mod: Module, body: List[ast.stmt],
                     consumed: Dict[str, int], out: List[Finding]) -> None:
    """Branch-aware linear scan: ``consumed`` maps key-expr text to its
    first-use line and is mutated in place.  Exclusive branches (if/
    else, try/except) fork a copy each and merge afterwards, so a
    consumer per branch never counts as sequential reuse."""
    for stmt in body:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue                # separate scope, scanned separately
        # consumption before refresh within one statement is fine
        # (x = normal(key) does not refresh key) — scan uses first
        for call in mod.own_calls(stmt):
            if _prng_call_kind(mod, call) != "consume":
                continue
            key = _key_arg(call)
            if key is None:
                continue
            if (isinstance(key, ast.Call)
                    and _prng_call_kind(mod, key) == "derive"):
                continue                # inline split/fold_in: fresh
            text = ast.unparse(key)
            if text in consumed:
                out.append(Finding(
                    "prng-reuse", mod.path, call.lineno,
                    f"PRNG key {text!r} already consumed on line "
                    f"{consumed[text]} — two consumers of one key "
                    f"draw correlated randomness; split/fold_in "
                    f"between uses"))
            else:
                consumed[text] = call.lineno
        rebound = _assigned_names(stmt)
        if rebound:
            stale = [t for t in consumed
                     if rebound.intersection(
                         n.id for n in ast.walk(ast.parse(t, mode="eval"))
                         if isinstance(n, ast.Name))]
            for t in stale:
                del consumed[t]
        if isinstance(stmt, ast.If):
            branches = [stmt.body, stmt.orelse]
        elif isinstance(stmt, ast.Try):
            branches = ([stmt.body + stmt.orelse]
                        + [h.body for h in stmt.handlers])
        else:
            for attr in ("body", "orelse"):   # loops, with: sequential
                sub = getattr(stmt, attr, None)
                if isinstance(sub, list) and sub:
                    _scan_prng_block(mod, sub, consumed, out)
            continue
        merged: Dict[str, int] = {}
        for br in branches:
            fork = dict(consumed)
            _scan_prng_block(mod, br, fork, out)
            for t, ln in fork.items():
                merged[t] = min(ln, merged.get(t, ln))
        if isinstance(stmt, ast.Try):
            _scan_prng_block(mod, stmt.finalbody, merged, out)
        consumed.clear()
        consumed.update(merged)


@rule("prng-reuse")
def check_prng_reuse(mod: Module) -> List[Finding]:
    out: List[Finding] = []
    for info in mod.functions:
        body = getattr(info.node, "body", None)
        if isinstance(body, list):          # Lambda bodies: single expr
            _scan_prng_block(mod, body, {}, out)
    return out


@rule("prng-seed")
def check_prng_seed(mod: Module) -> List[Finding]:
    out = []
    for call in mod.walk_calls():
        name = mod.call_name(call)
        if name not in ("jax.random.PRNGKey", "jax.random.key"):
            continue
        if not call.args or not isinstance(call.args[0], ast.Constant) \
                or not isinstance(call.args[0].value, int):
            continue
        # shape-structural keys under jax.eval_shape never draw randomness
        cur = mod.parents.get(call)
        structural = False
        while cur is not None:
            if isinstance(cur, ast.Call) \
                    and mod.call_name(cur) == "jax.eval_shape":
                structural = True
                break
            cur = mod.parents.get(cur)
        if structural:
            continue
        out.append(Finding(
            "prng-seed", mod.path, call.lineno,
            f"literal integer seed {name.rsplit('.', 1)[-1]}"
            f"({call.args[0].value}) in library code — thread a seed/key "
            f"parameter instead (pinned seeds belong in tests/benchmarks)"))
    return out


# ---------------------------------------------------------------------------
# (d) host sync reachable from jitted bodies
# ---------------------------------------------------------------------------


def _unwrap_transform(mod: Module, node: ast.AST) -> Optional[ast.AST]:
    """Peel jax.vmap / functools.partial / grad wrappers off a jit arg."""
    wrappers = {"jax.vmap", "jax.grad", "jax.value_and_grad",
                "functools.partial", "jax.checkpoint", "jax.remat"}
    while isinstance(node, ast.Call) and mod.call_name(node) in wrappers \
            and node.args:
        node = node.args[0]
    return node


def _jit_roots(mod: Module) -> List[ast.AST]:
    """Function defs whose bodies are traced under jax.jit in this module."""
    roots: List[ast.AST] = []

    def defs_named(name: str) -> List[ast.AST]:
        return [i.node for i in mod.by_name.get(name, [])]

    for info in mod.functions:
        decs = getattr(info.node, "decorator_list", [])
        for d in decs:
            target = _unwrap_transform(mod, d)
            if (mod.dotted_name(target) == "jax.jit"
                    or (isinstance(target, ast.Call)
                        and mod.call_name(target) == "jax.jit")):
                roots.append(info.node)

    for call in mod.walk_calls():
        if mod.call_name(call) != "jax.jit" or not call.args:
            continue
        arg = _unwrap_transform(mod, call.args[0])
        if isinstance(arg, ast.Lambda):
            roots.append(arg)
        elif isinstance(arg, ast.Name):
            roots.extend(defs_named(arg.id))
        elif isinstance(arg, ast.Call):
            # jax.jit(self._make_decode_fn()) — the factory's returned
            # inner defs are the real traced bodies
            factory = arg.func
            fname = (factory.attr if isinstance(factory, ast.Attribute)
                     else factory.id if isinstance(factory, ast.Name)
                     else None)
            for fdef in defs_named(fname) if fname else []:
                for n in ast.walk(fdef):
                    if isinstance(n, ast.Return) \
                            and isinstance(n.value, ast.Name):
                        roots.extend(
                            d for d in defs_named(n.value.id)
                            if mod.enclosing_function(d) is fdef)
    return roots


def _reachable(mod: Module, roots: List[ast.AST]) -> List[ast.AST]:
    """Same-module call-graph closure over bare-name and self.* calls."""
    seen: List[ast.AST] = []
    frontier = list(roots)
    while frontier:
        fn = frontier.pop()
        if any(fn is s for s in seen):
            continue
        seen.append(fn)
        for call in mod.walk_calls(fn):
            f = call.func
            name = (f.id if isinstance(f, ast.Name)
                    else f.attr if isinstance(f, ast.Attribute)
                    and isinstance(f.value, ast.Name) and f.value.id == "self"
                    else None)
            if name:
                frontier.extend(i.node for i in mod.by_name.get(name, []))
    return seen


@rule("host-sync")
def check_host_sync(mod: Module) -> List[Finding]:
    out = []
    flagged = set()
    for fn in _reachable(mod, _jit_roots(mod)):
        fn_name = getattr(fn, "name", "<lambda>")
        for call in mod.walk_calls(fn):
            site = None
            if isinstance(call.func, ast.Attribute) \
                    and call.func.attr == "item" and not call.args:
                site = ".item()"
            else:
                name = mod.call_name(call)
                if name in _HOST_SYNC_FNS and call.args \
                        and not isinstance(call.args[0], ast.Constant):
                    site = _HOST_SYNC_FNS[name]
            if site and (call.lineno, site) not in flagged:
                flagged.add((call.lineno, site))
                out.append(Finding(
                    "host-sync", mod.path, call.lineno,
                    f"{site} inside {fn_name!r}, which is traced under "
                    f"jax.jit in this module — host sync in a jitted hot "
                    f"path (trace-time crash on traced values, silent "
                    f"pipeline stall on constants)"))
    return out


# ---------------------------------------------------------------------------
# (e) guard hygiene: bare assert / silent except
# ---------------------------------------------------------------------------


@rule("bare-assert")
def check_bare_assert(mod: Module) -> List[Finding]:
    return [
        Finding("bare-assert", mod.path, node.lineno,
                "assert in library code: stripped under python -O and "
                "invisible to callers — raise ValueError (or a typed "
                "error) with a message instead")
        for node in ast.walk(mod.tree) if isinstance(node, ast.Assert)
    ]


@rule("silent-except")
def check_silent_except(mod: Module) -> List[Finding]:
    out = []
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        broad = node.type is None or mod.dotted_name(node.type) in (
            "Exception", "BaseException")
        silent = all(
            isinstance(st, ast.Pass)
            or (isinstance(st, ast.Expr)
                and isinstance(st.value, ast.Constant))
            for st in node.body)
        if broad and silent:
            out.append(Finding(
                "silent-except", mod.path, node.lineno,
                "broad except with a pass-only body swallows every "
                "failure silently — narrow to the exceptions actually "
                "expected, or handle/log them"))
    return out
