"""The shared AST layer under the lint passes.

One :class:`Module` per analyzed file: the parsed tree plus the derived
facts every rule needs —

* **import aliases** — ``import jax.numpy as jnp`` / ``from jax import
  random`` are resolved so rules match *canonical* dotted names
  (``jax.numpy.concatenate``) regardless of local spelling;
* **function table** — every ``def`` (module-level, method, nested) with
  its enclosing scope, so intra-module call graphs can be walked
  (``repro.analysis.rules`` uses this to decide jit-reachability);
* **parent links** — ``ast`` has none; rules need them to ask "is this
  call inside that function".

Rules are small classes registered with :func:`rule`; the runner in
``repro.analysis.report`` instantiates each against a :class:`Module`
and collects findings.  No rule mutates the tree.
"""

from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Tuple

#: registry: rule id -> check(Module) -> list[Finding]
RULES: Dict[str, Callable] = {}


def rule(rule_id: str):
    """Register a lint pass under ``rule_id`` (the suppression name)."""
    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id!r}")
        RULES[rule_id] = fn
        fn.rule_id = rule_id
        return fn
    return deco


@dataclasses.dataclass
class FunctionInfo:
    """One ``def`` (or lambda) and its lexical position."""

    node: ast.AST                    # FunctionDef | AsyncFunctionDef | Lambda
    name: str                        # "<lambda>" for lambdas
    parent: Optional[ast.AST]        # enclosing def, or None at module level


class Module:
    """A parsed source file plus the derived lookup tables."""

    def __init__(self, source: str, path: str):
        self.source = source
        self.path = path
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(self.tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        # local alias -> canonical dotted prefix
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module:
                for a in node.names:
                    self.aliases[a.asname or a.name] = (
                        f"{node.module}.{a.name}")
        # every def, with its enclosing def (None = module/class level)
        self.functions: List[FunctionInfo] = []
        self.by_name: Dict[str, List[FunctionInfo]] = {}
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                name = getattr(node, "name", "<lambda>")
                info = FunctionInfo(node, name, self.enclosing_function(node))
                self.functions.append(info)
                self.by_name.setdefault(name, []).append(info)

    # -- generic helpers ---------------------------------------------------

    def enclosing_function(self, node: ast.AST) -> Optional[ast.AST]:
        """The nearest enclosing def/lambda, or None."""
        cur = self.parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return cur
            cur = self.parents.get(cur)
        return None

    def dotted_name(self, node: ast.AST) -> Optional[str]:
        """Canonical dotted name of a Name/Attribute chain, alias-resolved.

        ``jnp.concatenate`` -> ``jax.numpy.concatenate`` (given ``import
        jax.numpy as jnp``); returns None for non-name expressions.
        """
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id, node.id)
        return ".".join([head] + list(reversed(parts)))

    def call_name(self, call: ast.Call) -> Optional[str]:
        return self.dotted_name(call.func)

    def walk_calls(self, root: Optional[ast.AST] = None) -> Iterator[ast.Call]:
        for node in ast.walk(root if root is not None else self.tree):
            if isinstance(node, ast.Call):
                yield node

    def int_value(self, node: ast.AST,
                  scope: Optional[ast.AST] = None) -> Optional[int]:
        """Resolve ``node`` to an int: a literal, or a name assigned a
        single int literal inside ``scope`` (one-step constant folding —
        enough to see through ``bn = 64`` into ``BlockSpec((bm, bn))``)."""
        if isinstance(node, ast.Constant) and isinstance(node.value, int) \
                and not isinstance(node.value, bool):
            return node.value
        if isinstance(node, ast.Name) and scope is not None:
            value: Optional[int] = None
            for n in ast.walk(scope):
                if isinstance(n, ast.Assign):
                    for t in n.targets:
                        if isinstance(t, ast.Name) and t.id == node.id:
                            if (isinstance(n.value, ast.Constant)
                                    and isinstance(n.value.value, int)
                                    and not isinstance(n.value.value, bool)):
                                # ambiguous reassignment -> give up
                                value = (n.value.value if value is None
                                         or value == n.value.value else None)
                            else:
                                return None
            return value
        return None

    # -- statement ordering (for the PRNG linear scan) ---------------------

    def statement_order(self, fn: ast.AST) -> List[ast.stmt]:
        """All statements lexically inside ``fn``'s own body (nested defs
        excluded), in source order — the straight-line approximation the
        PRNG-reuse pass scans."""
        out: List[ast.stmt] = []

        def visit(body):
            for st in body:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue            # separate scope, scanned separately
                out.append(st)
                for attr in ("body", "orelse", "finalbody"):
                    visit(getattr(st, attr, []) or [])
                for h in getattr(st, "handlers", []) or []:
                    visit(h.body)

        body = getattr(fn.node if isinstance(fn, FunctionInfo) else fn,
                       "body", [])
        if isinstance(body, list):       # Lambda bodies are a bare expr
            visit(body)
        return out

    def own_calls(self, stmt: ast.stmt) -> Iterator[ast.Call]:
        """Calls in ``stmt``'s own expressions only: descent stops at
        nested statements (a compound statement's body is its own entry
        in :meth:`statement_order`) and at lambdas (deferred, not
        executed at this point in the straight line)."""
        def visit(node: ast.AST) -> Iterator[ast.Call]:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.stmt, ast.excepthandler,
                                      ast.Lambda)):
                    continue
                if isinstance(child, ast.Call):
                    yield child
                yield from visit(child)
        return visit(stmt)


def names_in(node: ast.AST) -> List[str]:
    """All bare Names referenced anywhere inside ``node``."""
    return [n.id for n in ast.walk(node) if isinstance(n, ast.Name)]


def parse_module(source: str, path: str) -> Module:
    return Module(source, path)
