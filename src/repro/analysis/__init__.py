"""Static analysis for the analog-inference stack (ISSUE 8).

Two layers:

* **lint** — AST passes over ``src/repro`` for the hazard classes this
  repo has actually shipped bugs in: SPMD concat-of-slices reassembly,
  Mosaic-illegal Pallas tile shapes, PRNG key reuse and literal seeds,
  host syncs reachable from jitted bodies, bare asserts / silent
  ``except: pass`` in library code.  See ``repro.analysis.rules`` for
  the catalog.
* **contracts** — :class:`CompileContract` declarations ("this entry
  point compiles at most N times across this grid") checked statically
  against the sweep executor's compile-group partition and, at trace
  level, against real XLA compilation counts.  The repo's own suite
  lives in ``repro.analysis.repo_contracts``.

``tools/analyze.py`` is the CLI; ``--ci`` gates on the committed
baseline (shipped empty — true positives were fixed, not grandfathered).
"""

from repro.analysis.contracts import (
    CompileContract,
    TRACE_SENTINELS,
    check_contract,
    check_contracts,
    compile_counter,
    jaxpr_scalar_constants,
    jit_cache_size,
    traced_constant_violations,
)
from repro.analysis.findings import (
    Baseline,
    Finding,
    apply_suppressions,
    suppressed_rules,
)
from repro.analysis.report import (
    analyze_file,
    analyze_paths,
    analyze_source,
    render,
    rule_ids,
)

__all__ = [
    "Baseline",
    "CompileContract",
    "Finding",
    "TRACE_SENTINELS",
    "analyze_file",
    "analyze_paths",
    "analyze_source",
    "apply_suppressions",
    "check_contract",
    "check_contracts",
    "compile_counter",
    "jaxpr_scalar_constants",
    "jit_cache_size",
    "render",
    "rule_ids",
    "suppressed_rules",
    "traced_constant_violations",
]
