"""Findings, inline suppressions, and the committed baseline.

A :class:`Finding` is the analyzer's one output currency: every lint
pass and every contract check reports ``Finding(rule, path, line, msg)``
rows, the CLI renders them, and CI gates on the set that is neither

* **suppressed** — the flagged source line carries an inline
  ``# repro: ignore[<rule>]`` marker (scoped to that rule; use it for
  reviewed, deliberate exceptions), nor
* **baselined** — the ``(rule, path, msg)`` triple appears in the
  committed baseline file (line numbers are excluded from the identity
  so unrelated edits above a baselined finding do not un-baseline it).

The shipped baseline is empty: every true positive the analyzer found
in ``src/repro`` was fixed rather than grandfathered (ISSUE 8), and the
CI gate (``tools/analyze.py --ci``) keeps it that way.
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Sequence, Tuple

#: inline suppression: ``some_code()  # repro: ignore[rule-name]``
_IGNORE = re.compile(r"#\s*repro:\s*ignore\[([a-z0-9_,\- ]+)\]")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer result, lint or contract.

    ``line`` is 1-indexed into ``path`` for lint findings; contract
    findings (no single source line) use line 0 and a path naming the
    contract's declaring module.
    """

    rule: str
    path: str
    line: int
    msg: str

    def key(self) -> Tuple[str, str, str]:
        """Line-insensitive identity used for baseline matching."""
        return (self.rule, self.path, self.msg)

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def suppressed_rules(source_line: str) -> frozenset:
    """Rule names suppressed by inline markers on ``source_line``."""
    rules: set = set()
    for m in _IGNORE.finditer(source_line):
        rules.update(r.strip() for r in m.group(1).split(","))
    return frozenset(rules)


def apply_suppressions(findings: Sequence[Finding],
                       source_lines: Sequence[str]) -> List[Finding]:
    """Drop findings whose flagged line carries ``# repro: ignore[rule]``."""
    kept = []
    for f in findings:
        if 1 <= f.line <= len(source_lines):
            if f.rule in suppressed_rules(source_lines[f.line - 1]):
                continue
        kept.append(f)
    return kept


class Baseline:
    """The committed set of grandfathered findings (normally empty).

    Stored as a JSON list of ``{"rule", "path", "msg"}`` rows; matching
    ignores line numbers so the baseline survives unrelated edits.
    """

    def __init__(self, entries: Sequence[Dict[str, str]] = ()):
        self._keys = {(e["rule"], e["path"], e["msg"]) for e in entries}

    def __len__(self) -> int:
        return len(self._keys)

    def __contains__(self, finding: Finding) -> bool:
        return finding.key() in self._keys

    @classmethod
    def load(cls, path: str) -> "Baseline":
        try:
            with open(path) as f:
                data = json.load(f)
        except FileNotFoundError:
            return cls()
        if not isinstance(data, list):
            raise ValueError(
                f"baseline {path!r} must be a JSON list of "
                f"{{rule, path, msg}} rows, got {type(data).__name__}")
        return cls(data)

    @staticmethod
    def write(path: str, findings: Sequence[Finding]) -> None:
        rows = [{"rule": f.rule, "path": f.path, "msg": f.msg}
                for f in sorted(findings, key=lambda f: f.key())]
        with open(path, "w") as fh:
            json.dump(rows, fh, indent=2)
            fh.write("\n")

    def filter(self, findings: Sequence[Finding]) -> List[Finding]:
        """The findings NOT covered by this baseline (the CI gate set)."""
        return [f for f in findings if f not in self]
