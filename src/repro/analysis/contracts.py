"""Compile contracts: declared compilation budgets, checked for real.

PRs 3-6 each pinned a compilation-structure invariant by hand — "the
Fig. 19 ``r_hat`` axis is ONE compile group", "``ServeRuntime``'s decode
step compiles once across a ragged trace", "drift's nu x t grid traces,
never re-lowers".  This module turns those ad-hoc pins into declarations
(:class:`CompileContract`) verifiable at two levels:

* **static** — the cheap structural check: expand the contract's sweep
  grid and assert the executor's :func:`~repro.sweep.executor.
  compile_groups` partition matches the declared group budget and traced
  field names.  Runs in tier-1 CI on every push.
* **trace** — run the *real* jitted entry points and count actual XLA
  compilations, via either the jit cache size of named entry points
  (exact, attributable) or a process-wide backend-compile event counter
  (:class:`compile_counter`, for entry points whose jit wrappers are
  created internally).  Runs in the nightly tier-2 job
  (``tools/analyze.py --contracts trace``).

Violations come back as :class:`~repro.analysis.findings.Finding` rows
(rule ``compile-contract``), the same currency as the lint layer, so the
CLI and CI gate treat both uniformly.

The third contract form guards the *bit-exactness* half of the story:
:func:`traced_constant_violations` traces an entry point with sentinel
values substituted into fields declared traced, and scans the jaxpr for
the sentinels appearing as **constants** — the failure mode where a
``float()`` snapshot silently bakes one axis value into the compiled
program (every other point of the axis then reuses the wrong constant).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding

#: the monitoring event jax records once per XLA backend compilation
_COMPILE_EVENT = "/jax/core/compile/backend_compile_duration"

_compiles = 0
_listener_installed = False


def _install_listener() -> None:
    global _listener_installed
    if _listener_installed:
        return
    import jax.monitoring

    def _on_event(name: str, secs: float, **kw) -> None:
        global _compiles
        if name == _COMPILE_EVENT:
            _compiles += 1

    jax.monitoring.register_event_duration_secs_listener(_on_event)
    _listener_installed = True


class compile_counter:
    """Counts XLA backend compilations inside a ``with`` block.

    Process-wide (every jit in the block counts, including incidental
    eager-op compiles), so contracts using it should compare counts
    between workloads rather than pin small absolute numbers.
    """

    def __enter__(self) -> "compile_counter":
        _install_listener()
        self._start = _compiles
        return self

    def __exit__(self, *exc) -> None:
        pass

    @property
    def count(self) -> int:
        return _compiles - self._start


def jit_cache_size(fn) -> int:
    """Number of compiled signatures held by a ``jax.jit`` wrapper."""
    size = getattr(fn, "_cache_size", None)
    if size is None:
        raise ValueError(
            f"{fn!r} exposes no jit compilation cache; contract entries "
            f"must be jax.jit-wrapped callables")
    return size()


@dataclasses.dataclass(frozen=True)
class CompileContract:
    """One declared compilation budget for an entry point.

    Static (sweep-structural) fields — require ``sweep`` + ``evaluator``:

    ``max_groups`` / ``min_groups``
        bounds on the :func:`compile_groups` partition of the expanded
        grid (min catches *merging* regressions: ``r_hat == 0`` traced
        to zero instead of split static would fuse two programs that
        must stay distinct).
    ``expect_dynamic``
        when given, the set of allowed per-group traced-field name
        tuples; every group's dyn names must be one of them.
    ``require_dynamic``
        field paths that must be traced in at least one group (the
        "this axis really batches" half of the pin).

    Trace (compilation-counting) fields:

    ``run``
        the workload.  May return a list of violation strings (e.g.
        from :func:`traced_constant_violations`) — non-empty fails the
        contract.
    ``warmup``
        executed before counting starts (e.g. compile the first point;
        the contract then bounds what the *rest* of the grid adds).
    ``entries``
        zero-arg callable returning the jitted entry points whose cache
        sizes are summed after ``run`` — exact per-entry-point counting
        (``ServeRuntime``'s decode step: 1 across a whole ragged trace).
    ``max_compiles``
        budget for ``entries`` cache sizes, or for the
        :class:`compile_counter` total during ``run`` when no
        ``entries`` are named.  ``None`` skips counting (contracts that
        only use ``run``'s returned violations).
    """

    name: str
    description: str = ""
    # static level
    sweep: Optional[Any] = None                  # SweepSpec
    evaluator: Optional[Callable[[], Any]] = None
    max_groups: Optional[int] = None
    min_groups: Optional[int] = None
    expect_dynamic: Optional[Tuple[Tuple[str, ...], ...]] = None
    require_dynamic: Tuple[str, ...] = ()
    # trace level
    run: Optional[Callable[[], Any]] = None
    warmup: Optional[Callable[[], Any]] = None
    entries: Optional[Callable[[], Sequence[Any]]] = None
    max_compiles: Optional[int] = None

    def declares_static(self) -> bool:
        return self.sweep is not None

    def declares_trace(self) -> bool:
        return self.run is not None


def _static_findings(c: CompileContract) -> List[Finding]:
    from repro.sweep.executor import compile_groups
    from repro.sweep.results import point_key

    ev = c.evaluator()
    pts = c.sweep.expand()
    proto = c.sweep.point_protocol()
    groups = compile_groups(
        [(point_key(ev.signature(), p, proto), p) for p in pts], ev)
    out: List[Finding] = []
    where = f"contract {c.name!r}"
    if c.max_groups is not None and len(groups) > c.max_groups:
        out.append(Finding(
            "compile-contract", where, 0,
            f"{len(pts)}-point grid partitions into {len(groups)} compile "
            f"groups, budget is {c.max_groups} — an axis declared traced "
            f"is recompiling per value"))
    if c.min_groups is not None and len(groups) < c.min_groups:
        out.append(Finding(
            "compile-contract", where, 0,
            f"grid partitions into {len(groups)} compile groups, expected "
            f"at least {c.min_groups} — a static program-structure split "
            f"(e.g. parasitics on/off) is being traced away"))
    dyn_seen = {dyn_names for _, dyn_names, _ in groups}
    if c.expect_dynamic is not None:
        allowed = {tuple(t) for t in c.expect_dynamic}
        for names in sorted(dyn_seen):
            if names not in allowed:
                out.append(Finding(
                    "compile-contract", where, 0,
                    f"group traces fields {names!r}, allowed sets are "
                    f"{sorted(allowed)!r}"))
    for path in c.require_dynamic:
        if not any(path in names for names in dyn_seen):
            out.append(Finding(
                "compile-contract", where, 0,
                f"field {path!r} is declared traced but appears in no "
                f"group's dynamic names — its axis recompiles per value"))
    return out


def _trace_findings(c: CompileContract) -> List[Finding]:
    where = f"contract {c.name!r}"
    out: List[Finding] = []
    if c.warmup is not None:
        c.warmup()
    with compile_counter() as counter:
        violations = c.run() if c.run is not None else None
    if isinstance(violations, (list, tuple)):
        out.extend(Finding("compile-contract", where, 0, str(v))
                   for v in violations)
    if c.max_compiles is not None:
        if c.entries is not None:
            n = sum(jit_cache_size(fn) for fn in c.entries())
            kind = "entry-point jit cache holds"
        else:
            n = counter.count
            kind = "workload performed"
        if n > c.max_compiles:
            out.append(Finding(
                "compile-contract", where, 0,
                f"{kind} {n} compilations, budget is {c.max_compiles}"))
    return out


def check_contract(c: CompileContract,
                   level: str = "static") -> List[Finding]:
    """Verify one contract; returns violations (empty = holds)."""
    if level not in ("static", "trace"):
        raise ValueError(f"level must be 'static' or 'trace', got {level!r}")
    if level == "static":
        if not c.declares_static():
            return []
        return _static_findings(c)
    if not c.declares_trace():
        return []
    return _trace_findings(c)


def check_contracts(contracts: Sequence[CompileContract],
                    level: str = "static") -> List[Finding]:
    out: List[Finding] = []
    for c in contracts:
        out.extend(check_contract(c, level))
    return out


# ---------------------------------------------------------------------------
# traced-field / jaxpr-constant verification
# ---------------------------------------------------------------------------


def jaxpr_scalar_constants(closed) -> List[float]:
    """Every scalar float constant in a closed jaxpr, sub-jaxprs included."""
    import jax.core

    out: List[float] = []

    def add(v) -> None:
        arr = np.asarray(v)
        if arr.ndim == 0 and np.issubdtype(arr.dtype, np.floating):
            out.append(float(arr))

    def visit_jaxpr(jaxpr) -> None:
        for eqn in jaxpr.eqns:
            for var in eqn.invars:
                if isinstance(var, jax.core.Literal):
                    add(var.val)
            for p in eqn.params.values():
                for sub in _sub_jaxprs(p):
                    visit_jaxpr(sub)

    def _sub_jaxprs(p):
        if isinstance(p, jax.core.ClosedJaxpr):
            for cv in p.consts:
                add(cv)
            yield p.jaxpr
        elif isinstance(p, jax.core.Jaxpr):
            yield p
        elif isinstance(p, (tuple, list)):
            for item in p:
                yield from _sub_jaxprs(item)

    for cv in closed.consts:
        add(cv)
    visit_jaxpr(closed.jaxpr)
    return out


#: sentinel magnitudes for traced-field checks: distinctive, finite, and
#: never arising from shape arithmetic
TRACE_SENTINELS = (0.0123456789, 0.0987654321, 0.0246813579, 0.0135792468)


def traced_constant_violations(fn: Callable, args: Sequence[Any],
                               sentinels: Sequence[float],
                               label: str = "") -> List[str]:
    """Trace ``fn(*args)`` and report sentinels baked in as constants.

    ``args`` carries the sentinel values in the positions the entry
    point declares traced; if any sentinel value appears as a jaxpr
    *constant*, the value leaked out of the traced path (a ``float()``
    snapshot, a Python-side branch) and every other axis value would
    silently reuse the compiled point's number.
    """
    import jax

    closed = jax.make_jaxpr(fn)(*args)
    consts = jaxpr_scalar_constants(closed)
    out = []
    for s in sentinels:
        if any(abs(cv - s) < 1e-12 for cv in consts):
            out.append(
                f"{label or getattr(fn, '__name__', 'entry point')}: traced "
                f"field value {s} appears as a jaxpr constant — the field "
                f"is being snapshotted out of the traced path")
    return out
