"""The analyzer front door: run rules over sources, apply suppressions.

``analyze_source`` is the unit every caller builds on (tests feed it
fixture strings); ``analyze_paths`` walks real trees and is what
``tools/analyze.py`` invokes.  Suppression (inline ``# repro:
ignore[rule]``) is applied here, once, so rules never need to know about
it; baseline filtering is left to the CLI because only the CI gate cares.
"""

from __future__ import annotations

import os
from typing import Iterable, List, Optional, Sequence

from repro.analysis.findings import Finding, apply_suppressions
from repro.analysis.walker import RULES, parse_module

# rules.py registers into RULES on import
from repro.analysis import rules as _rules  # noqa: F401


def rule_ids() -> List[str]:
    return sorted(RULES)


def analyze_source(source: str, path: str = "<string>",
                   only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run lint rules over one source string; suppressions applied."""
    selected = _select(only)
    try:
        mod = parse_module(source, path)
    except SyntaxError as e:
        return [Finding("syntax-error", path, e.lineno or 0, str(e.msg))]
    findings: List[Finding] = []
    for rid in selected:
        findings.extend(RULES[rid](mod))
    findings = apply_suppressions(findings, mod.lines)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def analyze_file(path: str,
                 only: Optional[Sequence[str]] = None) -> List[Finding]:
    with open(path) as f:
        source = f.read()
    return analyze_source(source, path, only=only)


def analyze_paths(paths: Iterable[str],
                  only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Analyze files and directory trees (``*.py``, sorted, deduped)."""
    files: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, names in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if d not in ("__pycache__", ".git"))
                files.extend(os.path.join(root, n)
                             for n in sorted(names) if n.endswith(".py"))
        elif p.endswith(".py"):
            files.append(p)
        else:
            raise ValueError(f"not a .py file or directory: {p!r}")
    findings: List[Finding] = []
    for path in dict.fromkeys(files):
        findings.extend(analyze_file(path, only=only))
    return findings


def render(findings: Sequence[Finding]) -> str:
    if not findings:
        return "analysis clean: 0 findings"
    lines = [f.render() for f in findings]
    lines.append(f"{len(findings)} finding(s)")
    return "\n".join(lines)


def _select(only: Optional[Sequence[str]]) -> List[str]:
    if only is None:
        return rule_ids()
    unknown = sorted(set(only) - set(RULES))
    if unknown:
        raise ValueError(
            f"unknown rule(s) {unknown!r}; available: {rule_ids()!r}")
    return sorted(dict.fromkeys(only))
