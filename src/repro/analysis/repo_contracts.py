"""This repo's declared compile contracts — the PR 3-6 pins, as data.

Each :class:`~repro.analysis.contracts.CompileContract` here encodes a
compilation-structure guarantee the paper reproduction leans on (see
docs/PAPER_MAP.md §compile contracts for the accuracy invariant each
one protects):

* the Fig. 7/8 error axes and the Fig. 19 parasitic axis batch as
  traced scalars — one compiled program per axis, not per value;
* ``r_hat == 0`` keeps its own (solve-free) program: traced-to-zero
  would both slow the clean baseline and perturb its numerics;
* non-varying dynamic fields stay concrete Python floats (the
  bit-exactness rule: traced scalars round ``1 - 1/on_off`` in float32,
  concrete ones in double);
* drift's nu x t grid (Fig. 21 horizons) compiles once;
* ``ServeRuntime``'s decode step compiles once across a ragged trace;
* ``PagedServeRuntime``'s decode step compiles once across a trace with
  prefix hits and radix evictions (block tables traced, ``page_size``
  static), and each paged prefill group compiles exactly once;
* serving through the fused decode kernels builds exactly one fused
  program per distinct site-class signature
  (``hw.fused_site_classes``), never one per site or per call;
* values for fields declared traced flow through the traced row, never
  out of the template (a template value silently reused by every other
  axis point is the worst failure: wrong numbers, no crash).

``static_contracts()`` run in tier-1 CI (structural, milliseconds);
``trace_contracts()`` execute real jitted entry points and run in the
tier-2 nightly (``tools/analyze.py --contracts trace``).
"""

from __future__ import annotations

from typing import List

from repro.analysis.contracts import (
    CompileContract,
    TRACE_SENTINELS,
    traced_constant_violations,
)

_vehicle_cache = None


def _classifier_vehicle():
    """The tiny random classifier from tests/test_sweep.py, module-cached."""
    global _vehicle_cache
    if _vehicle_cache is None:
        import jax
        import jax.numpy as jnp

        # the fixture's pinned seed IS the contract vehicle
        ks = jax.random.split(
            jax.random.PRNGKey(0), 6)  # repro: ignore[prng-seed]
        dims = (16, 32, 8)
        layers = [
            (jax.random.normal(ks[i], (dims[i], dims[i + 1]))
             * dims[i] ** -0.5,
             jnp.zeros((dims[i + 1],)))
            for i in range(2)
        ]
        xca = jax.random.normal(ks[3], (64, 16))
        xte = jax.random.normal(ks[4], (128, 16))
        yte = jax.random.randint(ks[5], (128,), 0, 8)
        _vehicle_cache = (layers, xca, xte, yte)
    return _vehicle_cache


def _evaluator():
    from repro.sweep import ClassifierEvaluator

    return ClassifierEvaluator(*_classifier_vehicle())


def _sweep(axes, base=None, trials=1):
    from repro.core.adc import ADCConfig
    from repro.core.analog import AnalogSpec
    from repro.sweep import SweepSpec

    return SweepSpec(
        name="contract",
        base=base if base is not None
        else AnalogSpec(adc=ADCConfig(style="none"), max_rows=64),
        axes=tuple(axes),
        trials=trials,
    )


def static_contracts() -> List[CompileContract]:
    from repro.core import errors as E
    from repro.core.adc import ADCConfig
    from repro.core.analog import AnalogSpec
    from repro.sweep import Axis

    return [
        CompileContract(
            name="sweep/alpha-axis-one-group",
            description="Fig. 7/8 error axis batches as one traced group",
            sweep=_sweep(
                (Axis("error.alpha", (0.01, 0.02, 0.05, 0.1)),),
                base=AnalogSpec(adc=ADCConfig(style="none"),
                                error=E.state_proportional(0.0)),
                trials=2),
            evaluator=_evaluator,
            max_groups=1,
            require_dynamic=("error.alpha",),
        ),
        CompileContract(
            name="sweep/constant-field-stays-static",
            description="non-varying dynamic fields stay concrete "
                        "(bit-exactness vs the serial reference)",
            sweep=_sweep(
                (Axis("max_rows", (72, 1152)),),
                base=AnalogSpec(adc=ADCConfig(style="none"),
                                error=E.state_proportional(0.05))),
            evaluator=_evaluator,
            max_groups=2, min_groups=2,
            expect_dynamic=((),),
        ),
        CompileContract(
            name="sweep/r-hat-axis-one-group",
            description="Fig. 19 parasitic axis shares one tridiagonal-"
                        "solve program across r_hat levels",
            sweep=_sweep((Axis("r_hat", (1e-5, 1e-4, 1e-3)),)),
            evaluator=_evaluator,
            max_groups=1,
            require_dynamic=("r_hat",),
        ),
        CompileContract(
            name="sweep/r-hat-on-off-split",
            description="r_hat == 0 keeps its own solve-free program, "
                        "never traced to zero",
            sweep=_sweep((Axis("r_hat", (0.0, 1e-4, 1e-3)),)),
            evaluator=_evaluator,
            max_groups=2, min_groups=2,
            expect_dynamic=((), ("r_hat",)),
            require_dynamic=("r_hat",),
        ),
        CompileContract(
            name="sweep/drift-grid-one-group",
            description="Fig. 21 nu x t drift grid compiles once "
                        "(horizon and exponent both traced)",
            sweep=_sweep(
                (Axis("drift.nu", (0.1, 0.2)),
                 Axis("drift.t", (1.0, 16.0, 256.0))),
                base=AnalogSpec(adc=ADCConfig(style="none"), max_rows=64,
                                drift=E.power_law_drift(0.2))),
            evaluator=_evaluator,
            max_groups=1,
            expect_dynamic=(("drift.nu", "drift.t"),),
            require_dynamic=("drift.nu", "drift.t"),
        ),
    ]


# ---------------------------------------------------------------------------
# trace level
# ---------------------------------------------------------------------------


def _alpha_grid_contract() -> CompileContract:
    from repro.core import errors as E
    from repro.core.adc import ADCConfig
    from repro.core.analog import AnalogSpec
    from repro.sweep import Axis, run_sweep

    ev = _evaluator()
    sweep = _sweep(
        (Axis("error.alpha", (0.01, 0.02, 0.05, 0.1)),),
        base=AnalogSpec(adc=ADCConfig(style="none"),
                        error=E.state_proportional(0.0)),
        trials=2)

    return CompileContract(
        name="sweep/alpha-axis-compiles-once",
        description="running the 4-point error axis leaves exactly one "
                    "compiled signature in the evaluator's jit cache",
        run=lambda: run_sweep(sweep, ev),
        entries=lambda: list(ev._fn_cache.values()),
        max_compiles=1,
    )


def _decode_once_contract() -> CompileContract:
    import numpy as np

    state = {}

    def run():
        import jax

        from repro.configs import get_smoke_config
        from repro.models.registry import get_model
        from repro.serve import ServeRuntime

        cfg = get_smoke_config("qwen1.5-4b")
        params = get_model(cfg).init_params(
            cfg, jax.random.PRNGKey(0))  # repro: ignore[prng-seed]
        rt = ServeRuntime(cfg, params, max_slots=3, max_len=32)
        state["rt"] = rt
        rng = np.random.default_rng(0)
        for i in range(9):     # mixed ragged trace: lens 3..14, new 2..8
            prompt = rng.integers(
                0, cfg.vocab, size=int(rng.integers(3, 15))).astype(np.int32)
            rt.submit(prompt, max_new_tokens=int(rng.integers(2, 9)), uid=i)
        rt.run()

    return CompileContract(
        name="serve/decode-compiles-once",
        description="ServeRuntime's decode step compiles once across a "
                    "mixed ragged trace (ragged-ness lives in data, "
                    "never in program shape)",
        run=run,
        entries=lambda: [state["rt"]._decode_fn],
        max_compiles=1,
    )


_paged_state: dict = {}


def _paged_run():
    """Serve one deterministic paged trace (prefix hits, evictions,
    admission stalls all exercised) and cache the runtime for both paged
    contracts — the trace is served once, inspected twice."""
    if "rt" in _paged_state:
        return
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.registry import get_model
    from repro.serve import PagedServeRuntime

    cfg = get_smoke_config("qwen1.5-4b")
    params = get_model(cfg).init_params(
        cfg, jax.random.PRNGKey(0))  # repro: ignore[prng-seed]
    # 13 data pages for 3 slots of up to 8 pages each: roomy enough for
    # the shared prefix to survive in the radix cache (hits), tight
    # enough that the distinct-prompt second wave must evict it
    rt = PagedServeRuntime(cfg, params, max_slots=3, max_len=32,
                           page_size=4, num_pages=14)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, size=9).astype(np.int32)
    for i in range(9):     # mixed ragged trace, every other prompt shared
        if i % 2:
            tail = rng.integers(
                0, cfg.vocab, size=int(rng.integers(1, 5))).astype(np.int32)
            prompt = np.concatenate([shared, tail])
        else:
            prompt = rng.integers(
                0, cfg.vocab, size=int(rng.integers(3, 13))).astype(np.int32)
        rt.submit(prompt, max_new_tokens=int(rng.integers(2, 7)), uid=i)
    rt.run()
    for i in range(6):     # distinct-prefix wave: forces radix eviction
        prompt = rng.integers(
            0, cfg.vocab, size=int(rng.integers(10, 13))).astype(np.int32)
        rt.submit(prompt, max_new_tokens=4, uid=100 + i)
    rt.run()
    rt.check()
    s = rt.stats
    if s["prefix_hits"] <= 0:
        raise RuntimeError("contract trace produced no prefix hits")
    if s["cache_evictions"] <= 0:
        raise RuntimeError("contract trace produced no radix evictions")
    _paged_state["rt"] = rt


def _paged_decode_once_contract() -> CompileContract:
    return CompileContract(
        name="serve/paged-decode-compiles-once",
        description="PagedServeRuntime's decode step compiles once "
                    "across a mixed trace with prefix hits and radix "
                    "evictions (block tables are traced data; page_size "
                    "and table width are the only static shape bits)",
        run=_paged_run,
        entries=lambda: [_paged_state["rt"]._decode_fn],
        max_compiles=1,
    )


def _paged_prefill_budget_contract() -> CompileContract:
    def run():
        from repro.analysis.contracts import jit_cache_size

        _paged_run()
        rt = _paged_state["rt"]
        return [
            f"paged prefill group {key} holds {jit_cache_size(fn)} "
            f"compilations (expected exactly 1)"
            for key, fn in rt._prefill_fns.items()
            if jit_cache_size(fn) != 1
        ]

    return CompileContract(
        name="serve/paged-prefill-group-budget",
        description="every paged prefill compile group — one per "
                    "(shared-ctx, suffix bucket, gang size) — compiles "
                    "exactly once; cache-hit geometry lives in the key, "
                    "page contents in traced operands",
        run=run,
    )


def _fused_site_class_contract() -> CompileContract:
    def run():
        import jax
        import numpy as np

        from repro.configs import get_smoke_config
        from repro.core.analog import design_a
        from repro.core.errors import ErrorModel
        from repro.hw import fused_site_classes
        from repro.kernels import fused as kfused
        from repro.models.registry import get_model
        from repro.serve import ServeRuntime
        from repro.serve.analog_engine import (
            calibrate_lm,
            lm_hook_names,
            program_lm,
        )
        from repro.sweep.serve_eval import pack_with_fused

        cfg = get_smoke_config("qwen1.5-4b")
        params = get_model(cfg).init_params(
            cfg, jax.random.PRNGKey(0))  # repro: ignore[prng-seed]
        rng = np.random.default_rng(0)
        calib = rng.integers(0, cfg.vocab, size=(2, 24)).astype(np.int32)
        pack = program_lm(cfg, params, design_a(error=ErrorModel()),
                          jax.random.PRNGKey(1))  # repro: ignore[prng-seed]
        pack = calibrate_lm(cfg, params, pack, calib)
        pack = pack_with_fused(pack, "kernel")
        expected = set(fused_site_classes(
            pack.profile, lm_hook_names(cfg), cfg.n_layers))
        kfused.BUILD_SIGNATURES.clear()
        rt = ServeRuntime(cfg, params, pack=pack, max_slots=3, max_len=32,
                          attn_backend="flash")
        for i in range(6):     # ragged trace over the fused serving stack
            prompt = rng.integers(
                0, cfg.vocab, size=int(rng.integers(3, 13))).astype(np.int32)
            rt.submit(prompt, max_new_tokens=int(rng.integers(2, 7)), uid=i)
        rt.run()
        from repro.analysis.contracts import jit_cache_size

        built = set(kfused.BUILD_SIGNATURES)
        out = []
        if built != expected:
            out.append(
                f"fused-kernel compile groups diverge from the profile's "
                f"site classes: built {sorted(built)}, hw.fused_site_classes "
                f"predicts {sorted(expected)}")
        n = jit_cache_size(rt._decode_fn)
        if n != 1:
            out.append(f"fused decode step holds {n} compilations "
                       f"(expected exactly 1)")
        return out

    return CompileContract(
        name="serve/fused-compile-per-site-class",
        description="serving through the fused kernels builds exactly one "
                    "fused program per distinct site-class signature "
                    "(hw.fused_site_classes), and the fused decode step "
                    "still compiles once across a ragged trace",
        run=run,
    )


def _traced_fields_contract() -> CompileContract:
    def run():
        import jax

        from repro.core import errors as E
        from repro.core.adc import ADCConfig
        from repro.core.analog import AnalogSpec
        from repro.sweep.evaluate import materialize, trial_accuracy

        layers, xca, xte, yte = _classifier_vehicle()
        # sentinels planted in the TEMPLATE for fields declared traced;
        # materialize must override them with the traced row — a
        # sentinel surviving into the jaxpr as a constant means a point
        # read the template value and every axis point shares it
        template = AnalogSpec(
            adc=ADCConfig(style="none"), max_rows=64,
            error=E.state_proportional(TRACE_SENTINELS[0]),
            r_hat=TRACE_SENTINELS[1])

        def point(alpha, r_hat, key):
            spec = materialize(template,
                               {"error.alpha": alpha, "r_hat": r_hat})
            return trial_accuracy(layers, spec, key, xca, xte, yte)

        return traced_constant_violations(
            point,
            (0.05, 1e-4, jax.random.PRNGKey(0)),  # repro: ignore[prng-seed]
            TRACE_SENTINELS[:2], label="classifier trial_accuracy")

    return CompileContract(
        name="sweep/dynamic-fields-flow-traced",
        description="values of fields declared traced come from the "
                    "traced row, never baked in from the template",
        run=run,
    )


def trace_contracts() -> List[CompileContract]:
    return [
        _alpha_grid_contract(),
        _decode_once_contract(),
        _paged_decode_once_contract(),
        _paged_prefill_budget_contract(),
        _fused_site_class_contract(),
        _traced_fields_contract(),
    ]


def all_contracts(level: str) -> List[CompileContract]:
    if level == "static":
        return static_contracts()
    if level == "trace":
        return trace_contracts()
    raise ValueError(f"level must be 'static' or 'trace', got {level!r}")
