"""Pallas TPU kernels for the analog in-situ MVM simulation hot loop.

TPU-native adaptation (see DESIGN.md): CrossSim's per-array Python loop
becomes MXU matmuls with the ADC model fused into the epilogue, and — for
the bit-serial (digital input accumulation) path — input bit planes are
extracted *inside* the kernel in VMEM instead of being materialized in HBM
(an 8x input-traffic reduction).

Grid/BlockSpec layout, both kernels::

    grid = (M // bm, N // bn, P)          # P = analog K-partitions
    x block  (bm, 1, rows)   index (i, p, 0)  -> VMEM
    g blocks (1, rows, bn)   index (p, 0, j)  -> VMEM
    out      (bm, bn)        index (i, j)     accumulated over p

The innermost grid dimension walks the analog partitions; the output block
is revisited and accumulated, mirroring the digital partial-sum adder that
follows each array's ADC.  ``rows`` (the analog array depth, <= 1152) and
the N tile are chosen so both matmul operands sit in VMEM with
MXU-aligned dims (multiples of 128 after padding in ops.py).

The ADC epilogue is pure VPU work: clip, scale, round — fused with the
matmul so the pre-ADC partial sums never leave VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import COMPILER_PARAMS


def _adc_epilogue(v, lo, hi, bits: int):
    n_levels = 2 ** bits
    lsb = (hi - lo) / (n_levels - 1)
    code = jnp.clip(jnp.round((v - lo) / lsb), 0.0, n_levels - 1.0)
    return lo + code * lsb


def _bit_plane(mag, sign, b: int):
    """In-VMEM signed bit-plane extraction from float-encoded integers:
    plane_b = bit b of |x|, carrying sign(x).  Shared by every bit-serial
    kernel (Design D here, the parasitic Design-A path in bitline.py) so
    the input-plane encoding cannot diverge between them."""
    return (jnp.floor(mag / 2.0 ** b) % 2.0) * sign


def _diff_kernel(x_ref, gp_ref, gm_ref, lo_ref, hi_ref, o_ref, *,
                 adc_bits: int, gain: float):
    """Design-A fast path: one matmul + ADC per (tile, partition)."""
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[:, 0, :]                     # (bm, rows)
    g = gp_ref[0] - gm_ref[0]              # (rows, bn) — analog subtraction
    v = jnp.dot(x, g, preferred_element_type=jnp.float32)
    lo = lo_ref[0, 0]
    hi = hi_ref[0, 0]
    v_hat = _adc_epilogue(v, lo, hi, adc_bits)
    o_ref[...] += (v_hat * gain).astype(o_ref.dtype)


def _bitserial_kernel(x_ref, gp_ref, gm_ref, lo_ref, hi_ref, o_ref, *,
                      n_bits: int, adc_bits: int, gain: float):
    """Design-D path: in-VMEM bit-plane extraction, ADC per input bit,
    digital shift-and-add accumulation."""
    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[:, 0, :]                     # (bm, rows) integer-valued float
    g = gp_ref[0] - gm_ref[0]
    lo = lo_ref[0, 0]
    hi = hi_ref[0, 0]
    sign = jnp.sign(x)
    mag = jnp.abs(x)
    acc = jnp.zeros_like(o_ref)
    for b in range(n_bits):                # static unroll: n_bits <= 7
        plane = _bit_plane(mag, sign, b)
        v = jnp.dot(plane, g, preferred_element_type=jnp.float32)
        v_hat = _adc_epilogue(v, lo, hi, adc_bits)
        acc += (v_hat * 2.0 ** b).astype(acc.dtype)
    o_ref[...] += acc * gain


def _common_call(kernel, x_parts, g_pos, g_neg, adc_lo, adc_hi, *,
                 bm: int, bn: int, interpret: bool):
    m, p, rows = x_parts.shape
    _, _, n = g_pos.shape
    if m % bm or n % bn:
        raise ValueError(
            f"block shape ({bm}, {bn}) does not tile operand ({m}, {n})")
    grid = (m // bm, n // bn, p)
    lo2 = adc_lo.reshape(1, 1).astype(jnp.float32)
    hi2 = adc_hi.reshape(1, 1).astype(jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1, rows), lambda i, j, p_: (i, p_, 0)),
            pl.BlockSpec((1, rows, bn), lambda i, j, p_: (p_, 0, j)),
            pl.BlockSpec((1, rows, bn), lambda i, j, p_: (p_, 0, j)),
            pl.BlockSpec((1, 1), lambda i, j, p_: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, p_: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_parts, g_pos, g_neg, lo2, hi2)


def analog_mvm_diff_pallas(
    x_parts: jax.Array,
    g_pos: jax.Array,
    g_neg: jax.Array,
    adc_lo: jax.Array,
    adc_hi: jax.Array,
    *,
    adc_bits: int,
    gain: float,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    kern = functools.partial(_diff_kernel, adc_bits=adc_bits, gain=gain)
    return _common_call(kern, x_parts, g_pos, g_neg, adc_lo, adc_hi,
                        bm=bm, bn=bn, interpret=interpret)


def analog_mvm_bitserial_pallas(
    x_parts: jax.Array,
    g_pos: jax.Array,
    g_neg: jax.Array,
    adc_lo: jax.Array,
    adc_hi: jax.Array,
    *,
    n_bits: int,
    adc_bits: int,
    gain: float,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    kern = functools.partial(
        _bitserial_kernel, n_bits=n_bits, adc_bits=adc_bits, gain=gain
    )
    return _common_call(kern, x_parts, g_pos, g_neg, adc_lo, adc_hi,
                        bm=bm, bn=bn, interpret=interpret)
