"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are tested against
(``assert_allclose`` over shape/dtype sweeps) and what the accuracy model
in ``repro.core.analog`` reduces to on the matching design points.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adc(v: jax.Array, lo, hi, bits: int) -> jax.Array:
    """Uniform clip+quantize, dequantized levels (see core.adc)."""
    n_levels = 2 ** bits
    lsb = (hi - lo) / (n_levels - 1)
    code = jnp.clip(jnp.round((v - lo) / lsb), 0, n_levels - 1)
    return lo + code * lsb


def analog_mvm_diff(
    x_parts: jax.Array,   # (M, P, rows) integer-valued
    g_pos: jax.Array,     # (P, rows, N)
    g_neg: jax.Array,     # (P, rows, N)
    *,
    adc_lo,
    adc_hi,
    adc_bits: int,
    gain: float,
) -> jax.Array:
    """Design-A path: differential, unsliced, analog input accumulation.

    Per K-partition: analog dot product, analog differential subtraction,
    one ADC conversion, then digital accumulation over partitions in code
    units (x ``gain``).  Output (M, N), code units.
    """
    v = jnp.einsum("mpr,prn->pmn", x_parts, g_pos - g_neg,
                   precision=jax.lax.Precision.HIGHEST)
    v_hat = adc(v, adc_lo, adc_hi, adc_bits)
    return jnp.sum(v_hat, axis=0) * gain


def analog_mvm_bitserial(
    x_parts: jax.Array,   # (M, P, rows) integer-valued, signed
    g_pos: jax.Array,     # (P, rows, N)
    g_neg: jax.Array,     # (P, rows, N)
    *,
    n_bits: int,
    adc_lo,
    adc_hi,
    adc_bits: int,
    gain: float,
) -> jax.Array:
    """Design-D path: differential, unsliced, *digital* input accumulation.

    Every input bit plane is digitized separately and aggregated by digital
    shift-and-add.  The oracle materializes all bit planes; the kernel
    extracts them in VMEM.
    """
    sign = jnp.sign(x_parts)
    mag = jnp.abs(x_parts).astype(jnp.int32)
    acc = None
    g = g_pos - g_neg
    for b in range(n_bits):
        plane = (((mag >> b) & 1).astype(x_parts.dtype)) * sign
        v = jnp.einsum("mpr,prn->pmn", plane, g,
                       precision=jax.lax.Precision.HIGHEST)
        v_hat = adc(v, adc_lo, adc_hi, adc_bits)
        contrib = jnp.sum(v_hat, axis=0) * (2.0 ** b)
        acc = contrib if acc is None else acc + contrib
    return acc * gain


def bitline_mvm(
    g: jax.Array,     # (K, N)
    x: jax.Array,     # (M, K) signed plane in {-1, 0, +1}
    r_hat: float,
) -> jax.Array:
    """Parasitic bit-line currents; delegates to the core Thomas solver."""
    from repro.core.parasitics import bitline_currents

    return bitline_currents(g, x, r_hat)


def analog_mvm_parasitic_diff(
    x_parts: jax.Array,   # (M, P, rows) integer-valued, signed
    g_pos: jax.Array,     # (P, rows, N)
    g_neg: jax.Array,     # (P, rows, N)
    *,
    r_hat: float,
    n_bits: int,
    adc_lo,
    adc_hi,
    adc_bits: int,
    gain: float,
) -> jax.Array:
    """Design-A path under parasitic bit-line resistance.

    Per input bit plane: both differential line stacks go through the
    tridiagonal bit-line solve; bits are accumulated in analog (the
    switched-capacitor stage after the bit line), then one ADC per
    partition and digital partition accumulation.  Output (M, N), code
    units — the oracle for ``ops.analog_mvm_parasitic``.
    """
    from repro.core.parasitics import bitline_currents

    sign = jnp.sign(x_parts)
    mag = jnp.abs(x_parts).astype(jnp.int32)
    solve = jax.vmap(bitline_currents, in_axes=(0, 1, None))  # over P
    acc = None
    for b in range(n_bits):
        plane = (((mag >> b) & 1).astype(x_parts.dtype)) * sign
        v = solve(g_pos, plane, r_hat) - solve(g_neg, plane, r_hat)
        contrib = v * (2.0 ** b)                              # (P, M, N)
        acc = contrib if acc is None else acc + contrib
    v_hat = adc(acc, adc_lo, adc_hi, adc_bits)
    return jnp.sum(v_hat, axis=0) * gain
