"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth the kernels are tested against
(``assert_allclose`` over shape/dtype sweeps) and what the accuracy model
in ``repro.core.analog`` reduces to on the matching design points.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adc(v: jax.Array, lo, hi, bits: int) -> jax.Array:
    """Uniform clip+quantize, dequantized levels (see core.adc)."""
    n_levels = 2 ** bits
    lsb = (hi - lo) / (n_levels - 1)
    code = jnp.clip(jnp.round((v - lo) / lsb), 0, n_levels - 1)
    return lo + code * lsb


def analog_mvm_diff(
    x_parts: jax.Array,   # (M, P, rows) integer-valued
    g_pos: jax.Array,     # (P, rows, N)
    g_neg: jax.Array,     # (P, rows, N)
    *,
    adc_lo,
    adc_hi,
    adc_bits: int,
    gain: float,
) -> jax.Array:
    """Design-A path: differential, unsliced, analog input accumulation.

    Per K-partition: analog dot product, analog differential subtraction,
    one ADC conversion, then digital accumulation over partitions in code
    units (x ``gain``).  Output (M, N), code units.
    """
    v = jnp.einsum("mpr,prn->pmn", x_parts, g_pos - g_neg,
                   precision=jax.lax.Precision.HIGHEST)
    v_hat = adc(v, adc_lo, adc_hi, adc_bits)
    return jnp.sum(v_hat, axis=0) * gain


def analog_mvm_bitserial(
    x_parts: jax.Array,   # (M, P, rows) integer-valued, signed
    g_pos: jax.Array,     # (P, rows, N)
    g_neg: jax.Array,     # (P, rows, N)
    *,
    n_bits: int,
    adc_lo,
    adc_hi,
    adc_bits: int,
    gain: float,
) -> jax.Array:
    """Design-D path: differential, unsliced, *digital* input accumulation.

    Every input bit plane is digitized separately and aggregated by digital
    shift-and-add.  The oracle materializes all bit planes; the kernel
    extracts them in VMEM.
    """
    sign = jnp.sign(x_parts)
    mag = jnp.abs(x_parts).astype(jnp.int32)
    acc = None
    g = g_pos - g_neg
    for b in range(n_bits):
        plane = (((mag >> b) & 1).astype(x_parts.dtype)) * sign
        v = jnp.einsum("mpr,prn->pmn", plane, g,
                       precision=jax.lax.Precision.HIGHEST)
        v_hat = adc(v, adc_lo, adc_hi, adc_bits)
        contrib = jnp.sum(v_hat, axis=0) * (2.0 ** b)
        acc = contrib if acc is None else acc + contrib
    return acc * gain


def paged_attention_decode(
    q: jax.Array,          # (B, H, hd)
    k_pages: jax.Array,    # (P, page_size, KV, hd)
    v_pages: jax.Array,    # (P, page_size, KV, hd)
    ptab: jax.Array,       # (B, NP) int32 block table
    kv_len: jax.Array,     # (B,) int32 valid positions per row
    *,
    scale=None,
) -> jax.Array:
    """Gather oracle for the paged-attention decode kernel.

    Walks the block table page by page in the kernel's exact two-phase
    order — a max-only pass, then a pure-add accumulation pass against
    the global max — with the same per-cell einsum contractions and
    masking constant.  The two-phase form has no ``acc * corr + x``
    rescale, so there is no multiply-add for the compiler to contract
    into an FMA differently per compilation context; that is what makes
    the interpret-mode kernel *bit-exact* against this oracle
    (``tests/test_kernels.py`` pins ``array_equal``).  Positions at or
    beyond ``kv_len[b]`` contribute exact zeros, so the result is
    invariant to block-table tail padding.

    ``page_size == 1`` is canonicalized to a single page of ``NP``
    tokens per row — the identical rewrite ``ops.paged_attention``
    applies — because size-1 page einsums degenerate to elementwise
    code whose FMA contraction is fusion-context-dependent, which would
    make "bitwise" ill-defined.
    """
    neg_inf = -1e30                      # layers.NEG_INF / paged.NEG_INF
    b, h, hd = q.shape
    _, page_size, kv_heads, _ = k_pages.shape
    n_pages = ptab.shape[1]
    if page_size == 1 and n_pages > 1:
        tab = jnp.asarray(ptab, jnp.int32)
        return paged_attention_decode(
            q, k_pages[:, 0][tab], v_pages[:, 0][tab],
            jnp.arange(b, dtype=jnp.int32)[:, None], kv_len, scale=scale)
    g = h // kv_heads
    scale = hd ** -0.5 if scale is None else scale
    kv_len = jnp.asarray(kv_len, jnp.int32)
    kpf = k_pages.astype(jnp.float32)
    vpf = v_pages.astype(jnp.float32)

    def row_fn(args):
        q_row, tab_row, len_row = args
        qg = q_row.astype(jnp.float32).reshape(kv_heads, g, hd) * scale

        def logits(j):
            s = jnp.einsum("kgd,pkd->kgp", qg, kpf[tab_row[j]],
                           preferred_element_type=jnp.float32,
                           precision=jax.lax.Precision.HIGHEST)
            k_pos = j * page_size + jnp.arange(page_size)
            return jnp.where((k_pos < len_row)[None, None, :], s, neg_inf)

        def max_pass(m, j):
            return jnp.maximum(m, jnp.max(logits(j), axis=-1)), None

        m, _ = jax.lax.scan(
            max_pass, jnp.full((kv_heads, g), neg_inf, jnp.float32),
            jnp.arange(n_pages))

        def contrib(j):
            p = jnp.exp(logits(j) - m[..., None])
            return jnp.sum(p, axis=-1), jnp.einsum(
                "kgp,pkd->kgd", p, vpf[tab_row[j]],
                preferred_element_type=jnp.float32,
                precision=jax.lax.Precision.HIGHEST)

        # materialize every page's (denominator, numerator) term first,
        # then left-fold with pure adds in a separate scan.  Keeping the
        # multiply out of the accumulation computation stops XLA from
        # contracting `acc + p @ v` into an FMA when the page contraction
        # degenerates to a broadcast multiply (page_size == 1) — the
        # interpret-mode kernel evaluates op by op and never fuses, so an
        # oracle-side FMA would break the bitwise contract by one ulp.
        ls, accs = jax.lax.map(contrib, jnp.arange(n_pages))

        def add_pass(carry, x):
            l, acc = carry
            dl, da = x
            return (l + dl, acc + da), None

        (l, acc), _ = jax.lax.scan(
            add_pass,
            (jnp.zeros((kv_heads, g), jnp.float32),
             jnp.zeros((kv_heads, g, hd), jnp.float32)),
            (ls, accs))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(row_fn, (q, ptab, kv_len))
    return out.reshape(b, h, hd).astype(q.dtype)


def bitline_mvm(
    g: jax.Array,     # (K, N)
    x: jax.Array,     # (M, K) signed plane in {-1, 0, +1}
    r_hat: float,
) -> jax.Array:
    """Parasitic bit-line currents; delegates to the core Thomas solver."""
    from repro.core.parasitics import bitline_currents

    return bitline_currents(g, x, r_hat)


def fused_mvm_diff(
    x_parts: jax.Array,   # (M, P, rows) integer-valued, padded to bm
    g_pos: jax.Array,     # (S, P, rows, N) padded to bn
    g_neg: jax.Array,     # (S, P, rows, N)
    adc_lo,               # (S,) per-slice calibrated range
    adc_hi,
    scale,                # scalar: gain * w_scale * x_scale
    *,
    adc_bits: int,
    cell_bits: int,
    n_bits,               # None = analog input accumulation
    bm: int,
    bn: int,
) -> jax.Array:
    """Oracle for ``fused.fused_mvm_pallas`` — the composed chain as plain
    jnp ops, walked in the kernel's exact tile order.

    Bitwise equality with the kernel rests on two things (see
    ``kernels.fused``): every dot is taken over the *identical*
    (bm, rows) x (rows, bn) operand tiles in the identical (i, j, p, s, b)
    order — same ``dot_general``, same reduction, same accumulation-add
    sequence — and every value feeding an accumulation add is produced by
    an add or an exact power-of-two multiply (the shared code-unit
    ``fused_adc_code_units`` epilogue), so FMA contraction cannot
    introduce a rounding difference between the two compilation contexts.
    The tile loops are static Python loops: tile counts on serving shapes
    are single digits.
    """
    from repro.kernels.analog_mvm import _bit_plane
    from repro.kernels.fused import (adc_lsb, fused_adc_code_units,
                                     term_weight)

    m, p, rows = x_parts.shape
    n_slices, _, _, n = g_pos.shape
    if m % bm or n % bn:
        raise ValueError(
            f"block shape ({bm}, {bn}) does not tile operand ({m}, {n})")
    scale = jnp.asarray(scale, jnp.float32).reshape(())
    lo = jnp.asarray(adc_lo, jnp.float32).reshape(n_slices)
    hi = jnp.asarray(adc_hi, jnp.float32).reshape(n_slices)
    bits = (None,) if n_bits is None else tuple(range(n_bits))
    out_scale = scale
    if n_slices == 1:
        out_scale = scale * adc_lsb(lo[0], hi[0], adc_bits)

    out_rows = []
    for i in range(m // bm):
        row_tiles = []
        for j in range(n // bn):
            tot = jnp.zeros((bm, bn), jnp.float32)
            for pi in range(p):
                x = x_parts[i * bm:(i + 1) * bm, pi, :]
                if n_bits is not None:
                    sign = jnp.sign(x)
                    mag = jnp.abs(x)
                acc = jnp.zeros((bm, bn), jnp.float32)
                for s in range(n_slices):
                    g = (g_pos[s, pi, :, j * bn:(j + 1) * bn]
                         - g_neg[s, pi, :, j * bn:(j + 1) * bn])
                    lsb = adc_lsb(lo[s], hi[s], adc_bits)
                    a_s = jnp.zeros((bm, bn), jnp.float32)
                    for b in bits:
                        plane = x if b is None else _bit_plane(mag, sign, b)
                        v = jnp.dot(plane, g,
                                    preferred_element_type=jnp.float32,
                                    precision=jax.lax.Precision.HIGHEST)
                        q = fused_adc_code_units(v, lo[s], lsb, adc_bits)
                        a_s = a_s + q * term_weight(0, 0, b)
                    if n_slices == 1:
                        acc = a_s
                    else:
                        acc = acc + (a_s * lsb) * term_weight(
                            cell_bits, s, None)
                tot = tot + acc
            row_tiles.append(tot * out_scale)
        out_rows.append(jnp.concatenate(row_tiles, axis=1))
    return jnp.concatenate(out_rows, axis=0)


def fused_mvm_parasitic(
    x_parts: jax.Array,   # (M, P, rows) integer-valued, padded to bm
    g_pos: jax.Array,     # (S, P, rows, N) padded to bn
    g_neg: jax.Array,     # (S, P, rows, N)
    r_hat,
    adc_lo,               # (S,)
    adc_hi,
    scale,                # scalar: gain * w_scale * x_scale
    *,
    adc_bits: int,
    cell_bits: int,
    n_bits: int,
    bm: int,
    bn: int,
) -> jax.Array:
    """Oracle for ``fused.fused_mvm_parasitic_pallas``: the same Thomas
    forward sweep (``bitline._thomas_bottom_current`` — shared, so the
    recurrence cannot diverge) over the kernel's exact operand tiles,
    analog bit accumulation, per-slice ADC, shift-and-add, one dequant."""
    from repro.kernels.analog_mvm import _bit_plane
    from repro.kernels.bitline import _thomas_bottom_current
    from repro.kernels.fused import (adc_lsb, fused_adc_code_units,
                                     term_weight)

    m, p, rows = x_parts.shape
    n_slices, _, _, n = g_pos.shape
    if m % bm or n % bn:
        raise ValueError(
            f"block shape ({bm}, {bn}) does not tile operand ({m}, {n})")
    scale = jnp.asarray(scale, jnp.float32).reshape(())
    r = jnp.asarray(r_hat, jnp.float32).reshape(())
    lo = jnp.asarray(adc_lo, jnp.float32).reshape(n_slices)
    hi = jnp.asarray(adc_hi, jnp.float32).reshape(n_slices)
    out_scale = scale
    if n_slices == 1:
        out_scale = scale * adc_lsb(lo[0], hi[0], adc_bits)

    out_rows = []
    for i in range(m // bm):
        row_tiles = []
        for j in range(n // bn):
            tot = jnp.zeros((bm, bn), jnp.float32)
            for pi in range(p):
                x = x_parts[i * bm:(i + 1) * bm, pi, :]
                sign = jnp.sign(x)
                mag = jnp.abs(x)
                acc = jnp.zeros((bm, bn), jnp.float32)
                for s in range(n_slices):
                    gp = g_pos[s, pi, :, j * bn:(j + 1) * bn]
                    gm = g_neg[s, pi, :, j * bn:(j + 1) * bn]
                    accb = jnp.zeros((bm, bn), jnp.float32)
                    for b in range(n_bits):
                        plane = _bit_plane(mag, sign, b)
                        i_pos = _thomas_bottom_current(plane, gp, r, k=rows)
                        i_neg = _thomas_bottom_current(plane, gm, r, k=rows)
                        accb = accb + (i_pos - i_neg) * 2.0 ** b
                    lsb = adc_lsb(lo[s], hi[s], adc_bits)
                    a_s = fused_adc_code_units(accb, lo[s], lsb, adc_bits)
                    if n_slices == 1:
                        acc = a_s
                    else:
                        acc = acc + (a_s * lsb) * term_weight(
                            cell_bits, s, None)
                tot = tot + acc
            row_tiles.append(tot * out_scale)
        out_rows.append(jnp.concatenate(row_tiles, axis=1))
    return jnp.concatenate(out_rows, axis=0)


def flash_attention_decode(
    q: jax.Array,          # (B, H, hd)
    k: jax.Array,          # (B, S, KV, hd) dense per-slot cache
    v: jax.Array,          # (B, S, KV, hd)
    kv_len: jax.Array,     # (B,) int32 valid positions per row
    *,
    block: int,
    scale=None,
) -> jax.Array:
    """Oracle for ``fused.flash_attention_pallas``.

    A dense per-slot cache chunked into ``block``-sized pieces *is* a
    paged pool whose block table is ``row * n_blocks + j`` — the chunk at
    (b, j) and the page at table entry (b, j) are the same (block, KV,
    hd) array, and the kernels walk them with identical contractions,
    masks, and phase order.  Delegating to ``paged_attention_decode``
    therefore reuses its proven bitwise form verbatim.
    """
    b, seq, kv_heads, hd = k.shape
    if seq % block:
        raise ValueError(f"cache length {seq} not divisible by "
                         f"block {block}")
    n_blocks = seq // block
    kp = k.reshape(b * n_blocks, block, kv_heads, hd)
    vp = v.reshape(b * n_blocks, block, kv_heads, hd)
    tab = (jnp.arange(b, dtype=jnp.int32)[:, None] * n_blocks
           + jnp.arange(n_blocks, dtype=jnp.int32)[None, :])
    return paged_attention_decode(q, kp, vp, tab, kv_len, scale=scale)


def analog_mvm_parasitic_diff(
    x_parts: jax.Array,   # (M, P, rows) integer-valued, signed
    g_pos: jax.Array,     # (P, rows, N)
    g_neg: jax.Array,     # (P, rows, N)
    *,
    r_hat: float,
    n_bits: int,
    adc_lo,
    adc_hi,
    adc_bits: int,
    gain: float,
) -> jax.Array:
    """Design-A path under parasitic bit-line resistance.

    Per input bit plane: both differential line stacks go through the
    tridiagonal bit-line solve; bits are accumulated in analog (the
    switched-capacitor stage after the bit line), then one ADC per
    partition and digital partition accumulation.  Output (M, N), code
    units — the oracle for ``ops.analog_mvm_parasitic``.
    """
    from repro.core.parasitics import bitline_currents

    sign = jnp.sign(x_parts)
    mag = jnp.abs(x_parts).astype(jnp.int32)
    solve = jax.vmap(bitline_currents, in_axes=(0, 1, None))  # over P
    acc = None
    for b in range(n_bits):
        plane = (((mag >> b) & 1).astype(x_parts.dtype)) * sign
        v = solve(g_pos, plane, r_hat) - solve(g_neg, plane, r_hat)
        contrib = v * (2.0 ** b)                              # (P, M, N)
        acc = contrib if acc is None else acc + contrib
    v_hat = adc(acc, adc_lo, adc_hi, adc_bits)
    return jnp.sum(v_hat, axis=0) * gain
