"""Pallas TPU kernel for the parasitic bit-line solve (paper Sec. 8).

One (bm, bn) output tile solves bm*bn independent tridiagonal systems of
depth K — one per (input sample, bit line).  The Thomas forward sweep is a
``fori_loop`` over rows carrying the (c', d') elimination state for the
whole tile in VREGs; the full x-tile (bm, K) and conductance tile (K, bn)
live in VMEM.  Only the bottom-node voltage is needed (the column output
current is the current through the bottom segment), so no back-substitution
pass or per-row voltage storage is required — this is the structural win
over a dense solve (O(K) work, O(1) state per line).

Grid: (M // bm, N // bn); K is kept whole inside the kernel (K <= 1152 for
realistic arrays: x tile 128x1152 f32 = 0.6 MB, g tile 1152x128 = 0.6 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import COMPILER_PARAMS


def _bitline_kernel(g_ref, x_ref, o_ref, *, r_hat: float, k: int):
    x = x_ref[...]                    # (bm, K) signed plane
    g = g_ref[...]                    # (K, bn)
    a = jnp.abs(x)

    bm = x.shape[0]
    bn = g.shape[1]

    def body(i, carry):
        c_prev, d_prev = carry                        # (bm, bn)
        g_i = jax.lax.dynamic_slice(g, (i, 0), (1, bn))      # (1, bn)
        x_i = jax.lax.dynamic_slice(x, (0, i), (bm, 1))      # (bm, 1)
        a_i = jax.lax.dynamic_slice(a, (0, i), (bm, 1))
        gr = a_i * g_i * r_hat                        # (bm, bn)
        rhs = x_i * g_i * r_hat
        base = jnp.where(i == 0, 1.0, 2.0)
        denom = base + gr + c_prev
        c_new = -1.0 / denom
        d_new = (rhs + d_prev) / denom
        return (c_new, d_new)

    zeros = jnp.zeros((bm, bn), jnp.float32)
    _, d_last = jax.lax.fori_loop(0, k, body, (zeros, zeros))
    o_ref[...] = (d_last / r_hat).astype(o_ref.dtype)


def bitline_mvm_pallas(
    g: jax.Array,          # (K, N)
    x: jax.Array,          # (M, K) signed plane
    r_hat: float,
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Output currents (M, N) under parasitic bit-line resistance."""
    if r_hat == 0.0:
        return x @ g
    k, n = g.shape
    m = x.shape[0]
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    kern = functools.partial(_bitline_kernel, r_hat=float(r_hat), k=k)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(g, x)
