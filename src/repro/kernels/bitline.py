"""Pallas TPU kernels for the parasitic bit-line solve (paper Sec. 8).

One (bm, bn) output tile solves bm*bn independent tridiagonal systems of
depth K — one per (input sample, bit line).  The Thomas forward sweep is a
``fori_loop`` over rows carrying the (c', d') elimination state for the
whole tile in VREGs; the full x-tile (bm, K) and conductance tile (K, bn)
live in VMEM.  Only the bottom-node voltage is needed (the column output
current is the current through the bottom segment), so no back-substitution
pass or per-row voltage storage is required — this is the structural win
over a dense solve (O(K) work, O(1) state per line).

``r_hat`` is a *traced* scalar input (a (1, 1) array read inside the
kernel), not a Python-float closure constant: the sweep engine batches a
whole Fig. 19 ``r_hat`` axis through one compiled program by substituting
traced values, so the kernel must not bake the parasitic level into the
compiled artifact.  Whether parasitics are in the program at all is a
*static* bit decided by the caller (``AnalogSpec.parasitics_on``).

Two kernels:

* :func:`bitline_mvm_pallas` — one signed input plane through the
  parasitic circuit (the building block ``core.analog._apply_line``
  dispatches to per (slice, partition)).
* :func:`analog_bitline_diff_pallas` — the fused Design-A fast path:
  in-VMEM input bit-plane extraction, per-bit Thomas solves for both
  differential lines, analog (switched-capacitor) accumulation over bits,
  one ADC per (tile, partition), digital accumulation over partitions —
  the parasitic analogue of ``analog_mvm._diff_kernel``.

Grid: (M // bm, N // bn[, P]); K is kept whole inside the kernel
(K <= 1152 for realistic arrays: x tile 128x1152 f32 = 0.6 MB, g tile
1152x128 = 0.6 MB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.compat import COMPILER_PARAMS


def _thomas_bottom_current(plane, g, r, *, k: int):
    """Bottom-node current (bm, bn) of one signed plane through one line
    stack: Thomas forward sweep over rows; d'_{K-1} IS v_{K-1} since
    c_{K-1} = 0 in back-substitution, and I = v_{K-1} / r.

    ``g_i * r`` is factored out so that every product feeding an add
    (``gr``, ``rhs``) has an exactly-representable value — ``a_i`` is in
    {0, 1} and ``x_i`` in {-1, 0, +1} — making the sweep FMA-invariant:
    whether LLVM contracts ``a*b + c`` into an FMA or not, the bits come
    out the same.  That is what lets the fused parasitic kernel
    (``kernels.fused``) be pinned bitwise against a jnp oracle calling
    this very function under a different compilation context.
    """
    a = jnp.abs(plane)
    bm = plane.shape[0]
    bn = g.shape[1]

    def body(i, carry):
        c_prev, d_prev = carry                        # (bm, bn)
        g_i = jax.lax.dynamic_slice(g, (i, 0), (1, bn))      # (1, bn)
        x_i = jax.lax.dynamic_slice(plane, (0, i), (bm, 1))  # (bm, 1)
        a_i = jax.lax.dynamic_slice(a, (0, i), (bm, 1))
        grr = g_i * r                                 # (1, bn)
        gr = a_i * grr                                # (bm, bn) exact
        rhs = x_i * grr                               # (bm, bn) exact
        base = jnp.where(i == 0, 1.0, 2.0)
        denom = base + gr + c_prev
        c_new = -1.0 / denom
        d_new = (rhs + d_prev) / denom
        return (c_new, d_new)

    zeros = jnp.zeros((bm, bn), jnp.float32)
    _, d_last = jax.lax.fori_loop(0, k, body, (zeros, zeros))
    return d_last / r


def _bitline_kernel(r_ref, g_ref, x_ref, o_ref, *, k: int):
    x = x_ref[...]                    # (bm, K) signed plane
    g = g_ref[...]                    # (K, bn)
    r = r_ref[0, 0]
    out = _thomas_bottom_current(x, g, r, k=k)
    o_ref[...] = out.astype(o_ref.dtype)


def bitline_mvm_pallas(
    g: jax.Array,          # (K, N)
    x: jax.Array,          # (M, K) signed plane
    r_hat,                 # scalar (traced or concrete) parasitic level
    *,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Output currents (M, N) under parasitic bit-line resistance."""
    from repro.core.parasitics import parasitics_off

    if parasitics_off(r_hat):
        return x @ g
    k, n = g.shape
    m = x.shape[0]
    if m % bm or n % bn:
        raise ValueError(
            f"block shape ({bm}, {bn}) does not tile operand ({m}, {n})")
    r2 = jnp.asarray(r_hat, jnp.float32).reshape(1, 1)
    kern = functools.partial(_bitline_kernel, k=k)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(r2, g, x)


def _parasitic_diff_kernel(r_ref, lo_ref, hi_ref, x_ref, gp_ref, gm_ref,
                           o_ref, *, n_bits: int, adc_bits: int,
                           gain: float, rows: int):
    """Fused parasitic Design-A path: per input bit, Thomas-solve both
    differential lines, analog-accumulate over bits, one ADC per
    partition, digital accumulation over partitions."""
    from repro.kernels.analog_mvm import _adc_epilogue, _bit_plane

    p = pl.program_id(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[:, 0, :]                     # (bm, rows) signed integer-valued
    gp = gp_ref[0]                         # (rows, bn)
    gm = gm_ref[0]
    r = r_ref[0, 0]
    sign = jnp.sign(x)
    mag = jnp.abs(x)

    acc = jnp.zeros((x.shape[0], gp.shape[1]), jnp.float32)
    for b in range(n_bits):                # static unroll: n_bits <= 7
        plane = _bit_plane(mag, sign, b)
        i_pos = _thomas_bottom_current(plane, gp, r, k=rows)
        i_neg = _thomas_bottom_current(plane, gm, r, k=rows)
        acc += 2.0 ** b * (i_pos - i_neg)  # switched-capacitor bit accum

    v_hat = _adc_epilogue(acc, lo_ref[0, 0], hi_ref[0, 0], adc_bits)
    o_ref[...] += (v_hat * gain).astype(o_ref.dtype)


def analog_bitline_diff_pallas(
    x_parts: jax.Array,    # (M, P, rows) integer-valued signed
    g_pos: jax.Array,      # (P, rows, N)
    g_neg: jax.Array,      # (P, rows, N)
    r_hat,                 # scalar (traced or concrete) parasitic level
    adc_lo: jax.Array,
    adc_hi: jax.Array,
    *,
    n_bits: int,
    adc_bits: int,
    gain: float,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused Design-A MVM under parasitic resistance; (M, N) code units."""
    m, p, rows = x_parts.shape
    _, _, n = g_pos.shape
    if m % bm or n % bn:
        raise ValueError(
            f"block shape ({bm}, {bn}) does not tile operand ({m}, {n})")
    r2 = jnp.asarray(r_hat, jnp.float32).reshape(1, 1)
    lo2 = jnp.asarray(adc_lo, jnp.float32).reshape(1, 1)
    hi2 = jnp.asarray(adc_hi, jnp.float32).reshape(1, 1)
    kern = functools.partial(
        _parasitic_diff_kernel, n_bits=n_bits, adc_bits=adc_bits,
        gain=gain, rows=rows)
    return pl.pallas_call(
        kern,
        grid=(m // bm, n // bn, p),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, p_: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, p_: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, p_: (0, 0)),
            pl.BlockSpec((bm, 1, rows), lambda i, j, p_: (i, p_, 0)),
            pl.BlockSpec((1, rows, bn), lambda i, j, p_: (p_, 0, j)),
            pl.BlockSpec((1, rows, bn), lambda i, j, p_: (p_, 0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(r2, lo2, hi2, x_parts, g_pos, g_neg)
