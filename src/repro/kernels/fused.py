"""Fused Pallas serving kernels: the whole analog decode chain per site.

One kernel launch covers what ``core.analog.analog_matmul`` otherwise
composes from many small ops: in-VMEM input bit-plane expansion (PR 3's
``analog_bitline_diff_pallas`` technique), the slice/partition-tiled
differential MVM, the per-partition ADC epilogue, and the dequant +
shift-and-add slice accumulation — so the (B, S, P, M, N) pre-ADC
intermediates of the composed path never exist in HBM.  A parasitic
variant runs the Thomas bit-line solve per input bit plane inside the
same launch (Design A under Sec. 8 parasitics).

Grid/BlockSpec layout (both MVM kernels)::

    grid = (M // bm, N // bn, P)           # P = analog K-partitions
    scale    (1, 1)                        # gain * w_scale * x_scale
    lo/hi    (S, 1)                        # per-slice calibrated ADC range
    x block  (bm, 1, rows)   index (i, p, 0)
    g blocks (S, 1, rows, bn) index (0, p, 0, j)   # all slices resident
    out      (bm, bn)        index (i, j)  accumulated over p

Slices and input bits are *static unrolled* loops inside one grid step
(S <= 8, n_bits <= 7), so a sliced design still costs one launch; the
innermost grid dimension walks partitions and revisits the output block,
and the final partition's step applies the single dequant multiply.

Bitwise contract
----------------
Every kernel here is pinned ``array_equal`` against its ``ref.py`` oracle
(``tests/test_kernels.py``).  Two disciplines make that hold across
compilation contexts (the oracle compiles inside an arbitrary XLA fusion;
the kernel lowers through interpret mode on CPU and Mosaic on TPU):

* *Exact-product multiply-adds only.*  LLVM may contract ``a + b*c`` into
  an FMA (one rounding instead of two) depending on the surrounding
  graph — even across optimization barriers (see ``kernels.paged``), and
  XLA's simplifier strips identity ``* 1.0`` weights first, so the
  multiply the add actually sees is whatever produced the term.  An FMA
  is bit-identical to mul-then-add exactly when the product rounds to
  itself, so the epilogue is arranged so that every value feeding an
  accumulation add is produced by an *add* or by an exact power-of-two
  multiply: the ADC stays in code units (``fused_adc_code_units`` ends in
  ``lo/lsb + code``), bit weights ``2**b`` and slice weights are powers
  of two, and the one inexact ``* lsb`` per slice is applied *outside*
  the bit fold where its outer power-of-two weight shields it (single-
  slice designs defer ``lsb`` to the final dequant multiply entirely).
  The result is FMA-*invariant*: any contraction choice yields the same
  bits.
* *Shape-matched dots.*  The oracle mirrors the wrapper's padding and
  walks the identical (bm, rows) x (rows, bn) tiles in the identical
  (i, j, p, s, b) order, so each ``dot_general`` reduction and each
  accumulation add sees the same operands in the same order on both sides.

The dequant scale (``gain * w_scale * x_scale``) is one *traced* (1, 1)
operand — the sweep engine batches traced ``on_off_ratio`` (hence traced
``gain``) points through a single compilation, so the kernel must not
bake it in (same rule as ``r_hat`` in ``kernels.bitline``).

``flash_attention_pallas`` is the dense-cache sibling of PR 8's paged
kernel: same three-phase (max / materialize / pure-add) structure and
bitwise discipline, but blocks are addressed arithmetically as
``(row, j)`` chunks of the per-slot ``(B, S, KV, hd)`` cache — no block
table, one scalar-prefetch operand for the per-row fills.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.analog_mvm import _bit_plane
from repro.kernels.bitline import _thomas_bottom_current
from repro.kernels.compat import COMPILER_PARAMS
from repro.kernels.paged import NEG_INF


#: static compile identities of every fused MVM kernel traced in this
#: process, in ``core.analog.fuse_signature`` format.  Populated at trace
#: time (one entry per distinct fused program structure, shapes excluded);
#: the ``serve/fused-compile-per-site-class`` contract clears it, serves a
#: trace, and pins it equal to ``hw.fused_site_classes`` of the profile.
BUILD_SIGNATURES: set = set()


def adc_lsb(lo, hi, bits: int):
    """ADC step size with the ``core.adc`` degenerate-range guard."""
    lsb = (hi - lo) / (2 ** bits - 1)
    return jnp.where(lsb <= 0, 1.0, lsb)


def fused_adc_code_units(v, lo, lsb, bits: int):
    """Clip/quantize to ``2**bits`` levels, returning the dequantized
    value in *code units* (``value / lsb = lo/lsb + code``).

    Keeping the epilogue in code units until a single late ``* lsb`` is
    what makes the accumulation FMA-invariant (see module docstring):
    the value fed to every accumulation add is produced by this *add*
    (or by an exact power-of-two multiply of it), never by the inexact
    ``* lsb`` — so LLVM contracting ``a + b*c`` into an FMA cannot
    change the bits on either the kernel or the oracle side.

    Shared verbatim by the kernels and the ``ref.py`` oracles so the
    epilogue cannot diverge between them.  ``(lo/lsb + code) * lsb``
    matches ``core.adc.adc_quantize`` to within 1 ulp (same grid,
    different rounding of the ``lo`` offset).
    """
    n_levels = 2 ** bits
    code = jnp.clip(jnp.round((v - lo) / lsb), 0.0, n_levels - 1.0)
    return lo / lsb + code


def term_weight(cell_bits: int, s: int, b) -> float:
    """Shift-and-add weight of slice ``s``, input bit ``b`` (``None`` for
    the analog-accumulation single term) — an exact power of two."""
    return 2.0 ** (cell_bits * s + (0 if b is None else b))


def _fused_diff_kernel(scale_ref, lo_ref, hi_ref, x_ref, gp_ref, gm_ref,
                       o_ref, *, adc_bits: int, cell_bits: int, n_bits):
    """Differential MVM chain: per (slice, bit) matmul + ADC, shift-and-add
    in code units, partition accumulation, final dequant multiply.

    ``n_bits is None`` selects analog input accumulation (the quantized
    integer activations feed the array whole, one ADC per slice);
    otherwise bit planes are extracted in VMEM and digitized separately
    (digital shift-and-add, Design D/E style).
    """
    p = pl.program_id(2)
    n_p = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[:, 0, :]                       # (bm, rows) integer-valued
    n_slices = gp_ref.shape[0]
    if n_bits is not None:
        sign = jnp.sign(x)
        mag = jnp.abs(x)
    acc = jnp.zeros_like(o_ref)
    for s in range(n_slices):                # static unroll: S <= 8
        g = gp_ref[s, 0] - gm_ref[s, 0]      # (rows, bn) analog subtraction
        lo = lo_ref[s, 0]
        lsb = adc_lsb(lo, hi_ref[s, 0], adc_bits)
        a_s = jnp.zeros_like(o_ref)          # slice accum, code units
        for b in (range(n_bits) if n_bits is not None else (None,)):
            plane = x if b is None else _bit_plane(mag, sign, b)
            v = jnp.dot(plane, g, preferred_element_type=jnp.float32,
                        precision=jax.lax.Precision.HIGHEST)
            q = fused_adc_code_units(v, lo, lsb, adc_bits)
            # exact power-of-two product: FMA-invariant accumulation
            a_s = a_s + q * term_weight(0, 0, b)
        if n_slices == 1:
            acc = a_s                        # lsb folds into final dequant
        else:
            # outer multiply is the exact power-of-two slice weight, so
            # contraction into the cross-slice add cannot reround
            acc = acc + (a_s * lsb) * term_weight(cell_bits, s, None)
    o_ref[...] += acc

    @pl.when(p == n_p - 1)
    def _dequant():
        out_scale = scale_ref[0, 0]
        if n_slices == 1:
            out_scale = out_scale * adc_lsb(lo_ref[0, 0], hi_ref[0, 0],
                                            adc_bits)
        o_ref[...] = o_ref[...] * out_scale


def _fused_parasitic_kernel(scale_ref, r_ref, lo_ref, hi_ref, x_ref,
                            gp_ref, gm_ref, o_ref, *, adc_bits: int,
                            cell_bits: int, n_bits: int, rows: int):
    """Design-A parasitic chain: per (slice, bit) Thomas solves for both
    differential lines, analog (switched-capacitor) accumulation over
    bits, one ADC per slice, shift-and-add, final dequant multiply."""
    p = pl.program_id(2)
    n_p = pl.num_programs(2)

    @pl.when(p == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[:, 0, :]                       # (bm, rows) integer-valued
    r = r_ref[0, 0]
    sign = jnp.sign(x)
    mag = jnp.abs(x)
    n_slices = gp_ref.shape[0]
    acc = jnp.zeros_like(o_ref)
    for s in range(n_slices):                # static unroll: S <= 8
        gp = gp_ref[s, 0]                    # (rows, bn)
        gm = gm_ref[s, 0]
        accb = jnp.zeros_like(o_ref)
        for b in range(n_bits):              # static unroll: n_bits <= 7
            plane = _bit_plane(mag, sign, b)
            i_pos = _thomas_bottom_current(plane, gp, r, k=rows)
            i_neg = _thomas_bottom_current(plane, gm, r, k=rows)
            accb = accb + (i_pos - i_neg) * 2.0 ** b
        lo = lo_ref[s, 0]
        lsb = adc_lsb(lo, hi_ref[s, 0], adc_bits)
        a_s = fused_adc_code_units(accb, lo, lsb, adc_bits)
        if n_slices == 1:
            acc = a_s                        # lsb folds into final dequant
        else:
            acc = acc + (a_s * lsb) * term_weight(cell_bits, s, None)
    o_ref[...] += acc

    @pl.when(p == n_p - 1)
    def _dequant():
        out_scale = scale_ref[0, 0]
        if n_slices == 1:
            out_scale = out_scale * adc_lsb(lo_ref[0, 0], hi_ref[0, 0],
                                            adc_bits)
        o_ref[...] = o_ref[...] * out_scale


def _mvm_call(kern, x_parts, g_pos, g_neg, extra, adc_lo, adc_hi, scale, *,
              bm: int, bn: int, interpret: bool):
    """Shared pallas_call plumbing for both fused MVM kernels.

    ``extra`` is a list of additional leading (1, 1) scalar operands
    (the parasitic ``r_hat``); ``adc_lo/adc_hi`` are per-slice (S,).
    """
    m, p, rows = x_parts.shape
    n_slices, _, _, n = g_pos.shape
    if m % bm or n % bn:
        raise ValueError(
            f"block shape ({bm}, {bn}) does not tile operand ({m}, {n})")
    grid = (m // bm, n // bn, p)
    scale2 = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    lo2 = jnp.asarray(adc_lo, jnp.float32).reshape(n_slices, 1)
    hi2 = jnp.asarray(adc_hi, jnp.float32).reshape(n_slices, 1)
    extra2 = [jnp.asarray(e, jnp.float32).reshape(1, 1) for e in extra]
    scalar_specs = [pl.BlockSpec((1, 1), lambda i, j, p_: (0, 0))
                    for _ in range(1 + len(extra2))]
    slice_specs = [pl.BlockSpec((n_slices, 1), lambda i, j, p_: (0, 0))
                   for _ in range(2)]
    g_spec = pl.BlockSpec((n_slices, 1, rows, bn),
                          lambda i, j, p_: (0, p_, 0, j))
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=scalar_specs + slice_specs + [
            pl.BlockSpec((bm, 1, rows), lambda i, j, p_: (i, p_, 0)),
            g_spec,
            g_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p_: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(scale2, *extra2, lo2, hi2, x_parts, g_pos, g_neg)


def fused_mvm_pallas(
    x_parts: jax.Array,    # (M, P, rows) integer-valued signed
    g_pos: jax.Array,      # (S, P, rows, N)
    g_neg: jax.Array,      # (S, P, rows, N)
    adc_lo: jax.Array,     # (S,) per-slice calibrated range
    adc_hi: jax.Array,
    scale,                 # traced scalar: gain * w_scale * x_scale
    *,
    adc_bits: int,
    cell_bits: int,
    n_bits,                # None = analog input accumulation
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused differential analog MVM; returns the dequantized (M, N)."""
    BUILD_SIGNATURES.add(("linear", g_pos.shape[0], cell_bits, adc_bits,
                          n_bits, None))
    kern = functools.partial(_fused_diff_kernel, adc_bits=adc_bits,
                             cell_bits=cell_bits, n_bits=n_bits)
    return _mvm_call(kern, x_parts, g_pos, g_neg, [], adc_lo, adc_hi,
                     scale, bm=bm, bn=bn, interpret=interpret)


def fused_mvm_parasitic_pallas(
    x_parts: jax.Array,    # (M, P, rows) integer-valued signed
    g_pos: jax.Array,      # (S, P, rows, N)
    g_neg: jax.Array,      # (S, P, rows, N)
    r_hat,                 # traced or concrete scalar parasitic level
    adc_lo: jax.Array,     # (S,)
    adc_hi: jax.Array,
    scale,                 # traced scalar: gain * w_scale * x_scale
    *,
    adc_bits: int,
    cell_bits: int,
    n_bits: int,
    bm: int = 128,
    bn: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Fused parasitic differential MVM; returns the dequantized (M, N)."""
    BUILD_SIGNATURES.add(("parasitic", g_pos.shape[0], cell_bits, adc_bits,
                          None, n_bits))
    rows = x_parts.shape[-1]
    kern = functools.partial(_fused_parasitic_kernel, adc_bits=adc_bits,
                             cell_bits=cell_bits, n_bits=n_bits, rows=rows)
    return _mvm_call(kern, x_parts, g_pos, g_neg, [r_hat], adc_lo, adc_hi,
                     scale, bm=bm, bn=bn, interpret=interpret)


# ---------------------------------------------------------------------------
# flash-decode attention over the dense per-slot KV cache
# ---------------------------------------------------------------------------


def _flash_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, da_ref, *,
                  block: int, scale: float):
    """Three-phase flash-decode over dense cache chunks — the body of
    ``kernels.paged._paged_kernel`` with arithmetic block addressing in
    place of the block-table gather (see that module for why the phases
    and the per-chunk term slots are what make it bit-exact)."""
    b = pl.program_id(0)
    phase = pl.program_id(1)
    j = pl.program_id(2)
    n_blocks = pl.num_programs(2)

    @pl.when((phase == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_heads, g, hd = acc_ref.shape
    qg = q_ref[0].reshape(kv_heads, g, hd) * scale       # (KV, g, hd) f32
    k = k_ref[0]                                         # (block, KV, hd)

    s = jnp.einsum("kgd,pkd->kgp", qg, k,
                   preferred_element_type=jnp.float32,
                   precision=jax.lax.Precision.HIGHEST)  # (KV, g, block)
    k_pos = j * block + jax.lax.iota(jnp.int32, block)
    valid = k_pos < len_ref[b]
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    @pl.when(phase == 0)
    def _max_pass():
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(s, axis=-1))

    @pl.when(phase == 1)
    def _materialize():
        p = jnp.exp(s - m_ref[...][..., None])           # (KV, g, block)
        l_ref[...] = l_ref[...] + jnp.sum(p, axis=-1)
        da_ref[j] = jnp.einsum("kgp,pkd->kgd", p, v_ref[0],
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.HIGHEST)

    @pl.when(phase == 2)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + da_ref[j]

    @pl.when((phase == 2) & (j == n_blocks - 1))
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(kv_heads * g, hd)


def flash_attention_pallas(
    q: jax.Array,          # (B, H, hd) float32
    k: jax.Array,          # (B, S, KV, hd) float32 dense per-slot cache
    v: jax.Array,          # (B, S, KV, hd) float32
    kv_len: jax.Array,     # (B,) int32 valid positions per row
    *,
    block: int,
    scale: float,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    _, seq, kv_heads, _ = k.shape
    if seq % block:
        raise ValueError(f"cache length {seq} not divisible by "
                         f"block {block}")
    if h % kv_heads:
        raise ValueError(f"{h} query heads not divisible by {kv_heads} "
                         "KV heads")
    g = h // kv_heads
    n_blocks = seq // block
    kern = functools.partial(_flash_kernel, block=block, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, 3, n_blocks),
        in_specs=[
            pl.BlockSpec((1, h, hd),
                         lambda bi, ph, j, ln: (bi, 0, 0)),
            pl.BlockSpec((1, block, kv_heads, hd),
                         lambda bi, ph, j, ln: (bi, j, 0, 0)),
            pl.BlockSpec((1, block, kv_heads, hd),
                         lambda bi, ph, j, ln: (bi, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd),
                               lambda bi, ph, j, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv_heads, g), jnp.float32),       # global max
            pltpu.VMEM((kv_heads, g), jnp.float32),       # denominator
            pltpu.VMEM((kv_heads, g, hd), jnp.float32),   # weighted acc
            pltpu.VMEM((n_blocks, kv_heads, g, hd),
                       jnp.float32),                      # per-chunk terms
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(kv_len, q, k, v)
