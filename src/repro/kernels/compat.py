"""Pallas API compatibility across jax releases.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``;
resolve whichever this installation provides so the interpret-mode CPU
path (and Mosaic on TPU) runs on either side of the rename.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
