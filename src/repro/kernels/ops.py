"""Jit'd public wrappers for the Pallas kernels.

Handles MXU-alignment padding (M/N to tile multiples), backend selection
(``interpret=True`` on CPU — the container's validation mode — and compiled
Mosaic on TPU), and the squeeze/reshape glue to/from the shapes used by
``repro.core.analog``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import analog_mvm as _k_mvm
from repro.kernels import bitline as _k_bl
from repro.kernels import fused as _k_fused
from repro.kernels import paged as _k_paged
from repro.kernels import ref as _k_ref


def _use_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _pick_tile(size: int, pref: int, *, lane: bool = False) -> int:
    """Tile size for one grid dimension.

    The lane (last, N) dimension is always the full ``pref`` (128) tile:
    Mosaic requires lane tiles of 128, so small N pads up to one full
    tile rather than shrinking it (interpret mode tolerates any tile,
    which is exactly how a sublane-rounded N tile stayed latent until
    TPU compilation).  Sublane (M) dimensions may shrink to cap padding
    waste on small inputs — but only to a *power-of-two multiple of 8*
    (8, 16, 32, 64, ...): an M that is already a multiple of 8 used to be
    taken verbatim as the tile, and odd multiples of 8 (24, 40, 56, ...)
    are the fragile Mosaic relayout class that small-N tiles fell into
    before PR 3 pinned the lane rule.  Rounding to the next power-of-two
    multiple keeps padding waste under 2x and every tile in the
    well-trodden {8, 16, 32, 64, 128} set.
    """
    if lane:
        return pref
    if size >= pref:
        return pref
    tile = 8
    while tile < size:
        tile *= 2
    return min(tile, pref)


def analog_mvm(
    x_parts: jax.Array,      # (M, P, rows)
    g_pos: jax.Array,        # (S=1, P, rows, N) or (P, rows, N)
    g_neg: jax.Array,
    *,
    adc_lo: jax.Array,
    adc_hi: jax.Array,
    adc_bits: int,
    gain: float,
    interpret: bool | None = None,
) -> jax.Array:
    """Design-A fused analog MVM; returns (M, N) in code units."""
    if g_pos.ndim == 4:
        g_pos, g_neg = g_pos[0], g_neg[0]
    interpret = _use_interpret() if interpret is None else interpret
    m, p, rows = x_parts.shape
    n = g_pos.shape[-1]
    bm = _pick_tile(m, 128)
    bn = _pick_tile(n, 128, lane=True)
    xp = _pad_to(x_parts.astype(jnp.float32), 0, bm)
    gp = _pad_to(g_pos.astype(jnp.float32), 2, bn)
    gm = _pad_to(g_neg.astype(jnp.float32), 2, bn)
    out = _k_mvm.analog_mvm_diff_pallas(
        xp, gp, gm,
        jnp.asarray(adc_lo), jnp.asarray(adc_hi),
        adc_bits=adc_bits, gain=float(gain),
        bm=bm, bn=bn, interpret=interpret,
    )
    return out[:m, :n]


def analog_mvm_bitserial(
    x_parts: jax.Array,
    g_pos: jax.Array,
    g_neg: jax.Array,
    *,
    n_bits: int,
    adc_lo: jax.Array,
    adc_hi: jax.Array,
    adc_bits: int,
    gain: float,
    interpret: bool | None = None,
) -> jax.Array:
    """Design-D fused bit-serial analog MVM; returns (M, N) code units."""
    if g_pos.ndim == 4:
        g_pos, g_neg = g_pos[0], g_neg[0]
    interpret = _use_interpret() if interpret is None else interpret
    m, p, rows = x_parts.shape
    n = g_pos.shape[-1]
    bm = _pick_tile(m, 128)
    bn = _pick_tile(n, 128, lane=True)
    xp = _pad_to(x_parts.astype(jnp.float32), 0, bm)
    gp = _pad_to(g_pos.astype(jnp.float32), 2, bn)
    gm = _pad_to(g_neg.astype(jnp.float32), 2, bn)
    out = _k_mvm.analog_mvm_bitserial_pallas(
        xp, gp, gm,
        jnp.asarray(adc_lo), jnp.asarray(adc_hi),
        n_bits=n_bits, adc_bits=adc_bits, gain=float(gain),
        bm=bm, bn=bn, interpret=interpret,
    )
    return out[:m, :n]


def paged_attention(
    q: jax.Array,          # (B, H, hd)
    k_pages: jax.Array,    # (P, page_size, KV, hd)
    v_pages: jax.Array,    # (P, page_size, KV, hd)
    ptab: jax.Array,       # (B, NP) int32 block table
    kv_len: jax.Array,     # (B,) int32 valid positions per row
    *,
    scale: float | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Decode-step attention over a paged KV pool; returns (B, H, hd).

    The page gather happens inside the kernel via scalar-prefetched
    block-table indices, so the dense ``(B, NP*page)`` gather is never
    materialized.  Bit-exact vs ``ref.paged_attention_decode`` in
    float32 (positions >= ``kv_len[b]`` contribute exact zeros).

    TPU alignment pads the head dim (lane) to 128 with zeros — exact, as
    the padded lanes contribute zero dot products and are sliced away.
    ``page_size`` indexes absolute token positions so it can never be
    padded; Mosaic needs it sublane-aligned (multiple of 8).

    ``page_size == 1`` is canonicalized before the kernel runs: a row of
    ``NP`` one-token pages *is* one page of ``NP`` tokens, so the pool is
    pre-gathered into a per-row pool ``(B, NP, KV, hd)`` with the identity
    block table.  Size-1 page einsums degenerate to elementwise code whose
    FMA contraction is fusion-context-dependent on CPU (the same dot can
    round differently between the kernel's two phases), which breaks the
    bitwise contract; the canonical shape keeps every contraction a real
    ``dot_general``.  ``ref.paged_attention_decode`` applies the identical
    rewrite, so the bitwise comparison is over the same canonical problem.
    """
    interpret = _use_interpret() if interpret is None else interpret
    b, h, hd = q.shape
    page_size = k_pages.shape[1]
    n_pages = ptab.shape[1]
    scale = hd ** -0.5 if scale is None else scale
    qp, kp, vp = q, k_pages, v_pages
    if not interpret and page_size % 8:
        raise ValueError(
            f"page_size={page_size} must be a multiple of 8 (sublane) "
            "for the compiled TPU kernel")
    if page_size == 1 and n_pages > 1:
        ptab = jnp.asarray(ptab, jnp.int32)
        kp = kp[:, 0][ptab]                  # (B, NP, KV, hd) per-row pool
        vp = vp[:, 0][ptab]
        ptab = jnp.arange(b, dtype=jnp.int32)[:, None]
    if not interpret:
        qp = _pad_to(qp, 2, 128)
        kp = _pad_to(kp, 3, 128)
        vp = _pad_to(vp, 3, 128)
    out = _k_paged.paged_attention_pallas(
        qp.astype(jnp.float32), kp.astype(jnp.float32),
        vp.astype(jnp.float32),
        jnp.asarray(ptab, jnp.int32), jnp.asarray(kv_len, jnp.int32),
        scale=float(scale), interpret=interpret,
    )
    return out[:, :, :hd].astype(q.dtype)


def fused_mvm(
    x_parts: jax.Array,      # (M, P, rows) integer-valued signed
    g_pos: jax.Array,        # (S, P, rows, N)
    g_neg: jax.Array,        # (S, P, rows, N)
    *,
    adc_lo: jax.Array,       # (S,) per-slice calibrated range
    adc_hi: jax.Array,
    adc_bits: int,
    cell_bits: int,
    n_bits,                  # None = analog input accumulation
    scale,                   # traced scalar: gain * w_scale * x_scale
    backend: str = "kernel",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused analog MVM chain (matmul + ADC + dequant + slice/bit
    shift-and-add in one launch); returns the dequantized (M, N).

    ``backend="kernel"`` runs the Pallas kernel (interpret mode off-TPU),
    ``"oracle"`` the bitwise-identical jnp mirror (``kernels.ref``) —
    the composed multi-op form of the same chain, which is what the
    fused serving runtime is agreement-gated against end to end.
    """
    if backend not in ("kernel", "oracle"):
        raise ValueError(f"unknown fused_mvm backend {backend!r}")
    interpret = _use_interpret() if interpret is None else interpret
    m, p, rows = x_parts.shape
    n = g_pos.shape[-1]
    bm = _pick_tile(m, 128)
    bn = _pick_tile(n, 128, lane=True)
    xp = _pad_to(x_parts.astype(jnp.float32), 0, bm)
    gp = _pad_to(g_pos.astype(jnp.float32), 3, bn)
    gm = _pad_to(g_neg.astype(jnp.float32), 3, bn)
    if backend == "oracle":
        out = _k_ref.fused_mvm_diff(
            xp, gp, gm, adc_lo, adc_hi, scale,
            adc_bits=adc_bits, cell_bits=cell_bits, n_bits=n_bits,
            bm=bm, bn=bn,
        )
    else:
        out = _k_fused.fused_mvm_pallas(
            xp, gp, gm, adc_lo, adc_hi, scale,
            adc_bits=adc_bits, cell_bits=cell_bits, n_bits=n_bits,
            bm=bm, bn=bn, interpret=interpret,
        )
    return out[:m, :n]


def fused_mvm_parasitic(
    x_parts: jax.Array,      # (M, P, rows) integer-valued signed
    g_pos: jax.Array,        # (S, P, rows, N)
    g_neg: jax.Array,        # (S, P, rows, N)
    *,
    r_hat,                   # scalar parasitic level (traced or concrete)
    adc_lo: jax.Array,       # (S,)
    adc_hi: jax.Array,
    adc_bits: int,
    cell_bits: int,
    n_bits: int,
    scale,                   # traced scalar: gain * w_scale * x_scale
    backend: str = "kernel",
    interpret: bool | None = None,
) -> jax.Array:
    """Fused parasitic analog MVM chain (per-bit Thomas solve + analog
    bit accumulation + ADC + dequant in one launch); dequantized (M, N)."""
    if backend not in ("kernel", "oracle"):
        raise ValueError(f"unknown fused_mvm_parasitic backend {backend!r}")
    interpret = _use_interpret() if interpret is None else interpret
    m, p, rows = x_parts.shape
    n = g_pos.shape[-1]
    bm = _pick_tile(m, 128)
    bn = _pick_tile(n, 128, lane=True)
    xp = _pad_to(x_parts.astype(jnp.float32), 0, bm)
    gp = _pad_to(g_pos.astype(jnp.float32), 3, bn)
    gm = _pad_to(g_neg.astype(jnp.float32), 3, bn)
    if backend == "oracle":
        out = _k_ref.fused_mvm_parasitic(
            xp, gp, gm, r_hat, adc_lo, adc_hi, scale,
            adc_bits=adc_bits, cell_bits=cell_bits, n_bits=n_bits,
            bm=bm, bn=bn,
        )
    else:
        out = _k_fused.fused_mvm_parasitic_pallas(
            xp, gp, gm, r_hat, adc_lo, adc_hi, scale,
            adc_bits=adc_bits, cell_bits=cell_bits, n_bits=n_bits,
            bm=bm, bn=bn, interpret=interpret,
        )
    return out[:m, :n]


def flash_attention_decode(
    q: jax.Array,          # (B, H, hd)
    k: jax.Array,          # (B, S, KV, hd) dense per-slot cache
    v: jax.Array,          # (B, S, KV, hd)
    kv_len: jax.Array,     # (B,) int32 valid positions per row
    *,
    block: int = 8,
    scale: float | None = None,
    backend: str = "kernel",
    interpret: bool | None = None,
) -> jax.Array:
    """Flash-decode attention over the *dense* per-slot KV cache; returns
    (B, H, hd).  The dense sibling of :func:`paged_attention`: chunks are
    addressed arithmetically as (row, j) blocks — no block table, no
    gather — and per-row fills arrive by scalar prefetch, so positions at
    or beyond ``kv_len[b]`` contribute exact zeros.

    ``backend="oracle"`` runs the bitwise-identical jnp mirror (the
    chunked cache viewed as a paged pool with an arange table).  The
    cache length is zero-padded to a ``block`` multiple — exact, the pad
    sits at positions >= ``kv_len`` behind the mask.  TPU alignment pads
    the head dim (lane) to 128 with zeros, sliced away on return;
    ``block`` must stay sublane-aligned (multiple of 8) when compiled.
    """
    if backend not in ("kernel", "oracle"):
        raise ValueError(f"unknown flash_attention_decode backend "
                         f"{backend!r}")
    interpret = _use_interpret() if interpret is None else interpret
    b, h, hd = q.shape
    scale = hd ** -0.5 if scale is None else scale
    if not interpret and block % 8:
        raise ValueError(
            f"block={block} must be a multiple of 8 (sublane) for the "
            "compiled TPU kernel")
    kp = _pad_to(k, 1, block)
    vp = _pad_to(v, 1, block)
    qp = q
    if not interpret:
        qp = _pad_to(qp, 2, 128)
        kp = _pad_to(kp, 3, 128)
        vp = _pad_to(vp, 3, 128)
    kv_len = jnp.asarray(kv_len, jnp.int32)
    if backend == "oracle":
        out = _k_ref.flash_attention_decode(
            qp.astype(jnp.float32), kp.astype(jnp.float32),
            vp.astype(jnp.float32), kv_len,
            block=block, scale=float(scale),
        )
    else:
        out = _k_fused.flash_attention_pallas(
            qp.astype(jnp.float32), kp.astype(jnp.float32),
            vp.astype(jnp.float32), kv_len,
            block=block, scale=float(scale), interpret=interpret,
        )
    return out[:, :, :hd].astype(q.dtype)


def bitline_mvm(
    g: jax.Array,            # (K, N)
    x: jax.Array,            # (M, K) signed plane
    r_hat,                   # scalar parasitic level (traced or concrete)
    *,
    interpret: bool | None = None,
) -> jax.Array:
    """Parasitic bit-line MVM; returns output currents (M, N).

    ``r_hat`` may be a traced scalar (the sweep engine batches a whole
    Fig. 19 axis through one compilation); a *concrete* 0.0 short-circuits
    to the ideal matmul — that on/off decision is a program-structure bit
    and is never traced (``AnalogSpec.parasitics_on``).
    """
    from repro.core.parasitics import parasitics_off

    if parasitics_off(r_hat):
        return x @ g
    interpret = _use_interpret() if interpret is None else interpret
    m, k = x.shape
    n = g.shape[1]
    bm = _pick_tile(m, 128)
    bn = _pick_tile(n, 128, lane=True)
    xp = _pad_to(x.astype(jnp.float32), 0, bm)
    gp = _pad_to(g.astype(jnp.float32), 1, bn)
    out = _k_bl.bitline_mvm_pallas(gp, xp, r_hat, bm=bm, bn=bn,
                                   interpret=interpret)
    return out[:m, :n]


def analog_mvm_parasitic(
    x_parts: jax.Array,      # (M, P, rows) integer-valued signed
    g_pos: jax.Array,        # (S=1, P, rows, N) or (P, rows, N)
    g_neg: jax.Array,
    *,
    r_hat,                   # scalar parasitic level (traced or concrete)
    n_bits: int,
    adc_lo: jax.Array,
    adc_hi: jax.Array,
    adc_bits: int,
    gain: float,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused Design-A analog MVM under parasitic bit-line resistance.

    Per input bit plane: Thomas-solve both differential line stacks,
    analog-accumulate over bits, one ADC per partition, digital partition
    accumulation — all inside one kernel.  Returns (M, N) code units.
    """
    if g_pos.ndim == 4:
        g_pos, g_neg = g_pos[0], g_neg[0]
    interpret = _use_interpret() if interpret is None else interpret
    m, p, rows = x_parts.shape
    n = g_pos.shape[-1]
    bm = _pick_tile(m, 128)
    bn = _pick_tile(n, 128, lane=True)
    xp = _pad_to(x_parts.astype(jnp.float32), 0, bm)
    gp = _pad_to(g_pos.astype(jnp.float32), 2, bn)
    gm = _pad_to(g_neg.astype(jnp.float32), 2, bn)
    out = _k_bl.analog_bitline_diff_pallas(
        xp, gp, gm, r_hat,
        jnp.asarray(adc_lo), jnp.asarray(adc_hi),
        n_bits=n_bits, adc_bits=adc_bits, gain=float(gain),
        bm=bm, bn=bn, interpret=interpret,
    )
    return out[:m, :n]
