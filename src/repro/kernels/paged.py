"""Pallas TPU paged-attention decode kernel.

Single-token (decode-step) GQA attention over a paged KV pool: the KV
cache lives as fixed-size pages in one global ``(P, page, KV, hd)`` pool
and each batch row owns an ordered page list (its block table).  The
gather happens *in kernel*: the block table is a scalar-prefetch operand,
so each page's DMA source is computed from ``ptab[b, j]`` before the
body runs and pages stream HBM -> VMEM without ever materializing the
``(B, NP*page)`` dense gather in HBM (the same in-VMEM staging idea as
PR 3's ``analog_bitline_diff_pallas``, applied to the KV stream).

Grid/Block layout::

    grid = (B, 3, NP)              # NP = pages per row (block-table width)
    q block   (1, H, hd)      index (b, 0, 0)
    k/v block (1, ps, KV, hd) index (ptab[b, j], 0, 0, 0)   (prefetched)
    out       (1, H, hd)      index (b, 0, 0)  written at the last cell

The middle grid dimension is the *phase*: phase 0 walks the row's pages
accumulating only the running logit max into VMEM scratch; phase 1
re-walks them materializing each page's softmax contribution against
that now-global max into a per-page scratch slot; phase 2 folds the
slots into the output with pure adds.  A classic one-pass flash-decode
recurrence would rescale (``acc * corr + p @ v``) — a multiply-add that
XLA/LLVM may or may not contract into an FMA depending on the
surrounding graph, which breaks bitwise reproducibility between the
kernel and any independently compiled oracle.  Even the two-phase form
is not enough: when ``page_size == 1`` the page contraction degenerates
to a bare multiply and LLVM contracts ``acc + p * v`` into an FMA *even
across an explicit optimization barrier* (one rounding instead of two —
a 1-ulp drift).  Materializing every page's term first forces each
product through a loop-carried scratch buffer, where it must be a
rounded f32 before the phase-2 add ever sees it; the accumulation is
then a plain add of identically-computed terms in page order, so the
kernel is *bit-exact* against ``ref.paged_attention_decode`` (pinned
with ``array_equal`` in ``tests/test_kernels.py``), at the cost of
streaming K twice and ``NP`` per-page term slots of VMEM scratch.

Per-row cache lengths arrive as the second scalar-prefetch operand;
positions at or beyond ``kv_len[b]`` are masked to ``NEG_INF`` exactly
as ``models.layers.streaming_attention`` masks them, so block-table
entries past a row's fill (conventionally the sink page 0) contribute
exact zeros and the result is invariant to how the table tail is padded.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # matches models.layers.NEG_INF


def _paged_kernel(ptab_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, da_ref, *,
                  page_size: int, scale: float):
    b = pl.program_id(0)
    phase = pl.program_id(1)
    j = pl.program_id(2)
    n_pages = pl.num_programs(2)

    @pl.when((phase == 0) & (j == 0))
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kv_heads, g, hd = acc_ref.shape
    qg = q_ref[0].reshape(kv_heads, g, hd) * scale       # (KV, g, hd) f32
    k = k_ref[0]                                         # (ps, KV, hd)

    s = jnp.einsum("kgd,pkd->kgp", qg, k,
                   preferred_element_type=jnp.float32,
                   precision=jax.lax.Precision.HIGHEST)  # (KV, g, ps)
    k_pos = j * page_size + jax.lax.iota(jnp.int32, page_size)
    valid = k_pos < len_ref[b]
    s = jnp.where(valid[None, None, :], s, NEG_INF)

    @pl.when(phase == 0)
    def _max_pass():
        m_ref[...] = jnp.maximum(m_ref[...], jnp.max(s, axis=-1))

    @pl.when(phase == 1)
    def _materialize():
        p = jnp.exp(s - m_ref[...][..., None])           # (KV, g, ps)
        l_ref[...] = l_ref[...] + jnp.sum(p, axis=-1)
        # Store this page's numerator term instead of accumulating it in
        # place: `acc + p @ v` contracts to an FMA when page_size == 1
        # degenerates the contraction to a multiply (LLVM contracts even
        # across an optimization barrier), which would drift 1 ulp from
        # the oracle.  The store forces the product through a
        # loop-carried f32 slot; phase 2 adds only rounded values.
        da_ref[j] = jnp.einsum("kgp,pkd->kgd", p, v_ref[0],
                               preferred_element_type=jnp.float32,
                               precision=jax.lax.Precision.HIGHEST)

    @pl.when(phase == 2)
    def _accumulate():
        acc_ref[...] = acc_ref[...] + da_ref[j]

    @pl.when((phase == 2) & (j == n_pages - 1))
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[0] = out.reshape(kv_heads * g, hd)


def paged_attention_pallas(
    q: jax.Array,          # (B, H, hd) float32
    k_pages: jax.Array,    # (P, page_size, KV, hd) float32
    v_pages: jax.Array,    # (P, page_size, KV, hd) float32
    ptab: jax.Array,       # (B, NP) int32 block table
    kv_len: jax.Array,     # (B,) int32 valid positions per row
    *,
    scale: float,
    interpret: bool = True,
) -> jax.Array:
    b, h, hd = q.shape
    _, page_size, kv_heads, _ = k_pages.shape
    n_pages = ptab.shape[1]
    if h % kv_heads:
        raise ValueError(f"{h} query heads not divisible by {kv_heads} "
                         "KV heads")
    g = h // kv_heads
    kern = functools.partial(_paged_kernel, page_size=page_size,
                             scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, 3, n_pages),
        in_specs=[
            pl.BlockSpec((1, h, hd),
                         lambda bi, ph, j, tab, ln: (bi, 0, 0)),
            pl.BlockSpec((1, page_size, kv_heads, hd),
                         lambda bi, ph, j, tab, ln: (tab[bi, j], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kv_heads, hd),
                         lambda bi, ph, j, tab, ln: (tab[bi, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, hd),
                               lambda bi, ph, j, tab, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kv_heads, g), jnp.float32),       # global max
            pltpu.VMEM((kv_heads, g), jnp.float32),       # denominator
            pltpu.VMEM((kv_heads, g, hd), jnp.float32),   # weighted acc
            pltpu.VMEM((n_pages, kv_heads, g, hd),
                       jnp.float32),                      # per-page terms
        ],
    )
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), jnp.float32),
        interpret=interpret,
    )(ptab, kv_len, q, k_pages, v_pages)
