"""Gradient compression for the cross-pod data-parallel all-reduce.

Two pieces:

* **Error-feedback int8 quantization** (`ef_compress`/`ef_residual`): the
  gradient (plus carried residual) is quantized to int8 with a per-leaf
  fp32 scale before the cross-pod reduction; the quantization error is
  carried to the next step (error feedback keeps SGD/Adam convergence).
* **int8 ring all-reduce** (`ring_allreduce_int8`): a shard_map-level ring
  over the named axis exchanging int8 payloads + fp32 scales via
  ``ppermute``, dequant-add-requant at each hop.  Wire traffic is 1/4 of a
  bf16 ring (1/2 of fp8-less bf16 + scale overhead ~0.4%), which is the
  point: the pod-to-pod hop is the slow DCN link at 512+ chips.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _quant_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress(grads: Any, residual: Any) -> Tuple[Any, Any, Any]:
    """Quantize (grad + residual) to int8; return (q, scales, new_residual)."""

    def one(g, r):
        x = g.astype(jnp.float32) + r
        q, s = _quant_int8(x)
        return q, s, x - _dequant(q, s)

    out = jax.tree.map(one, grads, residual)
    pick = lambda i: jax.tree.map(
        lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), pick(1), pick(2)


def init_residual(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _axis_size(axis_name) -> int:
    """Static size of a named mesh axis inside shard_map.

    jax >= 0.5 spells this ``lax.axis_size``; 0.4.x exposes it as
    ``jax.core.axis_frame`` (which returns the size directly on 0.4.37,
    a frame object with ``.size`` on some adjacent versions).
    """
    if hasattr(lax, "axis_size"):
        return lax.axis_size(axis_name)
    frame = jax.core.axis_frame(axis_name)
    return getattr(frame, "size", frame)


def ring_allreduce_int8(q: jax.Array, scale: jax.Array, axis_name: str):
    """Ring all-reduce of an int8 payload inside shard_map.

    Returns the fp32 mean over the axis.  Each of the ``n-1`` hops moves
    int8 + one fp32 scale; the accumulator is requantized after each add,
    bounding wire format at 8 bits everywhere.
    """
    n = _axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    # The int8 payload rotates around the ring *unchanged* (each rank's
    # original contribution visits every rank); the accumulator is local
    # fp32 and never hits the wire, so there are no requantization chains.
    acc = _dequant(q, scale)
    relay_q, relay_s = q, scale
    for _ in range(n - 1):
        relay_q = lax.ppermute(relay_q, axis_name, perm)
        relay_s = lax.ppermute(relay_s, axis_name, perm)
        acc = acc + _dequant(relay_q, relay_s)
    return acc / n
