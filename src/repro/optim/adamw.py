"""AdamW, implemented from scratch (no optax): decoupled weight decay,
bias-corrected moments, global-norm clipping, schedules.

Moments are stored in fp32.  Under the production mesh the moments inherit
the parameter sharding; ZeRO-style additional sharding over the data axis
is applied by the sharding layer (``repro.sharding.rules.opt_sharding``),
not here — this module is mesh-agnostic.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    step: jax.Array
    mu: Any
    nu: Any


def init(params) -> AdamWState:
    z = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=z,
                      nu=jax.tree.map(jnp.copy, z))


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def update(
    grads,
    state: AdamWState,
    params,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[Any, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        u = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (-lr * u).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.mu, state.nu, params)
    updates = jax.tree.map(lambda o: o[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    mu = jax.tree.map(lambda o: o[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    nu = jax.tree.map(lambda o: o[2], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda p, u: p + u, params, updates)
    return new_params, AdamWState(step=step, mu=mu, nu=nu)


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)

    return lr
