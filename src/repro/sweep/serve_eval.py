"""The LM serving evaluator: program → calibrate → serve, per design point.

The classifier vehicle (``evaluate.ClassifierEvaluator``) exercises the
analog pipeline on a 4-layer MLP; this module is the same executor
protocol at the paper's actual experiment scale — a *full trained LM*
served through ``repro.serve.analog_engine``.  Per (design point, trial):

1. **program**  — ``program_lm_from_codes`` perturbs cached integer code
   stacks with trial-keyed cell errors.  The deterministic half
   (``lm_program_codes``: quantize + map every hook of the network) is
   cached per ``(mapping signature, params hash)`` — the LM-scale
   analogue of ``ClassifierEvaluator``'s programmed-codes cache, except
   the cached object is a whole pack of layer-stacked code matrices.
2. **calibrate** — the two collect passes of ``calibrate_lm`` (activation
   clips, then per-(layer, slice) ADC ranges), inside the trace.
3. **evaluate** — teacher-forced cross-entropy + top-1 next-token
   accuracy on held-out tokens, plus (optionally) ``decode_match``: the
   fraction of greedy KV-cached decode tokens agreeing with the digital
   model on a prompt batch — the serving configuration, not just
   teacher forcing.

Trials are vmapped over PRNG keys, design points over traced dynamic
scalars (``error.alpha``, ``mapping.on_off_ratio``), and the point/trial
batch shards over the 1-D ``data`` mesh — all through the same executor
(``run_sweep``) and dispatch layer as every other sweep.

:func:`serve_serial_reference` is the eager one-point-at-a-time loop the
tier-2 differential suite (``tests/test_serve_sweep.py``) pins the
vectorized path against: same key derivation
(``fold_in(PRNGKey(seed), trial)`` then the stable per-hook name fold of
``serve.analog_engine.hook_key``), same calibration placement.
"""

from __future__ import annotations

import hashlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.analog import AnalogSpec
from repro.hw.profile import HEAD, as_profile
from repro.serve.analog_engine import (
    analog_eval_metrics,
    calibrate_lm,
    decode_lm,
    lm_hook_names,
    lm_program_codes,
    program_lm,
    program_lm_from_codes,
)
from repro.sweep.dispatch import shard_point_trial_batch
from repro.sweep.evaluate import (
    dynamic_fields_for,
    mapping_signature,
    materialize,
    trial_keys,
)


def _hash_tree(h, tree) -> None:
    """Fold a pytree of arrays into a hash, order-stable by path."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    for path, leaf in sorted(leaves, key=lambda kv: jax.tree_util.keystr(kv[0])):
        h.update(jax.tree_util.keystr(path).encode())
        h.update(np.asarray(leaf).tobytes())


class ServeEvaluator:
    """Vectorized end-to-end analog LM serving metrics for the executor.

    One instance owns a trained LM (``cfg`` + ``params``), a calibration
    token batch, and held-out eval tokens/targets; the executor hands it
    compile groups and it returns per-(point, trial) metric dicts
    (``loss``, ``top1``, and ``decode_match`` when ``prompts`` given)
    from a single jitted, optionally mesh-sharded evaluation.

    ``test_n`` (from the sweep protocol) subsamples eval *rows* —
    the LM analogue of the classifier's test-subset trick.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params: dict,
        calib_tokens: jax.Array,
        eval_tokens: jax.Array,
        eval_targets: jax.Array,
        *,
        prompts: Optional[jax.Array] = None,
        decode_new: int = 8,
        include_head: bool = True,
        version: str = "v1",
    ):
        self.cfg = cfg
        self.params = params
        self.calib_tokens = jnp.asarray(calib_tokens)
        self.eval_tokens = jnp.asarray(eval_tokens)
        self.eval_targets = jnp.asarray(eval_targets)
        self.prompts = None if prompts is None else jnp.asarray(prompts)
        self.decode_new = decode_new
        self.include_head = include_head

        h = hashlib.sha256()
        h.update(repr(cfg).encode())
        _hash_tree(h, params)
        for a in (self.calib_tokens, self.eval_tokens, self.eval_targets):
            h.update(np.asarray(a).tobytes())
        if self.prompts is not None:
            h.update(np.asarray(self.prompts).tobytes())
            h.update(str(decode_new).encode())
        h.update(str(include_head).encode())
        self._sig = f"serve/{cfg.name}/{version}/{h.hexdigest()[:16]}"

        # digital greedy reference for decode_match, computed once
        self._digital_toks = None
        if self.prompts is not None:
            self._digital_toks = decode_lm(
                cfg, params, self.prompts, decode_new, pack=None)

        self._codes_cache: Dict[str, dict] = {}
        self._fn_cache: Dict[Tuple, Any] = {}

    # -- executor protocol -------------------------------------------------
    def signature(self) -> str:
        return self._sig

    def dynamic_fields(self, spec: AnalogSpec) -> Dict[str, float]:
        return dynamic_fields_for(spec)

    def evaluate_group(
        self,
        template: AnalogSpec,
        dyn_names: Tuple[str, ...],
        dyn_rows: Sequence[Tuple[float, ...]],
        trials: int,
        seed: int,
        test_n: Optional[int],
        mesh=None,
    ) -> List[List[Dict[str, float]]]:
        """Evaluate all design points of one compile group at once."""
        dyn = jnp.asarray(np.asarray(dyn_rows, dtype=np.float32).reshape(
            len(dyn_rows), len(dyn_names)))
        keys = trial_keys(seed, trials)
        dyn, keys = shard_point_trial_batch(dyn, keys, mesh)
        fn = self._compiled(template, dyn_names, test_n)
        out = jax.block_until_ready(fn(dyn, keys))
        out = {k: np.asarray(v) for k, v in out.items()}   # (points, trials)
        return [
            [{k: float(out[k][p, t]) for k in sorted(out)}
             for t in range(trials)]
            for p in range(len(dyn_rows))
        ]

    # -- caches ------------------------------------------------------------
    def _codes_key(self, template) -> str:
        """Per-*site* mapping-signature key of the programmed-codes cache.

        Codes depend only on each site's mapping (g_min-independent), so
        design points agreeing on every site's mapping — including which
        sites are digital — share one cached code pack.  A global
        AnalogSpec template resolves uniformly and degenerates to the
        legacy single-signature key.
        """
        profile = as_profile(template)
        parts = []
        for name in lm_hook_names(self.cfg):
            sp = profile.first_analog(name, self.cfg.n_layers)
            parts.append(
                f"{name}={'digital' if sp is None else mapping_signature(sp)}")
        if self.include_head:
            # the head has no layer index: mirror lm_program_codes, which
            # resolves it at layer=None (band rules never match it) — a
            # first_analog key here would collide banded-digital-head
            # profiles with analog-head ones and poison the cache
            hs = profile.resolve(HEAD)
            parts.append(
                f"{HEAD}="
                f"{mapping_signature(hs) if isinstance(hs, AnalogSpec) else 'digital'}")
        return "|".join(parts)

    def _codes(self, template) -> dict:
        """Programmed-pack cache keyed by (site mappings, params hash).

        The params hash is carried by the evaluator signature (one
        evaluator = one network), so the in-memory key is the per-site
        mapping signature alone — same structure as
        ``ClassifierEvaluator._programmed``.
        """
        key = self._codes_key(template)
        if key not in self._codes_cache:
            self._codes_cache[key] = lm_program_codes(
                self.cfg, self.params, template,
                include_head=self.include_head)
        return self._codes_cache[key]

    def _compiled(self, template: AnalogSpec, dyn_names: Tuple[str, ...],
                  test_n: Optional[int]):
        fkey = (repr(template), dyn_names, test_n)
        if fkey in self._fn_cache:
            return self._fn_cache[fkey]
        codes = self._codes(template)
        tokens = self.eval_tokens if test_n is None else self.eval_tokens[:test_n]
        targets = self.eval_targets if test_n is None else self.eval_targets[:test_n]

        def point_fn(dyn_vec, keys):
            assigns = {nm: dyn_vec[j] for j, nm in enumerate(dyn_names)}
            spec = materialize(template, assigns)

            def one_trial(k):
                pack = program_lm_from_codes(self.cfg, codes, spec, k)
                pack = calibrate_lm(self.cfg, self.params, pack,
                                    self.calib_tokens)
                m = analog_eval_metrics(self.cfg, self.params, pack,
                                        tokens, targets)
                if self.prompts is not None:
                    toks = decode_lm(self.cfg, self.params, self.prompts,
                                     self.decode_new, pack=pack)
                    m["decode_match"] = jnp.mean(
                        (toks == self._digital_toks).astype(jnp.float32))
                return m

            return jax.vmap(one_trial)(keys)

        fn = jax.jit(jax.vmap(point_fn, in_axes=(0, None)))
        self._fn_cache[fkey] = fn
        return fn


def runtime_agreement(
    cfg: ModelConfig,
    params: dict,
    requests: Sequence[Tuple[Any, int]],
    *,
    pack=None,
    max_slots: int = 4,
    max_len: Optional[int] = None,
    buckets: Optional[Sequence[int]] = None,
    seed: int = 0,
) -> float:
    """``decode_match``'s runtime sibling: greedy token agreement between
    the continuous-batching runtime and per-request ``decode_lm``.

    ``requests`` is a list of ``(prompt tokens, max_new)`` pairs with
    arbitrary (mixed) prompt lengths.  Each request is served twice at
    the same analog config: once through :class:`repro.serve.ServeRuntime`
    (slot-scheduled, bucket-padded, interleaved with whatever else is in
    flight) and once through the one-shot ``decode_lm`` reference
    (exact-length prompt, dedicated batch).  Returns the fraction of
    generated tokens that agree — the contract value is 1.0: scheduling
    must never change what the model says (pinned by
    ``tests/test_runtime.py`` and gated in ``benchmarks/servebench.py``).
    """
    from repro.serve.runtime import ServeRuntime

    prompts = [np.asarray(p, np.int32).reshape(-1) for p, _ in requests]
    n_new = [int(n) for _, n in requests]
    if max_len is None:
        max_len = max(p.size + n for p, n in zip(prompts, n_new))
    rt = ServeRuntime(cfg, params, pack=pack, max_slots=max_slots,
                      max_len=max_len, buckets=buckets, seed=seed)
    uids = [rt.submit(p, max_new_tokens=n) for p, n in zip(prompts, n_new)]
    outs = rt.run()
    agree = total = 0
    for uid, p, n in zip(uids, prompts, n_new):
        ref = np.asarray(decode_lm(cfg, params, jnp.asarray(p)[None, :], n,
                                   pack=pack))[0]
        got = outs[uid]
        total += n
        agree += int(np.sum(got[:ref.size] == ref[:got.size]))
    return agree / max(total, 1)


def pack_with_fused(pack, mode: str):
    """A copy of an :class:`AnalogPack` with every site spec's ``fused``
    field set to ``mode`` (``"off"`` | ``"kernel"`` | ``"oracle"``).

    ``fused`` selects program structure, not numbers-on-the-wire state:
    conductances, calibrated ranges and keys are shared by reference, so
    the copies serve the *same device* through different lowerings —
    exactly what :func:`fused_runtime_agreement` compares.  ``None``
    passes through (digital serving has no pack to rewrite).
    """
    import dataclasses

    from repro.hw.profile import SiteSpecs

    if pack is None:
        return None

    def rw(s):
        return (dataclasses.replace(s, fused=mode)
                if isinstance(s, AnalogSpec) else s)

    bands = tuple(
        SiteSpecs(items=tuple((n, rw(s)) for n, s in ss.items))
        for ss in pack.band_specs)
    profile = dataclasses.replace(
        pack.profile,
        rules=tuple(dataclasses.replace(r, spec=rw(r.spec))
                    for r in pack.profile.rules),
        default=rw(pack.profile.default))
    return dataclasses.replace(
        pack, band_specs=bands, profile=profile,
        head_spec=None if pack.head_spec is None else rw(pack.head_spec))


def fused_runtime_agreement(
    cfg: ModelConfig,
    params: dict,
    requests: Sequence[Tuple[Any, int]],
    *,
    pack=None,
    max_slots: int = 4,
    max_len: Optional[int] = None,
    sampler=None,
    seed: int = 0,
    modes: Tuple[str, str] = ("kernel", "oracle"),
    attn: Tuple[str, str] = ("flash", "flash_oracle"),
) -> float:
    """Token agreement between two fused lowerings of the same server.

    Serves every request twice through :class:`repro.serve.ServeRuntime`
    at the same device state, sampler and seed — by default once with
    the fused Pallas kernels (``fused="kernel"`` pack + flash-decode
    attention) and once with their jnp oracles (``fused="oracle"`` +
    flash oracle).  The oracle side *is* the composed multi-op chain,
    so this is the end-to-end fused-vs-composed serving gate; the
    contract value is 1.0 (kernel and oracle are pinned bitwise inside
    the jitted decode step), greedy or seeded sampling, digital
    (``pack=None``) or analog, uniform or heterogeneous packs — gated
    in ``benchmarks/servebench.py`` and pinned by
    ``tests/test_fastpath_routing.py``.  ``modes``/``attn`` select the
    two lowerings; e.g. ``modes=("kernel", "off")``,
    ``attn=("stream", "stream")`` compares the fused MVM chain against
    the legacy composed path at matched attention.
    """
    from repro.serve.runtime import SamplerConfig, ServeRuntime

    prompts = [np.asarray(p, np.int32).reshape(-1) for p, _ in requests]
    n_new = [int(n) for _, n in requests]
    if max_len is None:
        max_len = max(p.size + n for p, n in zip(prompts, n_new))
    sampler = SamplerConfig() if sampler is None else sampler
    outs = []
    for mode, ab in zip(modes, attn):
        rt = ServeRuntime(cfg, params, pack=pack_with_fused(pack, mode),
                          max_slots=max_slots, max_len=max_len,
                          sampler=sampler, seed=seed, attn_backend=ab)
        for i, (p, n) in enumerate(zip(prompts, n_new)):
            rt.submit(p, max_new_tokens=n, uid=f"req-{i}")
        outs.append(rt.run())
    ref, got = outs
    agree = total = 0
    for uid, r in ref.items():
        g = got[uid]
        total += max(r.size, g.size)
        agree += int(np.sum(r[:g.size] == g[:r.size]))
    return agree / max(total, 1)


def paged_runtime_agreement(
    cfg: ModelConfig,
    params: dict,
    requests: Sequence[Tuple[Any, int]],
    *,
    pack=None,
    max_slots: int = 4,
    max_len: Optional[int] = None,
    page_size: int = 8,
    num_pages: Optional[int] = None,
    sampler=None,
    seed: int = 0,
    backend: str = "gather",
) -> float:
    """Token agreement between the paged and dense serving runtimes.

    Every request is served twice at the same analog config and the
    same sampler/seed: once through the dense-slot
    :class:`repro.serve.ServeRuntime` (the differential oracle) and once
    through :class:`repro.serve.PagedServeRuntime` (paged KV + prefix
    sharing).  Returns the fraction of generated tokens that agree —
    the contract value is 1.0 *bitwise*, greedy or seeded sampling: the
    KV layout must never change what the model says (pinned by
    ``tests/test_paged.py``, gated in ``benchmarks/servebench.py``).
    ``max_len`` defaults to the tightest ``page_size`` multiple
    covering the longest request.
    """
    from repro.serve.paged import PagedServeRuntime
    from repro.serve.runtime import SamplerConfig, ServeRuntime

    prompts = [np.asarray(p, np.int32).reshape(-1) for p, _ in requests]
    n_new = [int(n) for _, n in requests]
    if max_len is None:
        need = max(p.size + n for p, n in zip(prompts, n_new))
        max_len = -(-need // page_size) * page_size
    sampler = SamplerConfig() if sampler is None else sampler
    dense = ServeRuntime(cfg, params, pack=pack, max_slots=max_slots,
                         max_len=max_len, sampler=sampler, seed=seed)
    paged = PagedServeRuntime(cfg, params, pack=pack, max_slots=max_slots,
                              max_len=max_len, page_size=page_size,
                              num_pages=num_pages, sampler=sampler,
                              seed=seed, backend=backend)
    agree = total = 0
    for rt in (dense, paged):
        for i, (p, n) in enumerate(zip(prompts, n_new)):
            rt.submit(p, max_new_tokens=n, uid=f"req-{i}")
    ref, got = dense.run(), paged.run()
    paged.check()
    for uid, r in ref.items():
        g = got[uid]
        total += max(r.size, g.size)
        agree += int(np.sum(r[:g.size] == g[:r.size]))
    return agree / max(total, 1)


def serve_serial_reference(
    cfg: ModelConfig,
    params: dict,
    spec: AnalogSpec,
    calib_tokens: jax.Array,
    eval_tokens: jax.Array,
    eval_targets: jax.Array,
    *,
    prompts: Optional[jax.Array] = None,
    decode_new: int = 8,
    include_head: bool = True,
    trials: int = 5,
    seed: int = 1234,
) -> List[Dict[str, float]]:
    """One-point-at-a-time eager program → calibrate → eval reference.

    The bit-faithful baseline the tier-2 differential suite pins
    :class:`ServeEvaluator` against (same role ``serial_accuracy`` plays
    for the classifier path).  Returns one metric dict per trial.
    """
    root = jax.random.PRNGKey(seed)
    digital_toks = None
    if prompts is not None:
        digital_toks = decode_lm(cfg, params, prompts, decode_new, pack=None)
    out: List[Dict[str, float]] = []
    for t in range(trials):
        key = jax.random.fold_in(root, t)
        pack = program_lm(cfg, params, spec, key, include_head=include_head)
        pack = calibrate_lm(cfg, params, pack, calib_tokens)
        m = analog_eval_metrics(cfg, params, pack, eval_tokens, eval_targets)
        if prompts is not None:
            toks = decode_lm(cfg, params, prompts, decode_new, pack=pack)
            m["decode_match"] = jnp.mean(
                (toks == digital_toks).astype(jnp.float32))
        out.append({k: float(v) for k, v in sorted(m.items())})
    return out
