"""The sweep executor: group -> batch -> evaluate -> cache.

Execution model (DESIGN.md §Sweep-engine):

1. **Expand** the :class:`~repro.sweep.spec.SweepSpec` grid into the flat
   design-point table.
2. **Resume**: points whose ``(evaluator signature, spec, protocol)``
   hash is already in the on-disk :class:`~repro.sweep.results.SweepCache`
   are returned without recomputation.
3. **Group** the remaining points by *compile signature* — the spec with
   the evaluator's dynamic scalar fields (error magnitude, On/Off ratio)
   replaced by a placeholder.  Points in one group differ only in values
   that can be traced, so the whole group is one jitted evaluation with
   trials vmapped over PRNG keys and points vmapped over the dynamic
   scalars.
4. **Dispatch** each group through the evaluator, optionally sharded over
   a device mesh (``repro.sweep.dispatch``), timing wall-clock per group.
5. **Record** one :class:`~repro.sweep.results.PointResult` per point and
   persist the cache.

The executor never inspects metric semantics — evaluators own that — so
accuracy sweeps, conductance audits, SNR probes, and energy tables all
run through this one path.
"""

from __future__ import annotations

import sys
import time
from typing import Dict, List, Optional, Tuple

from repro.sweep.results import (
    PointResult,
    SweepCache,
    SweepResults,
    point_key,
)
from repro.sweep.spec import DesignPoint, SweepSpec, set_field

#: placeholder written into dynamic fields to form the compile signature;
#: never evaluated numerically (real values are substituted in-trace).
_CANONICAL = 0.0


def compile_groups(
    points: List[Tuple[str, DesignPoint]],
    evaluator,
    all_points: Optional[List[DesignPoint]] = None,
) -> List[Tuple[object, Tuple[str, ...], List[Tuple[str, DesignPoint, Tuple[float, ...]]]]]:
    """Partition (cache_key, point) pairs into single-compilation batches.

    A dynamic field is only *actually* batched when its value varies
    across the sweep's points: a constant field stays a concrete Python
    float, which keeps the common single-value case bit-identical to the
    serial reference (traced scalars round ``1 - 1/on_off`` in float32,
    concrete ones in Python double — a 1-ULP conductance difference that
    can flip an ADC rounding boundary).

    ``all_points`` is the FULL expanded design-point table; the varying
    set must come from it, not from the (possibly cache-thinned)
    ``points``, so that whether a field is traced — and hence a point's
    exact numerics — is a deterministic property of the sweep, never of
    which other points happened to be cached.
    """
    dyns = {id(pt): evaluator.dynamic_fields(pt.spec) for _, pt in points}
    seen: Dict[str, set] = {}
    basis = all_points if all_points is not None else [pt for _, pt in points]
    for pt in basis:
        for path, value in evaluator.dynamic_fields(pt.spec).items():
            seen.setdefault(path, set()).add(value)
    varying = {path for path, vals in seen.items() if len(vals) > 1}

    groups: Dict[Tuple[str, Tuple[str, ...]], Tuple[object, Tuple[str, ...], list]] = {}
    for key, pt in points:
        dyn = {p: v for p, v in dyns[id(pt)].items() if p in varying}
        dyn_names = tuple(sorted(dyn))
        template = pt.spec
        for name in dyn_names:
            template = set_field(template, name, _CANONICAL)
        gkey = (repr(template), dyn_names)
        if gkey not in groups:
            groups[gkey] = (template, dyn_names, [])
        groups[gkey][2].append((key, pt, tuple(dyn[n] for n in dyn_names)))
    return list(groups.values())


def run_sweep(
    sweep: SweepSpec,
    evaluator,
    *,
    cache_dir: Optional[str] = None,
    force: bool = False,
    mesh=None,
    verbose: bool = False,
) -> SweepResults:
    """Evaluate every design point of ``sweep``, vectorized and resumable.

    ``cache_dir`` enables the on-disk cache (``<cache_dir>/sweeps/
    <name>.json``); ``force`` recomputes cached points; ``mesh`` shards
    the point/trial batch over devices (None = single-device).
    """
    points = sweep.expand()
    protocol = sweep.point_protocol()
    sig = evaluator.signature()
    cache = SweepCache(cache_dir, sweep.name) if cache_dir else None

    results: List[PointResult] = []
    pending: List[Tuple[str, DesignPoint]] = []
    for pt in points:
        key = point_key(sig, pt, protocol)
        hit = cache.get(key) if (cache and not force) else None
        if hit is not None:
            results.append(
                PointResult.from_values(pt, hit.values, hit.wall_s,
                                        cached=True))
        else:
            pending.append((key, pt))

    groups = compile_groups(pending, evaluator, all_points=points)
    if verbose and pending:
        # stderr: benchmarks.run's stdout is a CSV contract
        print(f"# sweep[{sweep.name}]: {len(pending)}/{len(points)} points "
              f"to run in {len(groups)} compile group(s)",
              file=sys.stderr, flush=True)

    for template, dyn_names, members in groups:
        rows = [m[2] for m in members]
        t0 = time.perf_counter()
        values = evaluator.evaluate_group(
            template, dyn_names, rows, sweep.trials, sweep.seed,
            sweep.test_n, mesh=mesh)
        wall = time.perf_counter() - t0
        if len(values) != len(members):
            raise ValueError(
                f"evaluator returned {len(values)} results for "
                f"{len(members)} points")
        per_point = wall / max(len(members), 1)
        for (key, pt, _), vals in zip(members, values):
            res = PointResult.from_values(pt, vals, per_point)
            results.append(res)
            if cache is not None:
                cache.put(key, res)

    if cache is not None:
        cache.save()
    return SweepResults(sweep, results)
