"""Declarative design-space sweeps (paper Figs. 6-19, Tables 3-4).

The paper's experimental method is one loop repeated thirteen times:
take a trained network, sweep a grid of analog design points (mapping
scheme x cell-error magnitude x ADC resolution x array size x parasitic
level), and average the metric over repeated programming trials.  A
:class:`SweepSpec` states that grid declaratively — a base
:class:`~repro.core.analog.AnalogSpec` plus :class:`Axis` entries naming
dotted field paths — and :meth:`SweepSpec.expand` flattens it into the
design-point table the executor (``repro.sweep.executor``) batches,
caches, and shards.  See DESIGN.md §Sweep-engine.

Two axis flavors:

* a single dotted path (``Axis("adc.bits", (5, 6, 7, 8))``) — a normal
  cartesian factor;
* a *zipped* tuple of paths
  (``Axis(("mapping.scheme", "input_accum"),
  (("differential", "analog"), ("offset", "digital")))``) — fields that
  co-vary, e.g. the paper always pairs offset subtraction with digital
  input accumulation.

Explicit point lists (the named designs A-E of Table 3/4) bypass the
grid via :meth:`SweepSpec.from_points`.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.analog import AnalogSpec


def set_field(obj, path: str, value):
    """Functionally set a dotted dataclass field path, e.g. ``mapping.scheme``.

    On a :class:`repro.hw.Profile`, paths are spelled
    ``"<selector>:<field.path>"`` (e.g. ``"attn:adc.bits"``): the selector
    names the profile rule(s) whose spec the field is set on (``"default"``
    for the fallback spec).  This is what makes per-site-class sweep axes
    compose with the unchanged grid/executor machinery.
    """
    from repro.hw.profile import Profile

    if isinstance(obj, Profile):
        selector, sep, rest = path.partition(":")
        if not sep or not rest:
            raise ValueError(
                f"profile field paths are '<selector>:<field.path>' "
                f"(e.g. 'attn:adc.bits'), got {path!r}")
        return obj.with_field(selector, rest, value)
    head, _, rest = path.partition(".")
    if rest:
        return dataclasses.replace(
            obj, **{head: set_field(getattr(obj, head), rest, value)}
        )
    return dataclasses.replace(obj, **{head: value})


def get_field(obj, path: str):
    from repro.hw.profile import Profile

    if isinstance(obj, Profile):
        selector, sep, rest = path.partition(":")
        if not sep or not rest:
            raise ValueError(
                f"profile field paths are '<selector>:<field.path>' "
                f"(e.g. 'attn:adc.bits'), got {path!r}")
        return obj.field(selector, rest)
    for name in path.split("."):
        obj = getattr(obj, name)
    return obj


def short_value(v) -> str:
    """Compact human-readable form of an axis value for point tags."""
    if v is None:
        return "None"
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        kind = getattr(v, "kind", None)
        if kind is not None:  # ErrorModel
            alpha = getattr(v, "alpha", 0.0)
            return kind if kind in ("none", "sonos") else f"{kind}:{alpha:g}"
        return type(v).__name__
    if isinstance(v, float):
        return "inf" if math.isinf(v) else f"{v:g}"
    return str(v)


@dataclasses.dataclass(frozen=True)
class Axis:
    """One swept factor: a field path (or zipped paths) and its values."""

    path: Any                      # str | tuple[str, ...]
    values: Tuple[Any, ...]
    labels: Optional[Tuple[str, ...]] = None   # overrides tag fragments

    def __post_init__(self):
        object.__setattr__(self, "values", tuple(self.values))
        if self.labels is not None:
            object.__setattr__(self, "labels", tuple(self.labels))
            if len(self.labels) != len(self.values):
                raise ValueError(
                    f"axis {self.path!r}: {len(self.labels)} labels for "
                    f"{len(self.values)} values")

    @property
    def paths(self) -> Tuple[str, ...]:
        return (self.path,) if isinstance(self.path, str) else tuple(self.path)

    def entries(self) -> List[Tuple[Dict[str, Any], str]]:
        """(assignments, tag fragment) per value."""
        out = []
        for i, v in enumerate(self.values):
            vs = (v,) if isinstance(self.path, str) else tuple(v)
            if len(vs) != len(self.paths):
                raise ValueError(
                    f"zipped axis {self.path!r} expects {len(self.paths)} "
                    f"values per entry, got {v!r}")
            assign = dict(zip(self.paths, vs))
            if self.labels is not None:
                frag = self.labels[i]
            else:
                name = self.paths[0].rsplit(".", 1)[-1]
                frag = f"{name}{short_value(vs[0])}"
            out.append((assign, frag))
        return out


@dataclasses.dataclass(frozen=True)
class DesignPoint:
    """One row of the expanded design-point table."""

    index: int
    tag: str
    spec: AnalogSpec
    coords: Tuple[Tuple[str, Any], ...]   # (path, value) in axis order

    def coord(self, path: str):
        for p, v in self.coords:
            if p == path:
                return v
        raise KeyError(path)


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """A named design-space sweep: grid x trials x evaluation protocol.

    ``trials`` is the paper's repeated-programming-trial count (Sec. 5's
    10-trial protocol); ``seed`` derives the per-trial PRNG keys exactly
    as the legacy serial loop did, so vectorized and serial execution are
    seed-equivalent.  ``test_n`` optionally subsamples the test set
    (Sec. 4.3's 1000-image subset trick for expensive parasitic points).

    ``base`` is an :class:`~repro.core.analog.AnalogSpec` or — for
    heterogeneous serving sweeps — a :class:`repro.hw.Profile`, in which
    case axis paths are spelled ``"<selector>:<field.path>"``
    (``Axis("mlp:adc.bits", (4, 6, 8))``).
    """

    name: str
    base: Any = dataclasses.field(default_factory=AnalogSpec)
    axes: Tuple[Axis, ...] = ()
    explicit: Optional[Tuple[Tuple[str, AnalogSpec], ...]] = None
    trials: int = 5
    seed: int = 1234
    test_n: Optional[int] = None

    @classmethod
    def from_points(
        cls,
        name: str,
        points: Iterable[Tuple[str, AnalogSpec]],
        **kw,
    ) -> "SweepSpec":
        return cls(name=name, explicit=tuple(points), **kw)

    def expand(self) -> List[DesignPoint]:
        """Flatten the declared grid into the design-point table."""
        if self.explicit is not None:
            return [
                DesignPoint(index=i, tag=tag, spec=spec,
                            coords=(("point", tag),))
                for i, (tag, spec) in enumerate(self.explicit)
            ]
        points: List[DesignPoint] = []
        per_axis = [ax.entries() for ax in self.axes]
        for i, combo in enumerate(itertools.product(*per_axis)):
            spec = self.base
            frags: List[str] = []
            coords: List[Tuple[str, Any]] = []
            for assign, frag in combo:
                for path, value in assign.items():
                    spec = set_field(spec, path, value)
                    coords.append((path, value))
                frags.append(frag)
            tag = "_".join(frags) if frags else "base"
            points.append(
                DesignPoint(index=i, tag=tag, spec=spec, coords=tuple(coords))
            )
        return points

    def point_protocol(self) -> str:
        """The evaluation-protocol part of a point's cache identity."""
        return f"trials={self.trials};seed={self.seed};test_n={self.test_n}"
