"""``repro.sweep`` — the vectorized design-space sweep engine.

Replaces the hand-rolled per-design-point Python loops of the benchmark
scripts with one declarative, batched, cached, mesh-shardable path:

>>> from repro.sweep import Axis, SweepSpec, ClassifierEvaluator, run_sweep
>>> sweep = SweepSpec(
...     name="onoff",
...     base=spec0,
...     axes=(Axis("mapping.on_off_ratio", (10.0, 100.0, float("inf"))),),
...     trials=5,
... )
>>> results = run_sweep(sweep, ClassifierEvaluator(layers, xca, xte, yte),
...                     cache_dir="benchmarks/_cache")
>>> results.mean("on_off_ratio100")

See DESIGN.md §Sweep-engine for the execution model.
"""

from repro.sweep.dispatch import shard_leading, sweep_mesh
from repro.sweep.evaluate import (
    ClassifierEvaluator,
    FunctionEvaluator,
    mapping_signature,
    materialize,
    serial_accuracy,
    trial_accuracy,
    trial_keys,
)
from repro.sweep.executor import compile_groups, run_sweep
from repro.sweep.results import PointResult, SweepCache, SweepResults, point_key
from repro.sweep.serve_eval import ServeEvaluator, serve_serial_reference
from repro.sweep.spec import Axis, DesignPoint, SweepSpec, get_field, set_field

__all__ = [
    "Axis",
    "ClassifierEvaluator",
    "DesignPoint",
    "FunctionEvaluator",
    "PointResult",
    "ServeEvaluator",
    "SweepCache",
    "SweepResults",
    "SweepSpec",
    "compile_groups",
    "get_field",
    "mapping_signature",
    "materialize",
    "point_key",
    "run_sweep",
    "serial_accuracy",
    "serve_serial_reference",
    "set_field",
    "shard_leading",
    "sweep_mesh",
    "trial_accuracy",
    "trial_keys",
]
