"""Sweep results: structured per-point records + resumable on-disk cache.

Every evaluated design point becomes a :class:`PointResult`; a sweep's
results persist as one JSON file per sweep name (default under
``benchmarks/_cache/sweeps``), keyed by a content hash of
``(evaluator signature, spec repr, trial protocol)``.  Re-running a sweep
— after a crash, an added axis value, or on another host with the cache
directory synced — recomputes only the missing points (the same
resumability contract as ``repro.launch.dryrun``'s result files).
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import math
import os
import tempfile
from typing import Any, Dict, List, Optional

from repro.sweep.spec import DesignPoint, SweepSpec


def point_key(evaluator_sig: str, point: DesignPoint, protocol: str) -> str:
    """Stable cache identity of one evaluated design point.

    ``repr`` of an :class:`~repro.core.analog.AnalogSpec` is deterministic
    (frozen dataclasses of primitives), so the hash covers every static
    field of the design point plus the weights/data hash carried in the
    evaluator signature.
    """
    blob = "\n".join([evaluator_sig, repr(point.spec), protocol])
    return hashlib.sha256(blob.encode()).hexdigest()[:24]


@dataclasses.dataclass
class PointResult:
    """Metric values for one design point.

    ``values`` holds per-trial scalars for trial-based metrics, or a
    single entry (possibly a dict of named metrics) for deterministic
    ones; ``mean``/``std`` are populated only for scalar trials.
    """

    index: int
    tag: str
    coords: Dict[str, str]
    values: List[Any]
    mean: Optional[float]
    std: Optional[float]
    wall_s: float
    cached: bool = False

    @classmethod
    def from_values(cls, point: DesignPoint, values, wall_s: float,
                    cached: bool = False) -> "PointResult":
        vals = list(values) if isinstance(values, (list, tuple)) else [values]
        mean = std = None
        if vals and all(isinstance(v, (int, float)) for v in vals):
            finite = [float(v) for v in vals]
            mean = sum(finite) / len(finite)
            std = math.sqrt(sum((v - mean) ** 2 for v in finite) / len(finite))
        return cls(
            index=point.index,
            tag=point.tag,
            coords={p: str(v) for p, v in point.coords},
            values=vals,
            mean=mean,
            std=std,
            wall_s=wall_s,
            cached=cached,
        )

    def metric_mean(self, key: str) -> float:
        """Mean of one named metric over dict-valued trials.

        Evaluators with non-scalar per-trial state (``ServeEvaluator``:
        loss / top1 / decode_match per trial) store one dict per trial in
        ``values``; ``mean``/``std`` stay None and aggregation goes
        through here.
        """
        vals = [v[key] for v in self.values if isinstance(v, dict)]
        if not vals:
            raise KeyError(
                f"{self.tag} has no dict-valued trials with {key!r}")
        return sum(float(v) for v in vals) / len(vals)

    def metric_std(self, key: str) -> float:
        vals = [float(v[key]) for v in self.values if isinstance(v, dict)]
        if not vals:
            raise KeyError(
                f"{self.tag} has no dict-valued trials with {key!r}")
        mean = sum(vals) / len(vals)
        return math.sqrt(sum((v - mean) ** 2 for v in vals) / len(vals))

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "PointResult":
        return cls(**d)


class SweepResults:
    """Ordered point results with tag lookup and small aggregations."""

    def __init__(self, sweep: SweepSpec, results: List[PointResult]):
        self.sweep = sweep
        self.results = sorted(results, key=lambda r: r.index)
        self._by_tag = {r.tag: r for r in self.results}

    def __iter__(self):
        return iter(self.results)

    def __len__(self):
        return len(self.results)

    def __getitem__(self, tag: str) -> PointResult:
        return self._by_tag[tag]

    def mean(self, tag: str) -> float:
        r = self[tag]
        if r.mean is None:
            raise ValueError(
                f"{tag} has non-scalar values; use metric() instead")
        return r.mean

    def metric(self, tag: str, key: str) -> float:
        """Trial-mean of one named metric of a dict-valued point."""
        return self[tag].metric_mean(key)

    def value(self, tag: str):
        return self[tag].values[0]

    @property
    def total_wall_s(self) -> float:
        return sum(r.wall_s for r in self.results if not r.cached)

    @property
    def n_cached(self) -> int:
        return sum(1 for r in self.results if r.cached)


class SweepCache:
    """One JSON file of finished point results per sweep name."""

    def __init__(self, cache_dir: str, name: str):
        self.path = os.path.join(cache_dir, "sweeps", f"{name}.json")
        self._data: Dict[str, dict] = {}
        if os.path.exists(self.path):
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except (json.JSONDecodeError, OSError):
                self._data = {}   # corrupt cache: recompute everything

    def get(self, key: str) -> Optional[PointResult]:
        d = self._data.get(key)
        if d is None:
            return None
        r = PointResult.from_json(d)
        r.cached = True
        return r

    def put(self, key: str, result: PointResult) -> None:
        self._data[key] = result.to_json()

    def save(self) -> None:
        os.makedirs(os.path.dirname(self.path), exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(self.path))
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._data, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)   # atomic: a crash never corrupts
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise
