"""Sweep evaluators: the vectorized trial pipeline and its serial reference.

The paper's metric loop (program -> calibrate -> evaluate, averaged over
programming trials, Sec. 5) appears here exactly once, in
:func:`trial_accuracy`.  Around it:

* :class:`ClassifierEvaluator` — the vectorized executor backend.  Trials
  become a ``vmap`` over PRNG keys; design points that share a compiled
  shape (same mapping scheme / slice count / partition count / ADC style)
  are batched into a single jitted evaluation by substituting their
  error magnitude and On/Off ratio as *traced scalars* into the
  :class:`~repro.core.analog.AnalogSpec`.  The deterministic half of
  programming (quantize + integer code mapping) is cached per
  ``(mapping signature, weights hash)`` via
  :func:`repro.core.analog.program_codes`, so per-trial work is only
  perturb + matmul + ADC.
* :func:`serial_accuracy` — the legacy one-point-at-a-time eager loop the
  benchmark scripts used before the sweep engine existed.  It is kept as
  the bit-faithful reference: the equivalence test
  (``tests/test_sweep.py``) and the ``kernelbench`` wall-clock comparison
  both pin the vectorized path against it, same seeds in, same
  accuracies out.
* :class:`FunctionEvaluator` — generic per-point metrics (conductance
  averages, energy models, SNR probes) with optional vmapped trials.

See DESIGN.md §Sweep-engine for the batching rules and their tracer-
safety constraints.
"""

from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog import (
    AnalogSpec,
    ProgrammedMatrix,
    analog_matmul,
    program,
    program_codes,
    program_from_codes,
)
from repro.core.calibrate import constrain_power_of_two
from repro.core.quant import calibrate_act_range
from repro.sweep.dispatch import shard_point_trial_batch
from repro.sweep.spec import set_field


def trial_keys(seed: int, trials: int) -> jax.Array:
    """The per-trial key stack, identical to the legacy serial derivation."""
    root = jax.random.PRNGKey(seed)
    return jnp.stack([jax.random.fold_in(root, t) for t in range(trials)])


def materialize(template: AnalogSpec, assignments: Dict[str, Any]) -> AnalogSpec:
    """Substitute (possibly traced) values into a template spec."""
    spec = template
    for path, value in assignments.items():
        spec = set_field(spec, path, value)
    return spec


def trial_accuracy(
    layers: Sequence[Tuple[jax.Array, jax.Array]],
    spec: AnalogSpec,
    trial_key: jax.Array,
    xca: jax.Array,
    xte: jax.Array,
    yte: jax.Array,
    *,
    act_fn: Callable = jax.nn.relu,
    pms: Optional[Sequence[ProgrammedMatrix]] = None,
) -> jax.Array:
    """One programming trial of the analog classifier (paper Sec. 5).

    Per layer: program (or reuse cached codes), calibrate the activation
    clip on the calibration split, run the collect pass for calibrated
    ADC ranges (power-of-two constrained when sliced, Sec. 6.2), then
    evaluate test and calibration batches through the analog pipeline.
    Traceable in the trial key and in ``spec.error.alpha`` /
    ``spec.mapping.on_off_ratio`` / ``spec.r_hat`` (while parasitics are
    on — the on/off bit itself is static, ``AnalogSpec.parasitics_on``).
    """
    h_te, h_ca = xte, xca
    for i, (w, b) in enumerate(layers):
        layer_key = jax.random.fold_in(trial_key, i)
        if pms is None:
            aw = program(w, spec, layer_key)
        else:
            aw = program_from_codes(pms[i], spec, layer_key)
        _, act_hi = calibrate_act_range(h_ca, spec.input_bits)
        if spec.adc.style == "calibrated":
            _, stats = analog_matmul(h_ca, aw, spec, act_hi=act_hi,
                                     collect=True)
            lo, hi = stats[:, 0], stats[:, 1]
            if spec.mapping.sliced:
                lo, hi = constrain_power_of_two(lo, hi)
            kw = dict(adc_lo=lo, adc_hi=hi)
        else:
            kw = {}
        y_te = analog_matmul(h_te, aw, spec, act_hi=act_hi, **kw) + b
        y_ca = analog_matmul(h_ca, aw, spec, act_hi=act_hi, **kw) + b
        if i < len(layers) - 1:
            h_te, h_ca = act_fn(y_te), act_fn(y_ca)
        else:
            h_te = y_te
    return jnp.mean(jnp.argmax(h_te, -1) == yte)


def serial_accuracy(
    layers: Sequence[Tuple[jax.Array, jax.Array]],
    spec: AnalogSpec,
    xca: jax.Array,
    xte: jax.Array,
    yte: jax.Array,
    *,
    trials: int = 5,
    seed: int = 1234,
    act_fn: Callable = jax.nn.relu,
) -> Tuple[float, float, List[float]]:
    """The legacy per-point serial loop: one eager trial at a time.

    Kept as the reference implementation the vectorized executor is
    tested against (and timed against in ``benchmarks/kernelbench.py``).
    """
    root = jax.random.PRNGKey(seed)
    accs = [
        float(trial_accuracy(layers, spec, jax.random.fold_in(root, t),
                             xca, xte, yte, act_fn=act_fn))
        for t in range(trials)
    ]
    return float(np.mean(accs)), float(np.std(accs)), accs


def dynamic_fields_for(spec) -> Dict[str, float]:
    """The spec fields batchable as traced scalars for ``spec``.

    Shared by every accuracy evaluator (``ClassifierEvaluator``,
    ``ServeEvaluator``) so the tracer-safety exclusion rules cannot drift
    apart between the classifier and serving sweep paths:

    * ``error.alpha`` — only for sampled error kinds;
    * ``mapping.on_off_ratio`` — excluded under the FPG ADC, whose range
      snapping consumes ``g_min`` in Python ``math.floor``;
    * ``r_hat`` — only while parasitics are *on*; the on/off bit is a
      static program property (``AnalogSpec.parasitics_on``), which is
      what collapses a Fig. 19 axis into one compile group.
    * ``drift.nu`` / ``drift.t`` — only under power-law drift, and
      ``fault.rate`` / ``fault.t`` — only with stuck faults: like
      parasitics, kind is static (``AnalogSpec.aging_on``) while the
      horizon and magnitude trace, so a ``benchmarks/driftbench`` grid
      over ``drift.t`` compiles once.

    ``spec`` may also be a :class:`repro.hw.Profile`: each analog rule's
    dynamic fields are prefixed with its selector
    (``"attn:error.alpha"``), matching the profile spelling of
    ``set_field`` — so mixed-precision serving grids batch per profile
    signature exactly like global-spec grids batch per shape.  A selector
    shared by several rules (layer bands) stays dynamic only if the rules
    agree on the value (``with_field`` sets all of them at once).
    """
    from repro.hw.profile import Profile

    if isinstance(spec, Profile):
        seen: Dict[str, List[float]] = {}
        for selector, sp in spec.selectors():
            for path, v in dynamic_fields_for(sp).items():
                seen.setdefault(f"{selector}:{path}", []).append(v)
        return {name: vals[0] for name, vals in seen.items()
                if len(set(vals)) == 1}
    dyn: Dict[str, float] = {}
    if spec.error.kind in ("state_independent", "state_proportional"):
        dyn["error.alpha"] = float(spec.error.alpha)
    if spec.adc.style != "fpg":
        dyn["mapping.on_off_ratio"] = float(spec.mapping.on_off_ratio)
    if spec.parasitics_on:
        dyn["r_hat"] = float(spec.r_hat)
    if spec.drift.kind == "power_law":
        dyn["drift.nu"] = float(spec.drift.nu)
        dyn["drift.t"] = float(spec.drift.t)
    if spec.fault.kind == "stuck":
        dyn["fault.rate"] = float(spec.fault.rate)
        dyn["fault.t"] = float(spec.fault.t)
    return dyn


def mapping_signature(spec: AnalogSpec) -> str:
    """The fields :func:`program_codes` depends on (g_min-independent).

    Shared key of the programmed-codes caches: per-network code stacks are
    identical across all design points agreeing on these fields
    (``ClassifierEvaluator._programmed``, ``ServeEvaluator`` pack cache).
    """
    m = spec.mapping
    return f"{m.scheme}|{m.weight_bits}|{m.bits_per_cell}|{m.unit_column}"


class ClassifierEvaluator:
    """Vectorized analog accuracy of a feed-forward classifier.

    One instance owns the network weights and the calibration/test splits;
    the executor hands it compile groups and it returns per-(point, trial)
    accuracies from a single jitted, optionally mesh-sharded evaluation.
    """

    def __init__(
        self,
        layers: Sequence[Tuple[jax.Array, jax.Array]],
        xca: jax.Array,
        xte: jax.Array,
        yte: jax.Array,
        *,
        act_fn: Callable = jax.nn.relu,
        version: str = "v1",
    ):
        self.layers = [(jnp.asarray(w), jnp.asarray(b)) for w, b in layers]
        self.xca, self.xte, self.yte = (
            jnp.asarray(xca), jnp.asarray(xte), jnp.asarray(yte))
        self.act_fn = act_fn
        h = hashlib.sha256()
        for w, b in self.layers:
            h.update(np.asarray(w).tobytes())
            h.update(np.asarray(b).tobytes())
        for a in (self.xca, self.xte, self.yte):
            h.update(np.asarray(a).tobytes())
        self._sig = f"classifier/{version}/{act_fn.__name__}/{h.hexdigest()[:16]}"
        self._pm_cache: Dict[str, List[ProgrammedMatrix]] = {}
        self._fn_cache: Dict[Tuple, Callable] = {}

    # -- executor protocol -------------------------------------------------
    def signature(self) -> str:
        return self._sig

    def dynamic_fields(self, spec: AnalogSpec) -> Dict[str, float]:
        return dynamic_fields_for(spec)

    def evaluate_group(
        self,
        template: AnalogSpec,
        dyn_names: Tuple[str, ...],
        dyn_rows: Sequence[Tuple[float, ...]],
        trials: int,
        seed: int,
        test_n: Optional[int],
        mesh=None,
    ) -> List[List[float]]:
        """Evaluate all design points of one compile group at once."""
        dyn = jnp.asarray(np.asarray(dyn_rows, dtype=np.float32).reshape(
            len(dyn_rows), len(dyn_names)))
        keys = trial_keys(seed, trials)
        dyn, keys = shard_point_trial_batch(dyn, keys, mesh)
        fn = self._compiled(template, dyn_names, test_n)
        accs = np.asarray(jax.block_until_ready(fn(dyn, keys)))
        return [row.tolist() for row in accs]

    # -- caches ------------------------------------------------------------
    def _programmed(self, template: AnalogSpec) -> List[ProgrammedMatrix]:
        """Programmed-weight cache keyed by (mapping signature, weights)."""
        key = mapping_signature(template)
        if key not in self._pm_cache:
            self._pm_cache[key] = [
                program_codes(w, template) for w, _ in self.layers
            ]
        return self._pm_cache[key]

    def _compiled(self, template: AnalogSpec, dyn_names: Tuple[str, ...],
                  test_n: Optional[int]) -> Callable:
        fkey = (repr(template), dyn_names, test_n)
        if fkey in self._fn_cache:
            return self._fn_cache[fkey]
        pms = self._programmed(template)
        xca, yte = self.xca, self.yte
        xte = self.xte if test_n is None else self.xte[:test_n]
        yt = yte if test_n is None else yte[:test_n]

        def point_fn(dyn_vec, keys):
            assigns = {nm: dyn_vec[j] for j, nm in enumerate(dyn_names)}
            spec = materialize(template, assigns)

            def one_trial(k):
                return trial_accuracy(self.layers, spec, k, xca, xte, yt,
                                      act_fn=self.act_fn, pms=pms)

            return jax.vmap(one_trial)(keys)

        fn = jax.jit(jax.vmap(point_fn, in_axes=(0, None)))
        self._fn_cache[fkey] = fn
        return fn


def _to_py(v):
    """JSON-able form of a metric value."""
    if isinstance(v, dict):
        return {k: _to_py(x) for k, x in v.items()}
    if isinstance(v, (list, tuple)):
        return [_to_py(x) for x in v]
    if isinstance(v, (jax.Array, np.ndarray)):
        arr = np.asarray(v)
        return float(arr) if arr.ndim == 0 else arr.tolist()
    if isinstance(v, (np.floating, np.integer)):
        return float(v)
    return v


class FunctionEvaluator:
    """Generic per-point metric for non-accuracy sweeps.

    ``fn(spec)`` for deterministic metrics (conductance averages, energy
    models); ``fn(spec, key)`` with ``takes_key=True`` for Monte-Carlo
    metrics, in which case the per-trial keys are vmapped through one
    jitted call (``vectorize=True``) instead of a Python trial loop.

    ``data`` MUST name everything ``fn`` closes over that can change
    between runs (weight matrices, calibration batches, model-fit
    constants): it is hashed into the cache signature, and omitting it
    lets the on-disk sweep cache serve results computed from stale
    inputs.  Pass arrays directly — they are hashed by content.
    """

    def __init__(
        self,
        fn: Callable,
        *,
        name: str,
        version: str = "v1",
        takes_key: bool = False,
        vectorize: bool = True,
        data: Sequence[Any] = (),
    ):
        self.fn = fn
        self.takes_key = takes_key
        self.vectorize = vectorize
        h = hashlib.sha256()
        for item in data:
            if isinstance(item, (jax.Array, np.ndarray)):
                h.update(np.asarray(item).tobytes())
            else:
                h.update(repr(item).encode())
        self._sig = f"function/{name}/{version}/{h.hexdigest()[:16]}"

    def signature(self) -> str:
        return self._sig

    def dynamic_fields(self, spec: AnalogSpec) -> Dict[str, float]:
        return {}

    def evaluate_group(self, template, dyn_names, dyn_rows, trials, seed,
                       test_n, mesh=None) -> List[List[Any]]:
        if dyn_names:
            raise ValueError(
                f"FunctionEvaluator declares no dynamic fields but the "
                f"executor passed {dyn_names!r}")
        if not self.takes_key:
            vals = [_to_py(self.fn(template))]
        elif self.vectorize:
            keys = trial_keys(seed, trials)
            out = jax.jit(jax.vmap(lambda k: self.fn(template, k)))(keys)
            vals = _to_py(out)
        else:
            root = jax.random.PRNGKey(seed)
            vals = [_to_py(self.fn(template, jax.random.fold_in(root, t)))
                    for t in range(trials)]
        return [list(vals) for _ in dyn_rows]
