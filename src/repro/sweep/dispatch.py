"""Mesh-sharded sweep dispatch.

A sweep's batch dimensions — design points within a compile group, and
programming trials within a point — are embarrassingly parallel, so they
shard over the same ``data`` axis the training/serving stack uses
(``repro.launch.mesh`` axis conventions; parameters and calibration data
stay replicated, exactly like FSDP-off serving in ``repro.sharding``).

On a single-device host everything below is a no-op and the jitted sweep
runs unsharded; on a multi-device host (or under
``--xla_force_host_platform_device_count``) the point/trial batch is
placed with a :class:`~jax.sharding.NamedSharding` and GSPMD partitions
the whole evaluation — programming, calibration, ADC, argmax — with no
changes to the evaluator.  See DESIGN.md §Sweep-engine.
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def sweep_mesh() -> Optional[jax.sharding.Mesh]:
    """1-D ``data`` mesh over all local devices; None when single-device."""
    devs = jax.devices()
    if len(devs) < 2:
        return None
    return jax.make_mesh((len(devs),), ("data",))


def shard_leading(arr: jax.Array, mesh: Optional[jax.sharding.Mesh],
                  axis: int = 0) -> jax.Array:
    """Shard ``axis`` of ``arr`` over the mesh's ``data`` axis.

    Falls back to the unsharded array when the mesh is absent or the dim
    does not divide (replication is always correct; the divisibility rule
    mirrors ``repro.sharding.rules``'s per-dim fallback).
    """
    if mesh is None or arr.ndim == 0:
        return arr
    n = mesh.shape["data"]
    if arr.shape[axis] % n != 0:
        return arr
    spec = [None] * arr.ndim
    spec[axis] = "data"
    return jax.device_put(arr, NamedSharding(mesh, P(*spec)))


def shard_point_trial_batch(dyn: jax.Array, keys: jax.Array,
                            mesh: Optional[jax.sharding.Mesh]):
    """Place the (points, dyn) matrix and (trials, key) stack on the mesh.

    Prefers sharding the larger batch axis: design points when they
    divide the axis, else trials.  Exactly one axis is sharded so GSPMD
    never has to all-gather mid-evaluation.
    """
    if mesh is None:
        return dyn, keys
    n = mesh.shape["data"]
    if dyn.shape[0] % n == 0 and dyn.shape[0] >= keys.shape[0]:
        return shard_leading(dyn, mesh), keys
    if keys.shape[0] % n == 0:
        return dyn, shard_leading(keys, mesh)
    # neither axis divides the mesh: replicate explicitly.  (The previous
    # fallback called shard_leading on the points axis, which silently
    # no-ops on the same divisibility check — stating the replication
    # outcome here keeps the contract readable and testable.)
    return dyn, keys
