"""Sharded, asynchronous, elastic checkpointing.

Layout: ``<dir>/step_<n>/`` with one ``.npy`` per pytree leaf (flattened
path as filename) plus ``manifest.json`` (paths, shapes, dtypes, step).
Writes go to ``step_<n>.tmp`` and are renamed at the end — a crashed write
never corrupts the latest checkpoint.  ``save_async`` does the serialization
on a daemon thread (the train loop donates a host copy and keeps going).

Elasticity: the manifest stores *global* shapes only.  ``restore`` rebuilds
the pytree and ``device_put``s it under whatever sharding the *current*
mesh prescribes — a 512-chip checkpoint restores onto 256 chips (or 1 CPU)
unchanged.  On a real multi-host pod each host would write its shard slice;
the manifest format (leaf -> shape/dtype) is already per-shard capable via
the ``shard`` field.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _key_str(p) -> str:
    for attr in ("key", "name", "idx"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _flatten(tree) -> dict:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        out["/".join(_key_str(p) for p in path)] = leaf
    return out


def _unflatten_into(template, values: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        leaves.append(values["/".join(_key_str(p) for p in path)])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Synchronous save."""
        host = jax.tree.map(np.asarray, tree)
        self._write(step, host, extra or {})

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        """Asynchronous save: device->host copy happens now (cheap, donates
        nothing), serialization on a daemon thread."""
        self.wait()
        host = jax.tree.map(np.asarray, tree)
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True
        )
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra: dict):
        final = os.path.join(self.dir, f"step_{step:09d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for name, arr in flat.items():
            arr = np.asarray(arr)
            fname = name.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][name] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": str(arr.dtype),
                "shard": None,
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        step: Optional[int] = None,
        *,
        sharding_fn: Optional[Callable[[str, tuple], Any]] = None,
    ):
        """Restore into the structure of ``template``.

        ``sharding_fn(leaf_name, shape)`` may return a ``jax.sharding``
        object per leaf — this is the elastic-reshard hook: the checkpoint
        knows nothing about meshes; placement is decided entirely here.
        Returns (tree, step, extra).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint found under {self.dir!r}")
        path = os.path.join(self.dir, f"step_{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        values = {}
        for name, meta in manifest["leaves"].items():
            arr = np.load(os.path.join(path, meta["file"]))
            if sharding_fn is not None:
                sh = sharding_fn(name, tuple(meta["shape"]))
                values[name] = (
                    jax.device_put(arr, sh) if sh is not None
                    else jnp.asarray(arr)
                )
            else:
                values[name] = jnp.asarray(arr)
        return _unflatten_into(template, values), step, manifest["extra"]
